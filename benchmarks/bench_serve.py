"""Serving control plane — continuous-traffic SLO drills (DESIGN.md §10).

Each section replays one built-in registry scenario through *both*
control-plane arms (`repro.serve.ControlPlane`): the adaptive arm runs
every tenant as an arbitrated ``Session`` on a shared congestion-pricing
fabric, the static arm freezes each tenant's plan at join — and the
scenario's declared :class:`~repro.serve.SloSpec` gates the pair:

  * **steady** — two balanced tenants, no drills.  The no-regression
    scenario: the adaptive control plane must *match* the static baseline
    (combined drain parity >= 0.99x) while holding every SLO;
  * **elephant_victim** — a victim tenant absorbing sustained background
    elephant flows on a rail pair.  Adaptive re-solves must spread the
    elephant across alternates (combined drain win > 1x) while holding
    the Jain floor and the p99 gate;
  * **flap_under_load** — drifting skew while a rail link flaps.
    Adaptive must beat static on combined drain, recover within the SLO's
    window budget after the final restore, and keep availability up;
  * **churn** — ``churn_storm``'s scavenger storm against the same
    scenario with churn stripped: once the last churned tenant leaves,
    the survivor's steady-state (tail-median) drain must sit within 2% of
    the never-churned run, and churn must never cost the survivor more
    than 2% over the whole horizon.

Metrics land in ``BENCH_serve.json`` (tagged ``nimble.serve/v1``, the
adaptive arm's full per-scenario reports embedded) for
``experiments/make_report.py``; :func:`validate_serve` is the ``run.py
--smoke`` ``serve_slo`` gate.
"""

from __future__ import annotations

import numpy as np

from repro.serve import (
    evaluate_scenario,
    get_scenario,
    run_scenario,
    validate_serve_record,
)

from .common import emit


def _gate_values(slo: dict) -> dict:
    return {k: v["value"] for k, v in slo["gates"].items()}


def _scenario_section(name: str) -> dict:
    """Both arms + SLO verdict for one registry scenario, summarized."""
    spec = get_scenario(name)
    res = evaluate_scenario(spec)
    adaptive, static, slo = res["adaptive"], res["static"], res["slo"]
    win = slo["gates"]["combined_drain"]["value"]
    emit(
        f"serve/{name}/W{spec.windows}", 0.0,
        f"slo={'PASS' if slo['pass'] else 'FAIL'} win={win:.3f}x "
        f"jain={adaptive.jain_index:.3f} avail={adaptive.availability:.3f} "
        f"tenants={len(adaptive.tenants)}",
    )
    return {
        "windows": spec.windows,
        "tenants": len(adaptive.tenants),
        "slo_pass": bool(slo["pass"]),
        "gates": _gate_values(slo),
        "adaptive_total_s": adaptive.total_completion_s,
        "static_total_s": static.total_completion_s,
        "win": float(win),
        "jain": float(adaptive.jain_index),
        "availability": float(adaptive.availability),
        "recovery_windows": adaptive.recovery_windows,
        "fault_digest": adaptive.fault_digest,
        "report": adaptive.to_json_obj(),
    }


def churn_section() -> dict:
    """``churn_storm`` vs its never-churned control, same adaptive arm."""
    spec = get_scenario("churn_storm")
    churned = run_scenario(spec, "adaptive")
    control = run_scenario(spec.without_churn(), "adaptive")
    survivor = spec.tenants[0].name
    last_leave = max(
        t.leave_window for t in spec.roster() if t.leave_window is not None
    )
    vc = churned.tenants[survivor].ring.values()
    v0 = control.tenants[survivor].ring.values()
    tail_c = float(np.median(vc[last_leave:]))
    tail_0 = float(np.median(v0[last_leave:]))
    tail_ratio = tail_c / tail_0 if tail_0 > 0 else 1.0
    total_ratio = (
        churned.tenants[survivor].completion_s
        / control.tenants[survivor].completion_s
    )
    churners = [
        n for n, led in churned.tenants.items() if n != survivor
    ]
    emit(
        f"serve/churn/W{spec.windows}", 0.0,
        f"survivor_tail={tail_ratio:.4f}x control (target |r-1| <= 0.02) "
        f"whole_run={total_ratio:.4f}x churners={len(churners)}",
    )
    return {
        "windows": spec.windows,
        "survivor": survivor,
        "churned_tenants": len(churners),
        "last_leave_window": int(last_leave),
        "survivor_tail_s": tail_c,
        "control_tail_s": tail_0,
        "tail_ratio": float(tail_ratio),
        "total_ratio": float(total_ratio),
    }


# -- smoke gate -------------------------------------------------------------------

def validate_serve(metrics: dict) -> None:
    """The ``serve_slo`` gate (``run.py --smoke``).

    Raises ``ValueError`` naming the first violated invariant:

      * every scenario section holds its declared SLOs (the scenario's own
        ``SloSpec`` — p99, availability, Jain, recovery, drain floors);
      * steady: adaptive/static combined-drain parity >= 0.99x;
      * elephant_victim and flap_under_load: adaptive strictly beats
        static on combined drain (> 1.0x);
      * churn: survivor steady-state tail within 2% of the never-churned
        control, whole-run drain no more than 2% worse;
      * each embedded report is a valid ``nimble.serve/v1`` record.
    """
    for key in ("steady", "elephant_victim", "flap_under_load", "churn"):
        if key not in metrics or not isinstance(metrics[key], dict):
            raise ValueError(f"serve metrics missing section {key!r}")
    for name in ("steady", "elephant_victim", "flap_under_load"):
        sec = metrics[name]
        if not sec["slo_pass"]:
            failed = [
                g for g, v in sec["gates"].items()
                if isinstance(v, (int, float)) and not np.isfinite(v)
            ]
            raise ValueError(
                f"serve scenario {name!r}: SLO gates failed "
                f"(gates: {sec['gates']})"
                + (f"; non-finite: {failed}" if failed else "")
            )
        validate_serve_record(sec["report"])
    if metrics["steady"]["win"] < 0.99:
        raise ValueError(
            f"serve steady: adaptive parity {metrics['steady']['win']:.4f}x "
            "static < 0.99x — the adaptive control plane regresses a "
            "scenario it should match"
        )
    for name in ("elephant_victim", "flap_under_load"):
        if metrics[name]["win"] <= 1.0:
            raise ValueError(
                f"serve {name}: adaptive {metrics[name]['win']:.4f}x static "
                "— no combined-drain win on a skewed scenario"
            )
    churn = metrics["churn"]
    if abs(churn["tail_ratio"] - 1.0) > 0.02:
        raise ValueError(
            f"serve churn: survivor tail {churn['tail_ratio']:.4f}x the "
            "never-churned control (threshold 2%)"
        )
    if churn["total_ratio"] > 1.02:
        raise ValueError(
            f"serve churn: survivor whole-run drain {churn['total_ratio']:.4f}"
            "x the never-churned control — churn cost more than 2%"
        )


def metrics() -> dict:
    return {
        "steady": _scenario_section("steady"),
        "elephant_victim": _scenario_section("elephant_victim"),
        "flap_under_load": _scenario_section("flap_under_load"),
        "churn": churn_section(),
    }


def run() -> dict:
    return metrics()


def smoke() -> dict:
    """CI variant — host numpy over n=8; all four drills run in seconds."""
    return metrics()


if __name__ == "__main__":
    run()
