"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn: Callable, *args, n: int = 20, warmup: int = 3) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn(*args)
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]
