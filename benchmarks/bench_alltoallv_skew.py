"""Fig. 7: skewed All-to-Allv over 8 GPUs / 2 nodes, hotspot-ratio sweep.

Each rank sends a ``hotspot`` fraction of its payload to one hot
destination and spreads the rest evenly.  Compared: the NCCL baseline
(static PXN routing + grouped-p2p round serialization), static multirail
striping (UCX-like), and NIMBLE.  Paper: parity at low skew, up to 5.2x
at hotspot >= 0.7.
"""

from __future__ import annotations

from repro.core.cost import CostModel
from repro.core.fabsim import simulate, simulate_nccl_rounds
from repro.core.mcf import (
    congestion_lower_bound,
    solve_direct,
    solve_mwu,
    solve_static_striping,
)
from repro.core.topology import Topology

from .common import emit

MB = 1 << 20


def demands(hot: float, per_rank_mb: float = 64, n: int = 8):
    D = {}
    for s in range(n):
        for d in range(n):
            if s == d:
                continue
            if hot > 0:
                D[(s, d)] = per_rank_mb * MB * (
                    hot if d == 0 else (1 - hot) / (n - 2)
                )
            else:
                D[(s, d)] = per_rank_mb * MB / (n - 1)
    return D


def run() -> None:
    cm = CostModel()
    t = Topology(8, group_size=4)
    for hot in (0.0, 0.2, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9):
        D = demands(hot)
        t_nimble = simulate(solve_mwu(t, D, cm, eps=1 * MB)).completion_time
        t_direct = simulate(solve_direct(t, D, cm)).completion_time
        t_stripe = simulate(solve_static_striping(t, D, cm)).completion_time
        t_nccl = simulate_nccl_rounds(t, D, cm)
        lb = congestion_lower_bound(t, D, cm)
        emit(
            f"fig7/hotspot_{hot}",
            t_nimble * 1e6,
            f"vs_nccl={t_nccl/t_nimble:.2f}x vs_direct={t_direct/t_nimble:.2f}x "
            f"vs_stripe={t_stripe/t_nimble:.2f}x opt_gap={t_nimble/max(lb,1e-12):.2f}",
        )
    # paper headline: >= 5x at hotspot 0.7+
    D = demands(0.9)
    s = simulate_nccl_rounds(t, D, cm) / simulate(
        solve_mwu(t, D, cm, eps=1 * MB)
    ).completion_time
    emit("fig7/paper_check/peak_speedup", 0.0,
         f"got={s:.2f}x paper<=5.2x")


if __name__ == "__main__":
    run()
