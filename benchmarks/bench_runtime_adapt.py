"""Execution-time adaptation: static plan vs runtime vs oracle (DESIGN.md §3).

Three policies replay the same traces through the fabric simulator:

  * **static**  — one-shot plan solved on the first window, never replanned
    (what PR 1's planner could do: a single demand matrix per call);
  * **adaptive** — the orchestration runtime's full monitor -> estimate ->
    replan -> swap loop, default policy/estimator;
  * **oracle**  — clairvoyant per-window re-solve (all windows batched
    through one ``plan_flows_batch`` dispatch), the adaptation upper bound.

Scenarios mirror the runtime acceptance criteria:

  * drifting-skew trace — adaptive must recover most of the oracle's win
    over static (paper regime: unanticipated traffic drift);
  * balanced trace — adaptive must match static within noise with zero
    replans after warmup (the "no overhead when symmetric" claim);
  * link-down event — adaptive converges to a replacement plan with all
    demand served off the dead link.

Metrics land in ``BENCH_runtime_adapt.json`` (tagged
``nimble.bench_runtime_adapt/v1``) for the per-PR bench trajectory and
``experiments/make_report.py``.  All three policies run through
:class:`repro.api.Session` (DESIGN.md §5): static vs adaptive is a
one-field ``SessionSpec`` diff, and the oracle is the session's
``run_oracle`` bookend.
"""

from __future__ import annotations

import time

import numpy as np

from repro.api import Session, SessionSpec
from repro.core.topology import Topology
from repro.runtime import (
    EventLog,
    balanced_trace,
    drifting_skew_trace,
    link_down,
)

from .common import emit

N = 8
GROUP = 4


def _session(topo, **kw) -> Session:
    return Session(SessionSpec(topology=topo, adaptivity="adaptive", **kw))


def drift_section(windows: int = 48, dwell: int = 12) -> dict:
    topo = Topology(N, group_size=GROUP)
    trace = drifting_skew_trace(N, windows, dwell=dwell)

    with Session(SessionSpec(topology=topo)) as static_sess:
        static = static_sess.run_trace(trace)
    with _session(topo) as sess:
        oracle = sess.run_oracle(trace)
        t0 = time.perf_counter()
        adaptive = sess.run_trace(trace)
        us_adaptive = (time.perf_counter() - t0) * 1e6

    speedup = static.total_completion_s / adaptive.total_completion_s
    oracle_speedup = static.total_completion_s / oracle.total_completion_s
    emit(
        f"runtime/drift/W{windows}", us_adaptive,
        f"static={static.total_completion_s * 1e3:.1f}ms "
        f"adaptive={adaptive.total_completion_s * 1e3:.1f}ms "
        f"oracle={oracle.total_completion_s * 1e3:.1f}ms "
        f"speedup={speedup:.2f}x (target >=1.3x, oracle {oracle_speedup:.2f}x) "
        f"replans={len(adaptive.replan_windows)}/{windows} "
        f"(target <=25%)",
    )
    return {
        "windows": windows,
        "static_completion_s": static.total_completion_s,
        "adaptive_completion_s": adaptive.total_completion_s,
        "oracle_completion_s": oracle.total_completion_s,
        "adaptive_speedup": speedup,
        "oracle_speedup": oracle_speedup,
        "replan_fraction": adaptive.replan_fraction,
        "replans": len(adaptive.replan_windows),
        "solves": adaptive.stats.solves,
        "cache_hits": adaptive.stats.cache_hits,
        "loop_wall_us_per_window": us_adaptive / max(windows, 1),
        # estimator/telemetry health rides along in every WindowReport
        # (ISSUE 8): full-visibility drift should end at confidence 1.0
        # with zero rejected telemetry records
        "confidence_end": float(adaptive.reports[-1].confidence),
        "telemetry_rejected": int(adaptive.reports[-1].telemetry_rejected),
    }


def balanced_section(windows: int = 30) -> dict:
    topo = Topology(N, group_size=GROUP)
    trace = balanced_trace(N, windows)
    with Session(SessionSpec(topology=topo)) as static_sess:
        static = static_sess.run_trace(trace)
    with _session(topo) as sess:
        adaptive = sess.run_trace(trace)
    ratio = adaptive.total_completion_s / static.total_completion_s
    emit(
        f"runtime/balanced/W{windows}", 0.0,
        f"adaptive/static={ratio:.4f} (target within 2%) "
        f"replans={len(adaptive.replan_windows)} (target 0 after warmup)",
    )
    return {
        "windows": windows,
        "balanced_ratio": ratio,
        "balanced_replans": len(adaptive.replan_windows),
        "confidence_end": float(adaptive.reports[-1].confidence),
        "telemetry_rejected": int(adaptive.reports[-1].telemetry_rejected),
    }


def linkdown_section(windows: int = 24, fail_at: int = 8) -> dict:
    topo = Topology(N, group_size=GROUP)
    trace = balanced_trace(N, windows)
    events = EventLog([link_down(fail_at, 0, GROUP)])
    with _session(topo) as sess:
        res = sess.run_trace(trace, events=events)
    pre = np.median([r.completion_s for r in res.reports[:fail_at]])
    # convergence: first window after the fault whose completion is within
    # 2x the pre-fault median (the degraded fabric has less capacity, so
    # exact parity is not expected)
    converged = next(
        (
            r.window
            for r in res.reports[fail_at:]
            if r.completion_s <= 2.0 * pre
        ),
        None,
    )
    tail = res.reports[-1].completion_s
    emit(
        f"runtime/linkdown/W{windows}", 0.0,
        f"fault@w{fail_at} converged@w{converged} "
        f"tail={tail * 1e3:.2f}ms (pre-fault {pre * 1e3:.2f}ms)",
    )
    return {
        "windows": windows,
        "fail_window": fail_at,
        "converged_window": converged,
        "recovery_windows": (
            converged - fail_at if converged is not None else None
        ),
        "tail_completion_s": float(tail),
        "prefault_completion_s": float(pre),
    }


def metrics(windows: int = 48, dwell: int = 12) -> dict:
    out = {}
    out.update({"drift": drift_section(windows, dwell)})
    out.update({"balanced": balanced_section()})
    out.update({"linkdown": linkdown_section()})
    return out


def run() -> dict:
    return metrics()


def smoke() -> dict:
    """CI variant — the discrete-event loop is host numpy over n=8, so the
    full acceptance-size traces already run in a few seconds."""
    return metrics()


if __name__ == "__main__":
    run()
