"""Fig. 6(a)+(c): intra-node point-to-point bandwidth with 1/2/3 paths.

Reproduces the paper's message-size sweep on the 4-GPU node model:
direct NVLink (120 GB/s peak), +1 relay path (213.1), +2 relay paths
(278.2); saturation beyond ~64 MB; multi-pathing disabled <= 1 MB
(forward-overhead policy, Fig. 6c).
"""

from __future__ import annotations

from repro.core.cost import CostModel
from repro.core.fabsim import simulate
from repro.core.mcf import solve_direct, solve_mwu
from repro.core.topology import Topology

from .common import emit

MB = 1 << 20

PAPER = {"direct": 120.0, "one_relay": 213.1, "two_relay": 278.2}


def run() -> None:
    cm = CostModel()
    for size_mb in (1, 4, 16, 64, 256, 1024):
        d = {(0, 1): size_mb * MB}
        bw_direct = simulate(
            solve_direct(Topology(4, 4), d, cm)
        ).bandwidth_gbs()
        plan1 = solve_mwu(Topology(3, 3), d, cm, eps=min(1 * MB, size_mb * MB // 4))
        bw1 = simulate(plan1).bandwidth_gbs()
        plan2 = solve_mwu(Topology(4, 4), d, cm, eps=min(1 * MB, size_mb * MB // 4))
        bw2 = simulate(plan2).bandwidth_gbs()
        emit(f"fig6a/intra_direct/{size_mb}MB", 0.0, f"{bw_direct:.1f}GB/s")
        emit(f"fig6a/intra_1relay/{size_mb}MB", 0.0,
             f"{bw1:.1f}GB/s paths={plan1.n_paths_used((0,1))}")
        emit(f"fig6a/intra_2relay/{size_mb}MB", 0.0,
             f"{bw2:.1f}GB/s paths={plan2.n_paths_used((0,1))}")
    # paper-point comparison at 256 MB
    d = {(0, 1): 256 * MB}
    bw1 = simulate(solve_mwu(Topology(3, 3), d, cm, eps=1 * MB)).bandwidth_gbs()
    bw2 = simulate(solve_mwu(Topology(4, 4), d, cm, eps=1 * MB)).bandwidth_gbs()
    for name, got, want in (("direct", 120.0, PAPER["direct"]),
                            ("one_relay", bw1, PAPER["one_relay"]),
                            ("two_relay", bw2, PAPER["two_relay"])):
        emit(f"fig6a/paper_check/{name}", 0.0,
             f"got={got:.1f} paper={want} err={abs(got-want)/want*100:.1f}%")
    # Fig 6c: the policy — 1 MB must not split
    plan_small = solve_mwu(Topology(4, 4), {(0, 1): 1 * MB}, cm, eps=256 * 1024)
    emit("fig6c/no_split_at_1MB", 0.0,
         f"paths={plan_small.n_paths_used((0,1))} (expect 1)")


if __name__ == "__main__":
    run()
