"""Table I: orchestration-algorithm overhead vs communication time.

Paper: 1D stencil (each rank exchanges with neighbours); Algo column is
NIMBLE's planning time (0.032-0.048 ms on their CPUs), Comm is the actual
transfer.  We time BOTH planner implementations — the vectorized host
sweep (Algorithm 1 over the cached incidence tables) and the jitted MWU —
against the modeled communication time for the same message sizes.

Additional sections quantify the incidence-core refactor:

  * ``table1/host_speedup/n32`` — vectorized sweep vs the legacy
    sequential-refresh solver on a skewed all-pairs demand at n=32
    (acceptance target: >=5x);
  * ``table1/jit_trace`` / ``table1/jit_plan`` — cold trace+compile time
    and steady-state latency of the jitted planner;
  * ``table1/jit_batch`` — per-matrix latency when B tenants are planned
    in one ``plan_flows_batch`` call vs B sequential jit dispatches.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import CostModel
from repro.core.fabsim import simulate
from repro.core.mcf import solve_mwu
from repro.core.planner import PlannerConfig, plan_flows, plan_flows_batch
from repro.core.schedule import build_planner_tables
from repro.core.topology import Topology

from .common import emit, time_fn

MB = 1 << 20


def stencil_demands(n: int, size: float):
    D = {}
    for r in range(n):
        D[(r, (r + 1) % n)] = size
        D[(r, (r - 1) % n)] = size
    return D


def skewed_all_pairs(n: int, hot_mult: float = 8.0, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        (s, d): float(rng.integers(1, 64)) * MB * (hot_mult if d == 0 else 1.0)
        for s in range(n)
        for d in range(n)
        if s != d
    }


def host_speedup(n: int = 32, reps: int = 5, slow_reps: int = 2) -> dict:
    """Vectorized sweep vs legacy sequential-refresh solver at ``n`` devices."""
    cm = CostModel()
    t = Topology(n, group_size=4)
    D = skewed_all_pairs(n)
    us_sweep = time_fn(
        lambda: solve_mwu(t, D, cm, eps=1 * MB), n=reps, warmup=1
    )
    us_seq = time_fn(
        lambda: solve_mwu(t, D, cm, eps=1 * MB, refresh="sequential"),
        n=slow_reps, warmup=0,
    )
    speedup = us_seq / us_sweep
    emit(
        f"table1/host_speedup/n{n}", us_sweep,
        f"sweep={us_sweep / 1e3:.2f}ms legacy={us_seq / 1e3:.2f}ms "
        f"speedup={speedup:.1f}x (target >=5x)",
    )
    return {
        "n_devices": n,
        "host_sweep_us": us_sweep,
        "host_legacy_us": us_seq,
        "host_speedup": speedup,
    }


def jit_metrics(n: int = 8, batch: int = 8, reps: int = 30) -> dict:
    """Cold trace+compile time, steady latency, and batched-planning latency."""
    t = Topology(n, group_size=4)
    tables = build_planner_tables(t)
    cfg = PlannerConfig(chunk_bytes=float(MB))
    rng = np.random.default_rng(0)
    Dm = (rng.integers(1, 64, size=(n, n)) * MB).astype(np.float32)
    np.fill_diagonal(Dm, 0)

    planner = jax.jit(lambda d: plan_flows(d, tables, cfg)[0])
    t0 = time.perf_counter()
    planner(jnp.asarray(Dm)).block_until_ready()
    trace_ms = (time.perf_counter() - t0) * 1e3
    us_jit = time_fn(
        lambda: planner(jnp.asarray(Dm)).block_until_ready(), n=reps
    )
    emit(f"table1/jit_trace/n{n}", trace_ms * 1e3,
         f"cold trace+compile={trace_ms:.1f}ms")
    emit(f"table1/jit_plan/n{n}", us_jit, f"steady={us_jit / 1e3:.3f}ms")

    Db = np.stack([Dm] * batch)
    bplanner = jax.jit(lambda d: plan_flows_batch(d, tables, cfg)[0])
    bplanner(jnp.asarray(Db)).block_until_ready()
    us_batch = time_fn(
        lambda: bplanner(jnp.asarray(Db)).block_until_ready(), n=reps
    )
    per_matrix = us_batch / batch
    emit(
        f"table1/jit_batch/B{batch}_n{n}", per_matrix,
        f"batched={us_batch / 1e3:.3f}ms per_matrix={per_matrix / 1e3:.3f}ms "
        f"vs sequential={us_jit / 1e3:.3f}ms "
        f"({us_jit / max(per_matrix, 1e-9):.1f}x)",
    )
    return {
        "jit_trace_ms": trace_ms,
        "jit_plan_us": us_jit,
        "jit_batch_per_matrix_us": per_matrix,
        "batch": batch,
    }


def table1(sizes=(16, 32, 64, 128, 256), reps: int = 30) -> dict:
    cm = CostModel()
    t = Topology(8, group_size=4)
    tables = build_planner_tables(t)
    cfg = PlannerConfig(chunk_bytes=float(MB))
    planner = jax.jit(lambda d: plan_flows(d, tables, cfg)[0])

    out = {}
    for size_mb in sizes:
        dem = stencil_demands(8, size_mb * MB)
        Dm = np.zeros((8, 8), np.float32)
        for (s, d), v in dem.items():
            Dm[s, d] = v

        us_jit = time_fn(
            lambda: planner(jnp.asarray(Dm)).block_until_ready(), n=reps
        )
        us_host = time_fn(lambda: solve_mwu(t, dem, cm, eps=1 * MB), n=5)
        plan = solve_mwu(t, dem, cm, eps=1 * MB)
        comm_ms = simulate(plan).completion_time * 1e3
        emit(
            f"table1/algo_jit/{size_mb}MB", us_jit,
            f"algo={us_jit / 1e3:.3f}ms comm={comm_ms:.3f}ms "
            f"ratio={us_jit / 1e3 / comm_ms:.3f}",
        )
        emit(f"table1/algo_host/{size_mb}MB", us_host,
             f"host_algo={us_host / 1e3:.3f}ms (paper: 0.032-0.048ms)")
        out[f"{size_mb}MB"] = {"jit_us": us_jit, "host_us": us_host,
                               "comm_ms": comm_ms}
    return out


def run() -> dict:
    metrics = {"table1": table1()}
    metrics.update(jit_metrics())
    metrics.update(host_speedup(n=32))
    return metrics


def smoke() -> dict:
    """Few-second variant for CI: one size, few reps, same metric keys.

    Keeps the n=32 host-speedup acceptance metric (the legacy solver runs
    once, ~0.5 s) so planner-latency regressions show up in the bench
    trajectory on every PR.
    """
    metrics = {"table1": table1(sizes=(16,), reps=5)}
    metrics.update(jit_metrics(n=8, batch=4, reps=5))
    metrics.update(host_speedup(n=32, reps=3, slow_reps=1))
    return metrics


if __name__ == "__main__":
    run()
