"""Table I: orchestration-algorithm overhead vs communication time.

Paper: 1D stencil (each rank exchanges with neighbours); Algo column is
NIMBLE's planning time (0.032-0.048 ms on their CPUs), Comm is the actual
transfer.  We time BOTH planner implementations — the faithful host
(numpy) Algorithm 1 and the jitted vectorized MWU — against the modeled
communication time for the same message sizes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import CostModel
from repro.core.fabsim import simulate
from repro.core.mcf import solve_mwu
from repro.core.planner import PlannerConfig, plan_flows
from repro.core.schedule import build_planner_tables
from repro.core.topology import Topology

from .common import emit, time_fn

MB = 1 << 20


def stencil_demands(n: int, size: float):
    D = {}
    for r in range(n):
        D[(r, (r + 1) % n)] = size
        D[(r, (r - 1) % n)] = size
    return D


def run() -> None:
    cm = CostModel()
    t = Topology(8, group_size=4)
    tables = build_planner_tables(t)
    cfg = PlannerConfig(chunk_bytes=float(MB))
    planner = jax.jit(lambda d: plan_flows(d, tables, cfg)[0])

    for size_mb in (16, 32, 64, 128, 256):
        dem = stencil_demands(8, size_mb * MB)
        Dm = np.zeros((8, 8), np.float32)
        for (s, d), v in dem.items():
            Dm[s, d] = v

        us_jit = time_fn(
            lambda: planner(jnp.asarray(Dm)).block_until_ready(), n=30
        )
        us_host = time_fn(lambda: solve_mwu(t, dem, cm, eps=1 * MB), n=5)
        plan = solve_mwu(t, dem, cm, eps=1 * MB)
        comm_ms = simulate(plan).completion_time * 1e3
        emit(
            f"table1/algo_jit/{size_mb}MB", us_jit,
            f"algo={us_jit/1e3:.3f}ms comm={comm_ms:.3f}ms "
            f"ratio={us_jit/1e3/comm_ms:.3f}",
        )
        emit(f"table1/algo_host/{size_mb}MB", us_host,
             f"host_algo={us_host/1e3:.3f}ms (paper: 0.032-0.048ms)")


if __name__ == "__main__":
    run()
