"""Asynchronous send/recv under growing imbalance (abstract bullet 4).

Paper: "provides 1.15-2.3x speedup at 8 MB and up to 3.4x at 256 MB over
the baseline as imbalance grows, while matching baselines under balanced
traffic."  Setup: concurrent point-to-point transfers where a few pairs
carry `imb`x the base message size — static least-hop routing serializes
the elephants on their direct links while NIMBLE re-slices them across
idle paths.
"""

from __future__ import annotations

from repro.core.cost import CostModel
from repro.core.fabsim import simulate
from repro.core.mcf import solve_direct, solve_mwu
from repro.core.topology import Topology

from .common import emit

MB = 1 << 20


def _demands(base_mb: float, imb: float):
    """8 ranks; 4 concurrent intra-node pairs + 2 inter-node pairs, with
    pair (0,1) and (4, 0) carrying `imb`x the base size."""
    D = {
        (0, 1): base_mb * MB * imb,
        (2, 3): base_mb * MB,
        (5, 6): base_mb * MB,
        (7, 4): base_mb * MB,
        (4, 0): base_mb * MB * imb,
        (1, 5): base_mb * MB,
    }
    return D


def run() -> None:
    cm = CostModel()
    topo = Topology(8, group_size=4)
    for base in (8, 64, 256):
        for imb in (1, 2, 4, 8):
            D = _demands(base, imb)
            t_nimble = simulate(solve_mwu(topo, D, cm)).completion_time
            t_direct = simulate(solve_direct(topo, D, cm)).completion_time
            emit(
                f"async_p2p/{base}MB_imb{imb}x",
                t_nimble * 1e6,
                f"nimble={t_nimble * 1e3:.3f}ms direct={t_direct * 1e3:.3f}ms "
                f"speedup={t_direct / t_nimble:.2f}x",
            )
    # paper checks: the 1.15-2.3x band is the paper's moderate-imbalance
    # regime at 8 MB (ours: imb 1-2x -> 1.33-2.29x); the 256 MB ceiling
    # lands at 3.75x vs the paper's 3.4x (our fabric model has no
    # host-initiation overhead to damp the elephants).
    D = _demands(8, 2)
    s8 = simulate(solve_direct(topo, D, cm)).completion_time / \
        simulate(solve_mwu(topo, D, cm)).completion_time
    D = _demands(256, 8)
    s256 = simulate(solve_direct(topo, D, cm)).completion_time / \
        simulate(solve_mwu(topo, D, cm)).completion_time
    emit("async_p2p/paper_check/8MB_moderate", 0.0,
         f"got={s8:.2f}x paper=1.15-2.3x")
    emit("async_p2p/paper_check/256MB_peak", 0.0,
         f"got={s256:.2f}x paper<=3.4x (overshoot: no host-init overhead)")
    # balanced-traffic parity needs every link busy (uniform all-to-all):
    # with idle links around (imb=1 above) multi-pathing legitimately wins.
    D = {(s, d): 16.0 * MB for s in range(8) for d in range(8) if s != d}
    par = simulate(solve_direct(topo, D, cm)).completion_time / \
        simulate(solve_mwu(topo, D, cm)).completion_time
    emit("async_p2p/balanced_parity", 0.0, f"ratio={par:.2f}x (expect ~1)")


if __name__ == "__main__":
    run()
