"""Fault drills — recovery, availability, and replan discipline (DESIGN.md §9).

Each drill compiles a declarative :class:`repro.faults.FaultScenario`
against the bench fabric and replays a trace through the runtime under it,
measuring what graceful degradation actually bought:

  * **flap** — a link flap train (down/up cycles).  With flap backoff the
    topology replan count must stay bounded (vs. one replan per event for
    the no-backoff arm), and the fabric must recover to its pre-fault
    completion within two windows of the final restore;
  * **blackout** — a full telemetry blackout across a drift-phase change.
    The estimator serves last-good demand with decaying confidence; total
    adaptive completion must stay at or below the static one-shot
    baseline, with zero crashes;
  * **tenant_crash** — a co-tenant stops heartbeating mid-run on a shared
    arbitrated fabric.  Staleness eviction must fire, and the survivor's
    tail completion must land within 2% of a fabric the crashed tenant
    never joined.  Double teardown (evict, then session close) must be a
    no-op;
  * **perturb** — stragglers + background elephant + partial telemetry
    dropout composed on one run: the loop survives, straggler inflation is
    visible in the reports, and no telemetry record is rejected.

Metrics land in ``BENCH_faults.json`` (tagged ``nimble.bench_faults/v1``)
for ``experiments/make_report.py``; :func:`validate_faults` is the
``run.py --smoke`` gate (schema + recovery/availability thresholds).
"""

from __future__ import annotations

import numpy as np

from repro.api import Session, SessionSpec
from repro.core.topology import Topology
from repro.fabric import ArbiterConfig, FabricArbiter
from repro.faults import (
    ElephantFlowSpec,
    FaultInjector,
    FaultScenario,
    LinkFlapSpec,
    StragglerSpec,
    TelemetryBlackoutSpec,
    TenantCrashSpec,
    run_drill,
)
from repro.runtime import PolicyConfig, balanced_trace, drifting_skew_trace

from .common import emit

N = 8
GROUP = 4
MB = 1 << 20


def _adaptive(topo, **kw) -> Session:
    return Session(SessionSpec(topology=topo, adaptivity="adaptive", **kw))


def flap_section(windows: int = 28, start: int = 8, cycles: int = 4) -> dict:
    """Flap train on one rail link; backoff arm vs. no-backoff arm."""
    topo = Topology(N, group_size=GROUP)
    trace = balanced_trace(N, windows)
    sched = FaultInjector(topo).compile(
        FaultScenario(
            name="flap",
            flaps=[
                LinkFlapSpec(
                    src=0, dst=GROUP, start=start, cycles=cycles,
                    down_windows=1, up_windows=1,
                )
            ],
        )
    )
    restore = max(ev.window for ev in sched.events)

    with _adaptive(topo) as sess:
        backoff = run_drill(sess, trace, sched)
    with _adaptive(topo, policy=PolicyConfig(flap_backoff_base=0)) as sess:
        storm = run_drill(sess, trace, sched)

    pre = backoff.healthy_median_s(start)
    rec = backoff.recovery_window(after=restore, threshold_s=1.5 * pre)
    topo_backoff = backoff.replans_by_reason().get("topology", 0)
    topo_storm = storm.replans_by_reason().get("topology", 0)
    avail = backoff.availability(pre)
    emit(
        f"faults/flap/W{windows}", 0.0,
        f"topo_replans={topo_backoff} (no-backoff {topo_storm}) "
        f"suppressed={len(backoff.backoff_windows)} "
        f"recovered@w{rec} (restore@w{restore}) avail={avail:.3f}",
    )
    return {
        "windows": windows,
        "digest": sched.digest(),
        "flap_events": len(sched.events),
        "restore_window": int(restore),
        "recovered_window": rec,
        "recovery_windows": (rec - restore) if rec is not None else None,
        "topology_replans_backoff": int(topo_backoff),
        "topology_replans_storm": int(topo_storm),
        "suppressed_windows": len(backoff.backoff_windows),
        "availability": float(avail),
        "prefault_completion_s": float(pre),
    }


def blackout_section(windows: int = 48, dwell: int = 12) -> dict:
    """Full telemetry blackout spanning a drift-phase change."""
    topo = Topology(N, group_size=GROUP)
    trace = drifting_skew_trace(N, windows, dwell=dwell)
    start, duration = 2 * dwell - 4, 8   # straddles the phase flip
    sched = FaultInjector(topo).compile(
        FaultScenario(
            name="blackout",
            blackouts=[TelemetryBlackoutSpec(start=start, duration=duration)],
        )
    )
    with Session(SessionSpec(topology=topo)) as static_sess:
        static = static_sess.run_trace(trace)
    with _adaptive(topo) as sess:
        drill = run_drill(sess, trace, sched)
        rt = sess.runtime
        missing = rt.estimator.missing_windows
        confidence = rt.estimator.confidence
    pre = drill.healthy_median_s(start)
    ratio = drill.total_completion_s / static.total_completion_s
    avail = drill.availability(pre)
    emit(
        f"faults/blackout/W{windows}", 0.0,
        f"adaptive/static={ratio:.3f} (target <= 1.0) "
        f"missing={missing}/{duration} conf_end={confidence:.3f} "
        f"avail={avail:.3f}",
    )
    return {
        "windows": windows,
        "digest": sched.digest(),
        "blackout_start": start,
        "blackout_windows": duration,
        "adaptive_completion_s": drill.total_completion_s,
        "static_completion_s": static.total_completion_s,
        "adaptive_static_ratio": float(ratio),
        "missing_windows": int(missing),
        "confidence_end": float(confidence),
        "availability": float(avail),
    }


def tenant_crash_section(
    windows: int = 36, dwell: int = 12, crash_at: int = 14
) -> dict:
    """Co-tenant crash on a shared fabric; staleness eviction + recovery."""
    topo = Topology(N, group_size=GROUP)
    trace = drifting_skew_trace(N, windows, dwell=dwell)
    tail = windows - 2 * dwell   # windows after the post-crash phase flip
    acfg = ArbiterConfig(price_decay=2.0, evict_staleness=6.0)
    sched = FaultInjector(topo).compile(
        FaultScenario(
            name="tenant_crash",
            crashes=[TenantCrashSpec(tenant="B", window=crash_at)],
        )
    )

    def tail_median(reports) -> float:
        return float(np.median([r.completion_s for r in reports[-tail:]]))

    # reference: the survivor on a fabric tenant B never joined
    with Session(SessionSpec(
        topology=topo, adaptivity="arbitrated", tenant="A",
        arbiter=acfg,
    )) as solo:
        solo_reports = [solo.step(trace[w]) for w in range(windows)]
    solo_tail = tail_median(solo_reports)

    arb = FabricArbiter(topo, cfg=acfg)
    sess_a = Session(SessionSpec(
        topology=topo, adaptivity="arbitrated", tenant="A", fabric=arb,
    ))
    sess_b = Session(SessionSpec(
        topology=topo, adaptivity="arbitrated", tenant="B", fabric=arb,
    ))
    a_reports = []
    for w in range(windows):
        a_reports.append(sess_a.step(trace[w]))
        if not sched.crashed("B", w):
            sess_b.step(trace[w])
    evictions = arb.stats.evictions
    survivors = arb.tenants()
    # double teardown: the crashed session's close runs *after* the
    # arbiter already evicted it — every sub-step must be a no-op
    sess_b.close()
    sess_b.close()
    arb.state.withdraw("B")          # withdraw of an unknown tenant: no-op
    double_teardown_ok = "B" not in arb.tenants() and "A" in arb.tenants()
    sess_a.close()

    crash_tail = tail_median(a_reports)
    ratio = crash_tail / solo_tail if solo_tail > 0 else 1.0
    emit(
        f"faults/tenant_crash/W{windows}", 0.0,
        f"survivor_tail/solo_tail={ratio:.4f} (target <= 1.02) "
        f"evictions={evictions} survivors={survivors}",
    )
    return {
        "windows": windows,
        "digest": sched.digest(),
        "crash_window": crash_at,
        "evictions": int(evictions),
        "survivors": survivors,
        "survivor_tail_s": crash_tail,
        "solo_tail_s": solo_tail,
        "survivor_solo_ratio": float(ratio),
        "double_teardown_ok": bool(double_teardown_ok),
    }


def perturb_section(windows: int = 20) -> dict:
    """Stragglers + background elephant + partial dropout, composed."""
    topo = Topology(N, group_size=GROUP)
    trace = balanced_trace(N, windows)
    sched = FaultInjector(topo).compile(
        FaultScenario(
            name="perturb",
            seed=7,
            stragglers=[StragglerSpec(start=8, duration=4, inflation=3.0)],
            elephants=[
                ElephantFlowSpec(
                    src=1, dst=GROUP + 1, start=4, duration=12,
                    bytes_per_window=256.0 * MB, jitter=0.2,
                )
            ],
            blackouts=[
                TelemetryBlackoutSpec(start=6, duration=8, drop_prob=0.3)
            ],
        )
    )
    with _adaptive(topo) as sess:
        drill = run_drill(sess, trace, sched)
        rejected = sess.runtime.telemetry.rejected
        confidence = sess.runtime.estimator.confidence
    comps = drill.completions()
    straggler_ratio = float(
        np.median(comps[8:12]) / max(np.median(comps[:8]), 1e-12)
    )
    emit(
        f"faults/perturb/W{windows}", 0.0,
        f"straggler_ratio={straggler_ratio:.2f} (inflation 3.0) "
        f"rejected={rejected} conf_end={confidence:.3f}",
    )
    return {
        "windows": windows,
        "digest": sched.digest(),
        "straggler_ratio": straggler_ratio,
        "telemetry_rejected": int(rejected),
        "confidence_end": float(confidence),
        "total_completion_s": drill.total_completion_s,
    }


# -- smoke gate -------------------------------------------------------------------

def validate_faults(metrics: dict) -> None:
    """Schema + threshold gate over the fault-drill metrics (``--smoke``).

    Raises ``ValueError`` naming the first violated invariant:

      * flap: recovery within <= 2 windows of the final restore, backoff
        replan count <= cycles + 1 and strictly bounded by the no-backoff
        arm, availability >= 0.75;
      * blackout: adaptive completion <= static baseline, every blackout
        window registered as missing, availability >= 0.9;
      * tenant_crash: exactly one eviction, survivor tail within 2% of the
        never-joined reference, double teardown a no-op;
      * perturb: zero rejected telemetry records, straggler inflation
        visible in the reports.
    """
    for key in ("flap", "blackout", "tenant_crash", "perturb"):
        if key not in metrics or not isinstance(metrics[key], dict):
            raise ValueError(f"fault metrics missing section {key!r}")
    flap = metrics["flap"]
    if flap["recovery_windows"] is None or flap["recovery_windows"] > 2:
        raise ValueError(
            f"flap drill: recovery took {flap['recovery_windows']} windows "
            "after the final restore (threshold 2)"
        )
    if flap["topology_replans_backoff"] > flap["topology_replans_storm"]:
        raise ValueError(
            "flap drill: backoff arm issued more topology replans "
            f"({flap['topology_replans_backoff']}) than the no-backoff arm "
            f"({flap['topology_replans_storm']})"
        )
    if flap["topology_replans_backoff"] > flap["flap_events"] // 2 + 1:
        raise ValueError(
            f"flap drill: {flap['topology_replans_backoff']} topology "
            f"replans for {flap['flap_events']} flap events — backoff cap "
            "not holding"
        )
    if flap["availability"] < 0.75:
        raise ValueError(
            f"flap drill: availability {flap['availability']:.3f} < 0.75"
        )
    blk = metrics["blackout"]
    if blk["adaptive_static_ratio"] > 1.0:
        raise ValueError(
            "blackout drill: adaptive completion "
            f"{blk['adaptive_static_ratio']:.3f}x static — last-good "
            "fallback lost to the one-shot baseline"
        )
    if blk["missing_windows"] < blk["blackout_windows"]:
        raise ValueError(
            f"blackout drill: estimator saw {blk['missing_windows']} "
            f"missing windows of {blk['blackout_windows']} blacked out"
        )
    if blk["availability"] < 0.9:
        raise ValueError(
            f"blackout drill: availability {blk['availability']:.3f} < 0.9"
        )
    crash = metrics["tenant_crash"]
    if crash["evictions"] != 1:
        raise ValueError(
            f"tenant-crash drill: {crash['evictions']} evictions, "
            "expected exactly 1"
        )
    if crash["survivor_solo_ratio"] > 1.02:
        raise ValueError(
            "tenant-crash drill: survivor tail "
            f"{crash['survivor_solo_ratio']:.4f}x the never-joined "
            "reference (threshold 1.02)"
        )
    if not crash["double_teardown_ok"]:
        raise ValueError("tenant-crash drill: double teardown not a no-op")
    pert = metrics["perturb"]
    if pert["telemetry_rejected"] != 0:
        raise ValueError(
            f"perturb drill: {pert['telemetry_rejected']} telemetry "
            "records rejected"
        )
    if pert["straggler_ratio"] < 2.0:
        raise ValueError(
            f"perturb drill: straggler inflation {pert['straggler_ratio']:.2f}"
            "x not visible in reports (expected ~3x)"
        )


def metrics() -> dict:
    return {
        "flap": flap_section(),
        "blackout": blackout_section(),
        "tenant_crash": tenant_crash_section(),
        "perturb": perturb_section(),
    }


def run() -> dict:
    return metrics()


def smoke() -> dict:
    """CI variant — host numpy over n=8; the full drills run in seconds."""
    return metrics()


if __name__ == "__main__":
    run()
