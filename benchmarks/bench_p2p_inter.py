"""Fig. 6(b)+(d): inter-node bandwidth vs number of rails.

Paper: one NDR rail 45.1 GB/s (saturating > 32 MB); all four rails
170.0 GB/s aggregate with rail-matched relays; near-linear scaling since
the NIC is the path bottleneck.
"""

from __future__ import annotations

import dataclasses

from repro.core.cost import CostModel
from repro.core.fabsim import simulate
from repro.core.mcf import solve_direct, solve_mwu
from repro.core.topology import Topology

from .common import emit

MB = 1 << 20


def run() -> None:
    cm = CostModel()
    t = Topology(8, group_size=4)
    for size_mb in (8, 32, 128, 256):
        d = {(0, 4): size_mb * MB}
        bw_direct = simulate(solve_direct(t, d, cm)).bandwidth_gbs()
        emit(f"fig6b/1rail/{size_mb}MB", 0.0, f"{bw_direct:.1f}GB/s")
        plan = solve_mwu(t, d, cm, eps=min(1 * MB, size_mb * MB // 8))
        bw = simulate(plan).bandwidth_gbs()
        emit(f"fig6b/4rail/{size_mb}MB", 0.0,
             f"{bw:.1f}GB/s paths={plan.n_paths_used((0,4))}")
    # restrict rails by shrinking the group: 2 rails
    t2 = Topology(4, group_size=2)
    bw2 = simulate(
        solve_mwu(t2, {(0, 2): 256 * MB}, cm, eps=1 * MB)
    ).bandwidth_gbs()
    emit("fig6b/2rail/256MB", 0.0, f"{bw2:.1f}GB/s")
    # paper check
    d = {(0, 4): 256 * MB}
    bw4 = simulate(solve_mwu(t, d, cm, eps=1 * MB)).bandwidth_gbs()
    bw1 = simulate(solve_direct(t, d, cm)).bandwidth_gbs()
    emit("fig6b/paper_check/1rail", 0.0,
         f"got={bw1:.1f} paper=45.1 err={abs(bw1-45.1)/45.1*100:.1f}%")
    emit("fig6b/paper_check/4rail", 0.0,
         f"got={bw4:.1f} paper=170.0 err={abs(bw4-170.0)/170.0*100:.1f}%")
    # Fig 6d: rail-mismatched pair must still use relays to stay rail-matched
    dmis = {(0, 5): 256 * MB}   # src rail 0, dst rail 1
    plan = solve_mwu(t, dmis, cm, eps=1 * MB)
    relayed = all(f.path.n_hops > 1 for fl in plan.consolidated().values()
                  for f in fl)
    emit("fig6d/rail_mismatch_uses_relays", 0.0,
         f"all_multihop={relayed} bw={simulate(plan).bandwidth_gbs():.1f}GB/s")


if __name__ == "__main__":
    run()
