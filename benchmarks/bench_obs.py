"""Flight-recorder overhead: traced vs untraced runtime loop (DESIGN.md §11).

The observability contract has two halves, and this bench gates both:

  * **bit-identical disabled** — a run without a recorder must produce
    exactly the JSON it produced before ``repro.obs`` existed, and a run
    *with* a recorder must not change the simulation's outputs either
    (tracing observes, never steers).  Both are checked by comparing the
    full ``run_trace`` result JSON of the two arms.
  * **bounded enabled overhead** — the instrumented drift loop must stay
    within ``OVERHEAD_LIMIT`` of the untraced wall-clock.  The arms run
    alternated back-to-back; the gate takes the smaller of the
    noise-floor ratio (min-of-reps per arm) and the best paired ratio,
    so both rep-level spikes and multi-second load bursts are rejected
    as machine noise while a systematic instrumentation cost still
    shows in every estimator.

The traced arm's artifacts are validated on the way out: the exported
``nimble.trace/v1`` passes :func:`repro.obs.validate_trace` and every
swap the runtime performed has a provenance record in the audit log.

Metrics land in ``BENCH_obs.json`` (tagged ``nimble.bench_obs/v1``);
``validate_obs`` is the ``obs_overhead`` smoke gate.
"""

from __future__ import annotations

import json
import time

from repro.api import Session, SessionSpec
from repro.core.topology import Topology
from repro.obs import FlightRecorder, validate_trace
from repro.runtime import drifting_skew_trace

from .common import emit

N = 8
GROUP = 4

#: enabled-tracing wall-clock budget vs the untraced loop (ISSUE 8)
OVERHEAD_LIMIT = 1.03

#: min-of-reps per arm — the loop is host numpy, so the minimum is the
#: de-noised estimate (same convention as ``common.time_fn``'s median)
REPS = 5

#: extra alternated reps when the first estimate breaches the limit —
#: container wall-clock noise on this loop is ~±10%, far above the real
#: instrumentation cost, so a breach is retried with a deeper sample
#: before the gate calls it a regression
ESCALATION_REPS = 10


def _run_arm(topo, trace, recorder=None):
    """(result_json_str, wall_s) for one full drift run."""
    with Session(
        SessionSpec(topology=topo, adaptivity="adaptive"), recorder=recorder
    ) as sess:
        t0 = time.perf_counter()
        res = sess.run_trace(trace)
        wall = time.perf_counter() - t0
    return json.dumps(res.to_json_obj(), sort_keys=True), wall


def obs_section(windows: int = 48, dwell: int = 12) -> dict:
    topo = Topology(N, group_size=GROUP)
    trace = drifting_skew_trace(N, windows, dwell=dwell)

    # one traced run kept for artifact validation (its recorder outlives
    # the session — provenance is an audit trail, DESIGN.md §11)
    recorder = FlightRecorder()
    traced_json, _ = _run_arm(topo, trace, recorder=recorder)
    plain_json, _ = _run_arm(topo, trace)
    identical = traced_json == plain_json

    # alternate the arms so drift in machine load hits both equally
    plain_walls, traced_walls = [], []

    def _sample(reps: int) -> float:
        for _ in range(reps):
            _, w_plain = _run_arm(topo, trace)
            _, w_traced = _run_arm(topo, trace, recorder=FlightRecorder())
            plain_walls.append(w_plain)
            traced_walls.append(w_traced)
        # two estimators, gate on the smaller: the ratio of per-arm noise
        # floors (robust to spikes hitting single reps), and the best
        # back-to-back pair ratio (robust to multi-second load bursts that
        # cover the whole sampling window — the two arms of one pair run
        # ~100ms apart, so bursty machine noise cancels inside the pair,
        # while a *real* instrumentation cost shows up in every pair
        # including the best one)
        ratio_of_mins = min(traced_walls) / min(plain_walls)
        best_pair = min(t / p for t, p in zip(traced_walls, plain_walls))
        return min(ratio_of_mins, best_pair)

    overhead = _sample(REPS)
    if overhead > OVERHEAD_LIMIT:
        # deepen the sample before calling it a regression: more reps give
        # both estimators more chances to land in comparable conditions
        overhead = _sample(ESCALATION_REPS)

    info = validate_trace(recorder.export_trace())
    swaps = len(recorder.provenance.swapped())
    unswapped = sum(
        1 for p in recorder.provenance
        if not p.swapped and not p.abandoned and p.trigger != "initial"
    )
    emit(
        f"obs/overhead/W{windows}", min(traced_walls) * 1e6 / windows,
        f"overhead={overhead:.4f}x (target <={OVERHEAD_LIMIT}) "
        f"identical={identical} trace_events={info['events']} "
        f"plans={len(recorder.provenance)} swapped={swaps}",
    )
    return {
        "windows": windows,
        "overhead_ratio": float(overhead),
        "identical": bool(identical),
        "trace_events": int(info["events"]),
        "trace_spans": int(info["spans"]),
        "layers": sorted(info["cats"]),
        "plans_issued": len(recorder.provenance),
        "plans_swapped": swaps,
        "plans_pending_or_lost": unswapped,
        "wall_us_per_window_traced": min(traced_walls) * 1e6 / windows,
        "wall_us_per_window_plain": min(plain_walls) * 1e6 / windows,
    }


def validate_obs(metrics: dict) -> None:
    """The ``obs_overhead`` gate: raise on any broken observability claim."""
    m = metrics["obs"] if "obs" in metrics else metrics
    if not m["identical"]:
        raise AssertionError(
            "flight-recorded run diverged from the plain run — tracing "
            "must observe, never steer"
        )
    if m["overhead_ratio"] > OVERHEAD_LIMIT:
        raise AssertionError(
            f"tracing overhead {m['overhead_ratio']:.4f}x exceeds "
            f"{OVERHEAD_LIMIT}x"
        )
    if m["trace_events"] <= 0 or m["trace_spans"] <= 0:
        raise AssertionError("traced run exported an empty trace")
    for layer in ("runtime", "planner"):
        if layer not in m["layers"]:
            raise AssertionError(f"trace is missing the {layer!r} layer")
    if m["plans_swapped"] < 1:
        raise AssertionError("drift run swapped no plans — trace is inert")


def metrics(windows: int = 48, dwell: int = 12) -> dict:
    return obs_section(windows, dwell)


def run() -> dict:
    return metrics()


def smoke() -> dict:
    return metrics()


if __name__ == "__main__":
    run()
