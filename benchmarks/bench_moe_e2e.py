"""Fig. 8: end-to-end MoE block latency breakdown (paper §V-D testbed).

Two-node / eight-GPU expert parallelism, 8 experts, token dim 4096 bf16,
two-layer FFN with 4x expansion, top-2 gating.  Token counts {2K..64K},
hotspot ratios {0.4..0.9}.  Per configuration: dispatch / compute /
combine breakdown for NCCL (round-serialized PXN baseline) vs NIMBLE —
compute identical by construction, gains come from slimmer dispatch and
combine (paper: avg 1.13x @0.4 -> 1.26x @0.9, peak 1.35x @16K/0.9).

Token routing skew -> demand matrices; comm times from the calibrated
fabric model; compute from per-device FLOPs at the paper's H100 bf16 rate
with the max-loaded device setting the critical path (expert skew).
"""

from __future__ import annotations

import numpy as np

from repro.core.cost import CostModel
from repro.core.fabsim import simulate, simulate_nccl_rounds
from repro.core.mcf import solve_direct, solve_mwu
from repro.core.topology import Topology

from .common import emit

MB = 1 << 20
N_GPU = 8
N_EXP = 8
D_MODEL = 4096
D_FF = 4 * D_MODEL
TOP_K = 2
BYTES_TOK = D_MODEL * 2            # bf16
H100_BF16 = 800e12                 # per-GPU effective matmul rate


def route_tokens(n_tokens: int, hot: float, seed: int = 0):
    """Top-k expert assignment with a hot expert taking ``hot`` fraction."""
    rng = np.random.default_rng(seed)
    probs = np.full(N_EXP, (1 - hot) / (N_EXP - 1))
    probs[0] = hot
    e1 = rng.choice(N_EXP, size=n_tokens, p=probs)
    e2 = (e1 + 1 + rng.integers(0, N_EXP - 1, n_tokens)) % N_EXP
    return np.stack([e1, e2], 1)


def demand_matrix(assign: np.ndarray, n_tokens: int):
    """tokens are owned uniformly by GPUs; expert e lives on GPU e."""
    owner = np.arange(assign.shape[0]) % N_GPU
    D = np.zeros((N_GPU, N_GPU))
    for j in range(TOP_K):
        np.add.at(D, (owner, assign[:, j]), BYTES_TOK)
    np.fill_diagonal(D, 0)
    return D


def comm_time(D: np.ndarray, method: str, t: Topology, cm: CostModel):
    dem = {(s, d): float(D[s, d]) for s in range(N_GPU)
           for d in range(N_GPU) if D[s, d] > 0}
    if method == "nccl":
        return simulate_nccl_rounds(t, dem, cm)
    plan = solve_mwu(t, dem, cm, eps=1 * MB)
    return simulate(plan).completion_time


def run() -> None:
    cm = CostModel()
    t = Topology(N_GPU, group_size=4)
    best = 0.0
    for hot in (0.4, 0.5, 0.7, 0.9):
        speedups = []
        for n_tok in (2048, 4096, 8192, 16384, 32768, 65536):
            assign = route_tokens(n_tok, hot)
            D = demand_matrix(assign, n_tok)
            # compute: per-expert token counts -> max-loaded GPU
            per_exp = np.bincount(assign.reshape(-1), minlength=N_EXP)
            flops = per_exp.max() * 2 * 2 * D_MODEL * D_FF  # 2 layers
            t_comp = flops / H100_BF16
            t_disp_nccl = comm_time(D, "nccl", t, cm)
            t_disp_nim = comm_time(D, "nimble", t, cm)
            t_comb_nccl = comm_time(D.T, "nccl", t, cm)
            t_comb_nim = comm_time(D.T, "nimble", t, cm)
            e2e_nccl = t_disp_nccl + t_comp + t_comb_nccl
            e2e_nim = t_disp_nim + t_comp + t_comb_nim
            sp = e2e_nccl / e2e_nim
            speedups.append(sp)
            best = max(best, sp)
            emit(
                f"fig8/tok{n_tok}_hot{hot}",
                e2e_nim * 1e6,
                f"speedup={sp:.3f}x disp={t_disp_nim*1e3:.2f}ms "
                f"comp={t_comp*1e3:.2f}ms comb={t_comb_nim*1e3:.2f}ms "
                f"nccl_disp={t_disp_nccl*1e3:.2f}ms",
            )
        emit(f"fig8/avg_hot{hot}", 0.0,
             f"avg_speedup={np.mean(speedups):.3f}x")
    emit("fig8/paper_check/peak", 0.0, f"got={best:.2f}x paper=1.35x")


if __name__ == "__main__":
    run()
