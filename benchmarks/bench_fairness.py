"""Fabric-arbiter fairness: arbitrated co-planning vs independent replanning.

Five sections over a 2-group/8-device fabric (DESIGN.md §4):

  * **host_coplan** — the acceptance scenario: a skewed All-to-Allv tenant
    sharing the fabric with a pinned (direct-routed) elephant background.
    Independent planning is load-oblivious and stacks the skew tenant onto
    the elephant rails; arbitrated planning prices the committed background
    into the solve.  Reports combined fabric drain for both, plus Jain's
    index over per-tenant drain times.
  * **weights_sweep** — the same contention with the skew tenant's weight
    swept: weight scales exported prices by ``1/w``, so a heavier tenant
    discounts peers' load, claims contested rails back, and trades combined
    drain for its own — the weighted-share dial, made visible.
  * **runtime_adaptive** — an :class:`~repro.runtime.OrchestrationRuntime`
    tenant registered with the arbiter, replanning a drifting-skew trace
    against the committed background: the execution-time view (prices enter
    the jitted batch solve, replans pass the admission gate).
  * **four_tenant** — two skewed MWU tenants (different hotspots) plus two
    pinned elephants on disjoint rails, arbitrated to equilibrium.
  * **mutual_drift** — the price-staleness regression (ROADMAP "Arbiter
    price staleness under mutual drift"): two *runtime* tenants whose
    hotspots rotate out of phase, each periodically landing on rails the
    other just vacated.  The raw-ledger arbiter ("legacy" arm:
    ``price_hint_rel=0``, no decay, no re-pricing) over-avoids the peer's
    stale committed load and loses to the unpriced baseline (~0.92x
    combined drain); the calibrated recency stack (decayed ledger prices +
    swap-boundary re-pricing + the prices-moved soft deadline, the
    arbitrated-session defaults) must recover to >= 1.0x.  The ``--smoke``
    ``mutual_drift`` gate pins that threshold every PR.

Metrics land in ``BENCH_fairness.json`` (tagged ``nimble.bench_fairness/v1``)
with Jain's index and per-tenant drain times per section.  Every arbitrated
stack is wired through :class:`repro.api.Session` (DESIGN.md §5) — the
``SessionSpec`` names the tenant, weight, and adaptivity; hand-wiring the
arbiter is retired here (the facade is bit-identical, pinned by
``tests/test_session.py``).
"""

from __future__ import annotations

import collections

import numpy as np

from repro.api import Session, SessionSpec
from repro.core.cost import CostModel
from repro.core.mcf import solve_direct, solve_mwu
from repro.core.topology import Topology
from repro.fabric import jains_index
from repro.runtime import drifting_skew_trace

from .common import emit

MB = float(1 << 20)
N = 8
GROUP = 4


def _skew_demand(bytes_per_src: float = 64 * MB, hot: int = 0,
                 hot_frac: float = 0.7) -> dict:
    """Skewed All-to-Allv: ``hot_frac`` of every source's bytes to ``hot``."""
    D = {}
    for s in range(N):
        for d in range(N):
            if s != d:
                D[(s, d)] = bytes_per_src * (
                    hot_frac if d == hot else (1.0 - hot_frac) / (N - 2)
                )
    return D


def _elephant_demand(mb: float, rails=(0, 1)) -> dict:
    """Bidirectional elephants pinned rail-matched across the groups."""
    D = {}
    for r in rails:
        D[(r, r + GROUP)] = mb * MB
        D[(r + GROUP, r)] = mb * MB
    return D


def _stacked_drain(rm, *loads) -> float:
    total = np.zeros_like(rm.capacity)
    for l in loads:
        total = total + l
    return float(np.max(total / rm.capacity))


def host_coplan(bg_mb: float = 128.0) -> dict:
    """Acceptance: arbitrated beats independent on combined drain, Jain >= 0.9."""
    cm = CostModel()
    topo = Topology(N, group_size=GROUP)
    D = _skew_demand()
    bg = solve_direct(topo, _elephant_demand(bg_mb), cm)

    # independent: the skew tenant plans as if the fabric were empty
    ind = solve_mwu(topo, D, cm)
    ind_combined = _stacked_drain(ind.rm, ind.resource_bytes, bg.resource_bytes)

    spec = SessionSpec(topology=topo, cost=cm, adaptivity="arbitrated",
                       tenant="skew")
    with Session(spec) as sess:
        sess.join_static_tenant("bg", bg)
        sess.plan(D)  # priced solve; commits the tenant's load
        arb_combined = sess.fabric.combined_drain_s()
        fairness = sess.fabric.fairness_report()

    win = ind_combined / arb_combined
    emit(
        f"fairness/host_coplan/bg{bg_mb:g}MB",
        arb_combined * 1e6,
        f"independent={ind_combined * 1e3:.2f}ms "
        f"arbitrated={arb_combined * 1e3:.2f}ms win={win:.2f}x "
        f"jain={fairness['jain_index']:.3f} (targets: win>1, jain>=0.9)",
    )
    return {
        "bg_mb": bg_mb,
        "independent_combined_drain_s": ind_combined,
        "arbitrated_combined_drain_s": arb_combined,
        "win": win,
        "jain_index": fairness["jain_index"],
        "maxmin_violation": fairness["maxmin_violation"],
        "drain_s": fairness["drain_s"],
    }


def weights_sweep(bg_mb: float = 128.0, weights=(0.5, 1.0, 2.0, 4.0)) -> dict:
    """Sweep the skew tenant's weight against a fixed elephant background."""
    cm = CostModel()
    topo = Topology(N, group_size=GROUP)
    D = _skew_demand()
    bg = solve_direct(topo, _elephant_demand(bg_mb), cm)

    points = []
    for w in weights:
        spec = SessionSpec(topology=topo, cost=cm, adaptivity="arbitrated",
                           tenant="skew", weight=w)
        with Session(spec) as sess:
            sess.join_static_tenant("bg", bg)
            sess.plan(D)
            fairness = sess.fabric.fairness_report()
        points.append(
            {
                "weight": w,
                "skew_drain_s": fairness["drain_s"]["skew"],
                "combined_drain_s": fairness["combined_drain_s"],
                "jain_index": fairness["jain_index"],
            }
        )
    emit(
        f"fairness/weights_sweep/bg{bg_mb:g}MB",
        0.0,
        " ".join(
            f"w={p['weight']:g}:own={p['skew_drain_s'] * 1e3:.2f}ms"
            f"/comb={p['combined_drain_s'] * 1e3:.2f}ms"
            for p in points
        ),
    )
    return {"bg_mb": bg_mb, "points": points}


def runtime_adaptive(bg_mb: float = 192.0, windows: int = 32) -> dict:
    """Execution-time view: an arbitrated runtime vs an oblivious one."""
    topo = Topology(N, group_size=GROUP)
    trace = drifting_skew_trace(N, windows, dwell=8)
    bg = solve_direct(topo, _elephant_demand(bg_mb))
    bg_time = bg.resource_bytes / bg.rm.capacity

    def replay(arbitrated: bool):
        spec = SessionSpec(
            topology=topo,
            adaptivity="arbitrated" if arbitrated else "adaptive",
            tenant="skew",
        )
        with Session(spec) as sess:
            if arbitrated:
                sess.join_static_tenant("bg", bg)
            combined = own = 0.0
            reports = []
            for w in range(windows):
                reports.append(sess.step(trace[w]))
                t = sess.runtime.telemetry.latest(1)[0].per_resource_time
                combined += float(np.max(t + bg_time))
                own += float(t.max())
            replans = sess.runtime.stats.replans
            throttled = sess.fabric.stats.throttled if arbitrated else 0
        return combined, own, replans, throttled, reports

    ind_combined, ind_own, _, _, _ = replay(False)
    arb_combined, arb_own, replans, throttled, reports = replay(True)
    win = ind_combined / arb_combined
    bg_total = float(bg_time.max()) * windows
    jain = jains_index([arb_own, bg_total])
    # gated vs no-trigger accounting (WindowReport.trigger_reason): a
    # "gated" window fired a real trigger that the fabric gate suppressed
    gated = [r.window for r in reports if r.replan_reason == "gated"]
    gated_triggers = dict(collections.Counter(
        r.trigger_reason for r in reports if r.replan_reason == "gated"
    ))
    emit(
        f"fairness/runtime/W{windows}",
        arb_combined * 1e6,
        f"independent={ind_combined * 1e3:.1f}ms "
        f"arbitrated={arb_combined * 1e3:.1f}ms win={win:.2f}x "
        f"replans={replans} gated={throttled} "
        f"jain={jain:.3f}",
    )
    return {
        "windows": windows,
        "bg_mb": bg_mb,
        "independent_combined_drain_s": ind_combined,
        "arbitrated_combined_drain_s": arb_combined,
        "win": win,
        "replans": replans,
        "throttled": throttled,
        "gated_windows": gated,
        "gated_triggers": gated_triggers,
        "jain_index": jain,
        "drain_s": {"skew": arb_own, "bg": bg_total},
    }


def mutual_drift(windows: int = 48, dwell: int = 8) -> dict:
    """Two mutually drifting runtime tenants: legacy prices lose, recency
    wins.  Reports combined drain for the unpriced baseline, the
    raw-ledger ("legacy") arbiter, and the calibrated recency defaults."""
    from repro.fabric import ArbiterConfig

    topo = Topology(N, group_size=GROUP)
    # out-of-phase hotspot rotations over the same destination pool: each
    # tenant's drift lands on rails the other occupied one phase earlier,
    # so planning against the peer's *last* committed load means avoiding
    # where it was and colliding with where it is
    traces = {
        "a": drifting_skew_trace(
            N, windows, bytes_per_src=128 * MB, dwell=dwell,
            hot_seq=(0, 4, 1, 5), seed=1,
        ),
        "b": drifting_skew_trace(
            N, windows, bytes_per_src=128 * MB, dwell=dwell,
            hot_seq=(4, 1, 5, 0), seed=2,
        ),
    }

    def replay(mode: str) -> dict:
        knobs = {}
        if mode == "unpriced":
            knobs["adaptivity"] = "adaptive"
        else:
            knobs["adaptivity"] = "arbitrated"
            if mode == "legacy":
                # the pre-recency arbiter: raw ledger prices, no hints,
                # no swap-boundary re-pricing, no soft deadline
                knobs.update(price_decay=None, fabric_staleness=None)
        arb_cfg = (
            ArbiterConfig(price_hint_rel=0.0) if mode == "legacy" else None
        )
        sess_a = Session(SessionSpec(
            topology=topo, tenant="a", arbiter=arb_cfg, **knobs,
        ))
        join = {"fabric": sess_a.fabric} if mode != "unpriced" else {}
        sess_b = Session(SessionSpec(
            topology=topo, tenant="b",
            **{**knobs, **join, "arbiter": None},
        ))
        combined = 0.0
        own = {"a": 0.0, "b": 0.0}
        with sess_a, sess_b:
            for w in range(windows):
                times = {}
                for name, sess in (("a", sess_a), ("b", sess_b)):
                    sess.step(traces[name][w])
                    times[name] = (
                        sess.runtime.telemetry.latest(1)[0].per_resource_time
                    )
                    own[name] += float(times[name].max())
                combined += float(np.max(times["a"] + times["b"]))
            return {
                "combined_drain_s": combined,
                "drain_s": dict(own),
                "jain_index": jains_index(own.values()),
                "replans": {
                    "a": sess_a.runtime.stats.replans,
                    "b": sess_b.runtime.stats.replans,
                },
                "reprices": (
                    0 if mode == "unpriced"
                    else sess_a.fabric.stats.reprices
                ),
                "price_hints": (
                    0 if mode == "unpriced"
                    else sess_a.fabric.stats.price_hints
                ),
            }

    arms = {m: replay(m) for m in ("unpriced", "legacy", "calibrated")}
    base = arms["unpriced"]["combined_drain_s"]
    win_legacy = base / arms["legacy"]["combined_drain_s"]
    win = base / arms["calibrated"]["combined_drain_s"]
    emit(
        f"fairness/mutual_drift/W{windows}",
        arms["calibrated"]["combined_drain_s"] * 1e6,
        f"unpriced={base * 1e3:.1f}ms "
        f"legacy={win_legacy:.3f}x calibrated={win:.3f}x "
        f"reprices={arms['calibrated']['reprices']} "
        f"hints={arms['calibrated']['price_hints']} "
        f"(target: calibrated>=1.0x)",
    )
    return {
        "windows": windows,
        "dwell": dwell,
        "arms": arms,
        "win_legacy": win_legacy,
        "win": win,
    }


def validate_mutual_drift(section: dict) -> None:
    """The ``--smoke`` mutual_drift gate: schema + the >=1.0x threshold.

    Raises ``ValueError`` on a malformed section or a combined-drain
    regression — the calibrated recency defaults must never lose to the
    unpriced baseline on the mutual-drift scenario again.
    """
    if not isinstance(section, dict):
        raise ValueError(
            f"mutual_drift section is {type(section).__name__}, not dict"
        )
    for field in ("windows", "dwell", "arms", "win_legacy", "win"):
        if field not in section:
            raise ValueError(f"mutual_drift section missing field {field!r}")
    arms = section["arms"]
    for arm in ("unpriced", "legacy", "calibrated"):
        if arm not in arms:
            raise ValueError(f"mutual_drift arms missing {arm!r}")
        drain = arms[arm].get("combined_drain_s")
        if not isinstance(drain, float) or drain <= 0:
            raise ValueError(
                f"mutual_drift arm {arm!r} combined_drain_s = {drain!r} "
                "not a float > 0"
            )
    if not isinstance(section["win"], float):
        raise ValueError(f"mutual_drift win = {section['win']!r} not a float")
    if section["win"] < 1.0:
        raise ValueError(
            f"mutual-drift regression: calibrated combined-drain win "
            f"{section['win']:.4f}x < 1.0x vs the unpriced baseline"
        )


def four_tenant(bg_mb: float = 96.0) -> dict:
    """2 arbitrated skew tenants + 2 pinned elephants on disjoint rails."""
    cm = CostModel()
    topo = Topology(N, group_size=GROUP)
    demands = {
        "skew0": _skew_demand(48 * MB, hot=0),
        "skew4": _skew_demand(48 * MB, hot=4),
    }
    pinned = {
        "ele01": solve_direct(topo, _elephant_demand(bg_mb, rails=(0, 1)), cm),
        "ele23": solve_direct(topo, _elephant_demand(bg_mb, rails=(2, 3)), cm),
    }

    # independent: every tenant oblivious of every other
    ind_loads = [solve_mwu(topo, D, cm).resource_bytes for D in demands.values()]
    ind_loads += [p.resource_bytes for p in pinned.values()]
    rm = pinned["ele01"].rm
    ind_combined = _stacked_drain(rm, *ind_loads)

    # one session owns the fabric; the second MWU tenant and the pinned
    # elephants join it as plain ledger tenants, then co-plan to the
    # priced equilibrium via the fabric's arbitrate()
    spec = SessionSpec(topology=topo, cost=cm, adaptivity="arbitrated",
                       tenant="skew0")
    with Session(spec) as sess:
        arb = sess.fabric
        arb.register("skew4")
        for name, plan in pinned.items():
            sess.join_static_tenant(name, plan)
        arb.arbitrate(demands)
        arb_combined = arb.combined_drain_s()
        fairness = arb.fairness_report()
        solves = arb.stats.solves
    win = ind_combined / arb_combined
    emit(
        "fairness/four_tenant",
        arb_combined * 1e6,
        f"independent={ind_combined * 1e3:.2f}ms "
        f"arbitrated={arb_combined * 1e3:.2f}ms win={win:.2f}x "
        f"jain={fairness['jain_index']:.3f} solves={solves}",
    )
    return {
        "independent_combined_drain_s": ind_combined,
        "arbitrated_combined_drain_s": arb_combined,
        "win": win,
        "jain_index": fairness["jain_index"],
        "drain_s": fairness["drain_s"],
        "solves": solves,
    }


def metrics() -> dict:
    return {
        "host_coplan": host_coplan(),
        "weights_sweep": weights_sweep(),
        "runtime_adaptive": runtime_adaptive(),
        "four_tenant": four_tenant(),
        "mutual_drift": mutual_drift(),
    }


def run() -> dict:
    return metrics()


def smoke() -> dict:
    """CI variant — host solves at n=8 plus one 32-window runtime replay
    already land in a few seconds."""
    return metrics()


if __name__ == "__main__":
    run()
