"""Static-analysis gate: the analyzer's own verdict on src/repro (§12).

Unlike the other benches this one measures *conventions*, not wall
clock: it runs the full ``repro.analysis`` rule registry — per-file and
interprocedural — over ``src/repro`` with the committed baseline and
checks that

  * the tree is **clean** — zero live findings (suppressed and
    baselined ones are counted but do not fail the gate; the ``src/``
    baseline ships empty, so in practice only suppressions absorb
    anything);
  * ``schemas.lock.json`` is **fresh** — regenerating it from the
    current sources is a byte-level no-op, so no ``tag()`` call grew a
    key or bumped a version without going through the lock;
  * ``retrace.lock.json`` is **fresh** and the ``nimble.retrace/v1``
    trace-boundary inventory is **non-empty with zero PLAN_DEPENDENT
    sites** — a new plan-dependent trace constant (the hazard that
    defeats zero-retrace hot swap, ROADMAP item 2) flips the gate even
    if someone regenerates the lock, because the classification itself
    is the failure.

Metrics land in ``BENCH_lint.json`` (tagged ``nimble.bench_lint/v1``)
with per-rule finding counts and the retrace-inventory breakdown;
``validate_lint`` is the ``static_gate`` in ``benchmarks/run.py
--smoke``.  Injecting any violation into a scoped layer (say a
``time.time()`` in ``repro/fabric/``, or a ``program_id``-arithmetic
slot target into a Pallas kernel) flips the gate — those teeth are
pinned by ``tests/test_analysis.py`` and ``tests/test_interproc.py``.

Analyzer wall-clock is reported (``lint_wall_us``) but volatile — the
gate is the verdict, not the speed.
"""

from __future__ import annotations

import os
import time

from repro.analysis import (
    RULES,
    AnalysisEngine,
    default_baseline_path,
    default_lock_path,
    default_retrace_lock_path,
    load_baseline,
    lock_is_fresh,
    retrace_lock_is_fresh,
)
from repro.analysis.engine import build_contexts
from repro.analysis.rules import RetraceProvenanceRule, UnitsRule

from .common import emit

SRC_REPRO = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "src", "repro",
)


def lint_section() -> dict:
    t0 = time.perf_counter()
    contexts = build_contexts([SRC_REPRO], rel_to=os.path.dirname(SRC_REPRO))
    engine = AnalysisEngine(
        RULES, load_baseline(default_baseline_path())
    )
    report = engine.run(contexts, root=SRC_REPRO)
    fresh = lock_is_fresh(default_lock_path(), contexts)

    retrace_rule = next(
        r for r in engine.rules if isinstance(r, RetraceProvenanceRule)
    )
    units_rule = next(r for r in engine.rules if isinstance(r, UnitsRule))
    sites = retrace_rule.sites
    by_class: dict = {}
    for s in sites:
        by_class[s.provenance] = by_class.get(s.provenance, 0) + 1
    retrace_fresh = retrace_lock_is_fresh(
        default_retrace_lock_path(), engine.program, retrace_rule.analysis
    )
    wall_us = (time.perf_counter() - t0) * 1e6

    # per-rule live counts with stable keys, so --compare baselines diff
    # rule-by-rule instead of only on the total
    by_rule = {rule.rule_id: 0 for rule in RULES}
    by_rule["suppression"] = 0
    by_rule["baseline"] = 0
    for rule_id, n in report.counts.items():
        by_rule[rule_id] = n

    emit(
        "lint/analyze", wall_us,
        f"files={report.files} findings={len(report.findings)} "
        f"suppressed={len(report.suppressed)} "
        f"baselined={len(report.baselined)} lock_fresh={fresh} "
        f"retrace_sites={len(sites)} "
        f"plan_dependent={by_class.get('PLAN_DEPENDENT', 0)}",
    )
    return {
        "files": report.files,
        "rules": len(RULES),
        "findings": len(report.findings),
        "findings_by_rule": by_rule,
        "suppressed": len(report.suppressed),
        "baselined": len(report.baselined),
        "clean": report.clean,
        "lock_fresh": fresh,
        "retrace_lock_fresh": retrace_fresh,
        "retrace_sites": len(sites),
        "retrace_plan_dependent": by_class.get("PLAN_DEPENDENT", 0),
        "retrace_window_dependent": by_class.get("WINDOW_DEPENDENT", 0),
        "units_mixes": len(units_rule.analysis.mixes),
        "lint_wall_us": wall_us,
    }


def validate_lint(metrics: dict) -> None:
    """The ``static_gate``: clean tree + fresh locks + hazard-free
    trace-boundary inventory, or raise."""
    if not metrics["clean"]:
        raise AssertionError(
            f"static analysis found {metrics['findings']} live finding(s) "
            "over src/repro — run `python -m repro.analysis` for the list; "
            "fix them or suppress with a written reason"
        )
    if not metrics["lock_fresh"]:
        raise AssertionError(
            "schemas.lock.json is stale — emitted schema kinds/keys changed "
            "without regenerating it; run "
            "`python -m repro.analysis --write-lock` and commit the result"
        )
    if not metrics["retrace_lock_fresh"]:
        raise AssertionError(
            "retrace.lock.json is stale — the trace-boundary inventory "
            "changed; run `python -m repro.analysis --write-lock`, review "
            "the diff, and commit the result"
        )
    if metrics["retrace_sites"] <= 0:
        raise AssertionError(
            "retrace inventory is empty — trace-boundary extraction is "
            "broken, the zero-PLAN_DEPENDENT verdict is vacuous"
        )
    if metrics["retrace_plan_dependent"] != 0:
        raise AssertionError(
            f"{metrics['retrace_plan_dependent']} PLAN_DEPENDENT trace "
            "constant(s) reached a jit/scan/pallas boundary — every plan "
            "swap would retrace (ROADMAP item 2); demote them to runtime "
            "data (see `python -m repro.analysis --retrace-out -`)"
        )
    if metrics["files"] < 50:
        raise AssertionError(
            f"analyzer only saw {metrics['files']} files — src/repro "
            "discovery is broken, the clean verdict is vacuous"
        )


def smoke() -> dict:
    return lint_section()


def run() -> dict:
    return lint_section()


if __name__ == "__main__":
    m = run()
    validate_lint(m)
    print(
        f"# lint: clean={m['clean']} lock_fresh={m['lock_fresh']} "
        f"retrace_sites={m['retrace_sites']} "
        f"plan_dependent={m['retrace_plan_dependent']}"
    )
