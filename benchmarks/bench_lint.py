"""Static-analysis gate: the analyzer's own verdict on src/repro (§12).

Unlike the other benches this one measures *conventions*, not wall
clock: it runs the full ``repro.analysis`` rule registry over
``src/repro`` with the committed baseline and checks that

  * the tree is **clean** — zero live findings (suppressed and
    baselined ones are counted but do not fail the gate; the ``src/``
    baseline ships empty, so in practice only suppressions absorb
    anything);
  * ``schemas.lock.json`` is **fresh** — regenerating it from the
    current sources is a byte-level no-op, so no ``tag()`` call grew a
    key or bumped a version without going through the lock.

Metrics land in ``BENCH_lint.json`` (tagged ``nimble.bench_lint/v1``);
``validate_lint`` is the ``static_gate`` in ``benchmarks/run.py
--smoke``.  Injecting any violation into a scoped layer (say a
``time.time()`` in ``repro/fabric/``) flips ``clean`` to false and the
gate raises — that teeth check is pinned by
``tests/test_analysis.py::test_injected_violation_is_caught``.

Analyzer wall-clock is reported (``lint_wall_us``) but volatile — the
gate is the verdict, not the speed.
"""

from __future__ import annotations

import os
import time

from repro.analysis import (
    RULES,
    analyze_paths,
    default_baseline_path,
    default_lock_path,
    load_baseline,
    lock_is_fresh,
)
from repro.analysis.engine import build_contexts

from .common import emit

SRC_REPRO = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "src", "repro",
)


def lint_section() -> dict:
    t0 = time.perf_counter()
    report = analyze_paths(
        [SRC_REPRO],
        baseline=load_baseline(default_baseline_path()),
        rel_to=os.path.dirname(SRC_REPRO),
    )
    contexts = build_contexts([SRC_REPRO], rel_to=os.path.dirname(SRC_REPRO))
    fresh = lock_is_fresh(default_lock_path(), contexts)
    wall_us = (time.perf_counter() - t0) * 1e6

    emit(
        "lint/analyze", wall_us,
        f"files={report.files} findings={len(report.findings)} "
        f"suppressed={len(report.suppressed)} "
        f"baselined={len(report.baselined)} lock_fresh={fresh}",
    )
    return {
        "files": report.files,
        "rules": len(RULES),
        "findings": len(report.findings),
        "suppressed": len(report.suppressed),
        "baselined": len(report.baselined),
        "clean": report.clean,
        "lock_fresh": fresh,
        "lint_wall_us": wall_us,
    }


def validate_lint(metrics: dict) -> None:
    """The ``static_gate``: clean tree + fresh lock, or raise."""
    if not metrics["clean"]:
        raise AssertionError(
            f"static analysis found {metrics['findings']} live finding(s) "
            "over src/repro — run `python -m repro.analysis` for the list; "
            "fix them or suppress with a written reason"
        )
    if not metrics["lock_fresh"]:
        raise AssertionError(
            "schemas.lock.json is stale — emitted schema kinds/keys changed "
            "without regenerating it; run "
            "`python -m repro.analysis --write-lock` and commit the result"
        )
    if metrics["files"] < 50:
        raise AssertionError(
            f"analyzer only saw {metrics['files']} files — src/repro "
            "discovery is broken, the clean verdict is vacuous"
        )


def smoke() -> dict:
    return lint_section()


def run() -> dict:
    return lint_section()


if __name__ == "__main__":
    m = run()
    validate_lint(m)
    print(f"# lint: clean={m['clean']} lock_fresh={m['lock_fresh']}")
