"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section markers) and
writes the aggregate to benchmarks/results.csv.

  Fig 6(a,c)  bench_p2p_intra       intra-node multi-path bandwidth
  Fig 6(b,d)  bench_p2p_inter       inter-node multi-rail bandwidth
  Fig 7       bench_alltoallv_skew  skewed All-to-Allv sweep
  Fig 8       bench_moe_e2e         MoE end-to-end breakdown
  Table I     bench_algo_overhead   planner overhead vs comm time
  §V-E        bench_multitenant     background-tenant interference
  §III/V      bench_runtime_adapt   execution-time adaptation vs static/oracle
  (arbiter)   bench_fairness        multi-tenant arbitration + Jain fairness
  (faults)    bench_faults          fault drills: flap/blackout/crash recovery
  (extra)     bench_kernels         kernel micro-benches

``--smoke`` runs the planner-overhead, runtime-adaptation, fairness, and
fault-drill sections in a few seconds and writes
``BENCH_algo_overhead.json`` / ``BENCH_runtime_adapt.json`` /
``BENCH_fairness.json`` / ``BENCH_faults.json`` at the repo root, so
planner-latency, adaptation, arbitration, and robustness regressions show
up in the bench trajectory on every PR.  Three gates close the run:
``mutual_drift`` validates the fairness JSON's mutual-drift section
(schema + the >= 1.0x combined-drain threshold the calibrated
price-recency defaults must hold, ISSUE 5), ``fault_drills`` validates the
fault JSON against the recovery/availability thresholds of ISSUE 6
(flap recovery <= 2 windows with bounded replans, blackout drain >= the
static baseline, post-eviction survivor within 2% of never-joined), and
``session_api`` pushes one arbitrated two-tenant window through the
``repro.api.Session`` facade with the exported JSON validated against the
``nimble.fabric_fairness/v1`` schema (the full facade selfcheck —
including the decayed-prices check — is ``python -m repro.api.selfcheck``).
"""

from __future__ import annotations

import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(ROOT, "src")
if _SRC not in sys.path:   # benches usually run with PYTHONPATH=src already
    sys.path.insert(0, _SRC)


def _write_metrics(fname: str, metrics: dict, kind: str | None = None) -> str:
    from repro.jsonio import tag, write_json_file

    if kind is not None:
        metrics = tag(kind, metrics)
    out = os.path.join(ROOT, fname)
    write_json_file(out, metrics)
    return out


def smoke() -> None:
    from . import (
        bench_algo_overhead,
        bench_fairness,
        bench_faults,
        bench_runtime_adapt,
        common,
    )

    print("name,us_per_call,derived")
    print("# --- table1_overhead (smoke) ---")
    out = _write_metrics(
        "BENCH_algo_overhead.json", bench_algo_overhead.smoke()
    )
    print("# --- runtime_adapt (smoke) ---")
    out2 = _write_metrics(
        "BENCH_runtime_adapt.json",
        bench_runtime_adapt.smoke(),
        kind="bench_runtime_adapt",
    )
    print("# --- fairness (smoke) ---")
    fairness_metrics = bench_fairness.smoke()
    out3 = _write_metrics(
        "BENCH_fairness.json",
        fairness_metrics,
        kind="bench_fairness",
    )
    print("# --- mutual_drift gate (smoke) ---")
    # schema + threshold gate (ISSUE 5): the calibrated recency defaults
    # must keep the mutual-drift scenario at >= 1.0x combined drain vs the
    # unpriced baseline; raises on regression
    bench_fairness.validate_mutual_drift(fairness_metrics["mutual_drift"])
    md = fairness_metrics["mutual_drift"]
    print(
        f"# mutual_drift: win={md['win']:.4f}x (legacy "
        f"{md['win_legacy']:.4f}x) >= 1.0x OK"
    )
    print("# --- faults (smoke) ---")
    fault_metrics = bench_faults.smoke()
    out4 = _write_metrics(
        "BENCH_faults.json",
        fault_metrics,
        kind="bench_faults",
    )
    print("# --- fault_drills gate (smoke) ---")
    # recovery/availability thresholds (ISSUE 6); raises on regression
    bench_faults.validate_faults(fault_metrics)
    print(
        f"# fault_drills: flap recovery "
        f"{fault_metrics['flap']['recovery_windows']}w, blackout "
        f"{fault_metrics['blackout']['adaptive_static_ratio']:.3f}x static, "
        f"survivor {fault_metrics['tenant_crash']['survivor_solo_ratio']:.4f}"
        "x solo OK"
    )
    print("# --- session_api (smoke) ---")
    from repro.api.selfcheck import smoke_session_check

    check = smoke_session_check()  # raises on schema violation
    print(f"# session_api: {check['summary']}")
    print(
        f"# wrote {len(common.ROWS)} rows; metrics -> {out}, {out2}, "
        f"{out3}, {out4}"
    )


def main() -> None:
    from . import (
        bench_algo_overhead,
        bench_alltoallv_skew,
        bench_fairness,
        bench_faults,
        bench_kernels,
        bench_moe_e2e,
        bench_multitenant,
        bench_p2p_async,
        bench_p2p_inter,
        bench_p2p_intra,
        bench_runtime_adapt,
        common,
    )

    sections = [
        ("fig6_intra", bench_p2p_intra),
        ("fig6_inter", bench_p2p_inter),
        ("async_p2p", bench_p2p_async),
        ("fig7_alltoallv", bench_alltoallv_skew),
        ("fig8_moe", bench_moe_e2e),
        ("table1_overhead", bench_algo_overhead),
        ("vE_multitenant", bench_multitenant),
        ("runtime_adapt", bench_runtime_adapt),
        ("fairness", bench_fairness),
        ("faults", bench_faults),
        ("kernels", bench_kernels),
    ]
    metric_files = {
        "runtime_adapt": ("BENCH_runtime_adapt.json", "bench_runtime_adapt"),
        "fairness": ("BENCH_fairness.json", "bench_fairness"),
        "faults": ("BENCH_faults.json", "bench_faults"),
    }
    print("name,us_per_call,derived")
    for name, mod in sections:
        print(f"# --- {name} ---")
        metrics = mod.run()
        if name in metric_files and metrics:
            fname, kind = metric_files[name]
            _write_metrics(fname, metrics, kind=kind)
    out = os.path.join(os.path.dirname(__file__), "results.csv")
    with open(out, "w") as f:
        f.write("name,us_per_call,derived\n")
        for row in common.ROWS:
            f.write(f"{row[0]},{row[1]:.3f},{row[2]}\n")
    print(f"# wrote {len(common.ROWS)} rows to {out}")


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
