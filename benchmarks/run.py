"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section markers) and
writes the aggregate to benchmarks/results.csv.

  Fig 6(a,c)  bench_p2p_intra       intra-node multi-path bandwidth
  Fig 6(b,d)  bench_p2p_inter       inter-node multi-rail bandwidth
  Fig 7       bench_alltoallv_skew  skewed All-to-Allv sweep
  Fig 8       bench_moe_e2e         MoE end-to-end breakdown
  Table I     bench_algo_overhead   planner overhead vs comm time
  §V-E        bench_multitenant     background-tenant interference
  (extra)     bench_kernels         kernel micro-benches

``--smoke`` runs only the planner-overhead section in a few seconds and
writes ``BENCH_algo_overhead.json`` at the repo root, so planner-latency
regressions show up in the bench trajectory on every PR.
"""

from __future__ import annotations

import json
import os
import sys


def smoke() -> None:
    from . import bench_algo_overhead, common

    print("name,us_per_call,derived")
    print("# --- table1_overhead (smoke) ---")
    metrics = bench_algo_overhead.smoke()
    out = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_algo_overhead.json",
    )
    with open(out, "w") as f:
        json.dump(metrics, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {len(common.ROWS)} rows; metrics -> {out}")


def main() -> None:
    from . import (
        bench_algo_overhead,
        bench_alltoallv_skew,
        bench_kernels,
        bench_moe_e2e,
        bench_multitenant,
        bench_p2p_async,
        bench_p2p_inter,
        bench_p2p_intra,
        common,
    )

    sections = [
        ("fig6_intra", bench_p2p_intra),
        ("fig6_inter", bench_p2p_inter),
        ("async_p2p", bench_p2p_async),
        ("fig7_alltoallv", bench_alltoallv_skew),
        ("fig8_moe", bench_moe_e2e),
        ("table1_overhead", bench_algo_overhead),
        ("vE_multitenant", bench_multitenant),
        ("kernels", bench_kernels),
    ]
    print("name,us_per_call,derived")
    for name, mod in sections:
        print(f"# --- {name} ---")
        mod.run()
    out = os.path.join(os.path.dirname(__file__), "results.csv")
    with open(out, "w") as f:
        f.write("name,us_per_call,derived\n")
        for row in common.ROWS:
            f.write(f"{row[0]},{row[1]:.3f},{row[2]}\n")
    print(f"# wrote {len(common.ROWS)} rows to {out}")


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
