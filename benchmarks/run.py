"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section markers) and
writes the aggregate to benchmarks/results.csv.

  Fig 6(a,c)  bench_p2p_intra       intra-node multi-path bandwidth
  Fig 6(b,d)  bench_p2p_inter       inter-node multi-rail bandwidth
  Fig 7       bench_alltoallv_skew  skewed All-to-Allv sweep
  Fig 8       bench_moe_e2e         MoE end-to-end breakdown
  Table I     bench_algo_overhead   planner overhead vs comm time
  §V-E        bench_multitenant     background-tenant interference
  §III/V      bench_runtime_adapt   execution-time adaptation vs static/oracle
  (arbiter)   bench_fairness        multi-tenant arbitration + Jain fairness
  (faults)    bench_faults          fault drills: flap/blackout/crash recovery
  (serve)     bench_serve           serving control plane: scenario SLO drills
  (lint)      bench_lint            static invariant checker verdict
  (extra)     bench_kernels         kernel micro-benches

``--smoke`` runs the planner-overhead, runtime-adaptation, fairness,
fault-drill, and serving-control-plane sections in a few seconds and
writes ``BENCH_algo_overhead.json`` / ``BENCH_runtime_adapt.json`` /
``BENCH_fairness.json`` / ``BENCH_faults.json`` / ``BENCH_serve.json`` at
the repo root, so planner-latency, adaptation, arbitration, robustness,
and serving-SLO regressions show up in the bench trajectory on every PR.
Four gates close the run: ``mutual_drift`` validates the fairness JSON's
mutual-drift section (schema + the >= 1.0x combined-drain threshold the
calibrated price-recency defaults must hold, ISSUE 5), ``fault_drills``
validates the fault JSON against the recovery/availability thresholds of
ISSUE 6 (flap recovery <= 2 windows with bounded replans, blackout drain
>= the static baseline, post-eviction survivor within 2% of
never-joined), ``serve_slo`` validates the serving scenarios of ISSUE 7
(every scenario holds its declared SLOs; steady parity >= 0.99x;
elephant_victim and flap_under_load beat static on combined drain; churn
leaves the survivor's steady state within 2% of a never-churned run),
``obs_overhead`` validates the flight-recorder contract of ISSUE 8 (a
traced drift run byte-identical to the untraced one and within 3%
wall-clock, with a valid ``nimble.trace/v1`` export — writes
``BENCH_obs.json``), ``static_gate`` runs the ``repro.analysis``
invariant checker over ``src/repro`` (ISSUE 9: zero live findings with
the shipped empty baseline, plus ``schemas.lock.json`` freshness —
writes ``BENCH_lint.json``), and ``session_api`` pushes one arbitrated
two-tenant window through the ``repro.api.Session`` facade with the
exported JSON validated against the ``nimble.fabric_fairness/v1`` schema
(the full facade selfcheck — including the serving check 6, the tracing
check 7, and the static-analysis check 8 — is
``python -m repro.api.selfcheck``).

``--compare`` re-runs the smoke benches and diffs every numeric metric
against the committed ``BENCH_*.json`` baselines, printing a per-metric
delta table and exiting nonzero when any non-wall-clock metric moved more
than ``--threshold`` (default 10%) — the pre-merge "did my change move
the benches" check.

Every ``--smoke`` run also appends one timestamped ``trajectory/`` row to
``benchmarks/results.csv`` — gate verdicts plus the headline metric from
each ``BENCH_*.json`` — so the repo-level trajectory accumulates across
PRs instead of living only in the per-run JSONs (full ``main()`` runs
rewrite the bench rows but preserve the accumulated trajectory rows).
"""

from __future__ import annotations

import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(ROOT, "src")
if _SRC not in sys.path:   # benches usually run with PYTHONPATH=src already
    sys.path.insert(0, _SRC)


def _write_metrics(fname: str, metrics: dict, kind: str | None = None) -> str:
    from repro.jsonio import tag, write_json_file

    if kind is not None:
        metrics = tag(kind, metrics)
    out = os.path.join(ROOT, fname)
    write_json_file(out, metrics)
    return out


RESULTS_CSV = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results.csv")
CSV_HEADER = "name,us_per_call,derived\n"

#: trajectory-row schema: v2 added the leading ``schema=`` token itself
#: plus the ``obs_overhead`` gate and headline (ISSUE 8); v1 rows (no
#: token) predate it and --compare treats them as unversioned
TRAJECTORY_SCHEMA = 2


def _append_trajectory_row(gates: dict, headline: dict) -> str:
    """Append one timestamped ``trajectory/`` row to benchmarks/results.csv.

    The row carries the bench schema version, the gate verdicts, and one
    headline metric per ``BENCH_*.json`` so the repo accumulates a
    cross-PR trend line that survives full ``main()`` rewrites.  The
    derived field is space-separated ``k=v`` pairs — no commas, it lives
    in a CSV cell.
    """
    import datetime

    stamp = datetime.datetime.now(datetime.timezone.utc).strftime(
        "%Y-%m-%dT%H:%M:%SZ"
    )
    verdicts = "+".join(
        f"{name}:{'pass' if ok else 'FAIL'}" for name, ok in gates.items()
    )
    parts = [f"schema=v{TRAJECTORY_SCHEMA}", f"gates={verdicts}"]
    parts += [f"{k}={v}" for k, v in headline.items()]
    derived = " ".join(parts)
    if "," in derived:
        raise ValueError(f"trajectory derived field grew a comma: {derived!r}")
    fresh = not os.path.exists(RESULTS_CSV)
    with open(RESULTS_CSV, "a") as f:
        if fresh:
            f.write(CSV_HEADER)
        f.write(f"trajectory/{stamp},0.000,{derived}\n")
    return stamp


def smoke() -> None:
    from . import (
        bench_algo_overhead,
        bench_fairness,
        bench_faults,
        bench_lint,
        bench_obs,
        bench_runtime_adapt,
        bench_serve,
        common,
    )

    gates: dict = {}
    gate_errors: list = []

    def _gate(name: str, fn) -> None:
        try:
            fn()
            gates[name] = True
        except Exception as exc:  # record, log trajectory, re-raise below
            gates[name] = False
            gate_errors.append((name, exc))

    print("name,us_per_call,derived")
    print("# --- table1_overhead (smoke) ---")
    algo_metrics = bench_algo_overhead.smoke()
    out = _write_metrics("BENCH_algo_overhead.json", algo_metrics)
    print("# --- runtime_adapt (smoke) ---")
    adapt_metrics = bench_runtime_adapt.smoke()
    out2 = _write_metrics(
        "BENCH_runtime_adapt.json",
        adapt_metrics,
        kind="bench_runtime_adapt",
    )
    print("# --- fairness (smoke) ---")
    fairness_metrics = bench_fairness.smoke()
    out3 = _write_metrics(
        "BENCH_fairness.json",
        fairness_metrics,
        kind="bench_fairness",
    )
    print("# --- mutual_drift gate (smoke) ---")
    # schema + threshold gate (ISSUE 5): the calibrated recency defaults
    # must keep the mutual-drift scenario at >= 1.0x combined drain vs the
    # unpriced baseline; raises on regression
    _gate(
        "mutual_drift",
        lambda: bench_fairness.validate_mutual_drift(
            fairness_metrics["mutual_drift"]
        ),
    )
    md = fairness_metrics["mutual_drift"]
    print(
        f"# mutual_drift: win={md['win']:.4f}x (legacy "
        f"{md['win_legacy']:.4f}x) >= 1.0x "
        f"{'OK' if gates['mutual_drift'] else 'FAIL'}"
    )
    print("# --- faults (smoke) ---")
    fault_metrics = bench_faults.smoke()
    out4 = _write_metrics(
        "BENCH_faults.json",
        fault_metrics,
        kind="bench_faults",
    )
    print("# --- fault_drills gate (smoke) ---")
    # recovery/availability thresholds (ISSUE 6); raises on regression
    _gate("fault_drills", lambda: bench_faults.validate_faults(fault_metrics))
    print(
        f"# fault_drills: flap recovery "
        f"{fault_metrics['flap']['recovery_windows']}w, blackout "
        f"{fault_metrics['blackout']['adaptive_static_ratio']:.3f}x static, "
        f"survivor {fault_metrics['tenant_crash']['survivor_solo_ratio']:.4f}"
        f"x solo {'OK' if gates['fault_drills'] else 'FAIL'}"
    )
    print("# --- serve (smoke) ---")
    serve_metrics = bench_serve.smoke()
    out5 = _write_metrics("BENCH_serve.json", serve_metrics, kind="serve")
    print("# --- serve_slo gate (smoke) ---")
    # scenario SLOs + adaptive-vs-static thresholds (ISSUE 7); raises on
    # any scenario missing its declared gates
    _gate("serve_slo", lambda: bench_serve.validate_serve(serve_metrics))
    print(
        f"# serve_slo: steady {serve_metrics['steady']['win']:.4f}x, "
        f"elephant {serve_metrics['elephant_victim']['win']:.4f}x, flap "
        f"{serve_metrics['flap_under_load']['win']:.4f}x static; churn tail "
        f"{serve_metrics['churn']['tail_ratio']:.4f}x control "
        f"{'OK' if gates['serve_slo'] else 'FAIL'}"
    )
    print("# --- obs (smoke) ---")
    obs_metrics = bench_obs.smoke()
    out6 = _write_metrics("BENCH_obs.json", obs_metrics, kind="bench_obs")
    print("# --- obs_overhead gate (smoke) ---")
    # flight-recorder contract (ISSUE 8): enabled tracing within 3% of
    # the untraced wall-clock, recorded run byte-identical to plain
    _gate("obs_overhead", lambda: bench_obs.validate_obs(obs_metrics))
    print(
        f"# obs_overhead: {obs_metrics['overhead_ratio']:.4f}x "
        f"(<= {bench_obs.OVERHEAD_LIMIT}x), "
        f"identical={obs_metrics['identical']}, "
        f"trace_events={obs_metrics['trace_events']} "
        f"{'OK' if gates['obs_overhead'] else 'FAIL'}"
    )
    print("# --- lint (smoke) ---")
    lint_metrics = bench_lint.smoke()
    out7 = _write_metrics("BENCH_lint.json", lint_metrics, kind="bench_lint")
    print("# --- static_gate (smoke) ---")
    # static invariant checker (ISSUE 9/10): zero live findings over
    # src/repro with the shipped empty baseline, fresh schemas.lock.json
    # + retrace.lock.json, and a non-empty trace-boundary inventory with
    # zero PLAN_DEPENDENT sites
    _gate("static_gate", lambda: bench_lint.validate_lint(lint_metrics))
    print(
        f"# static_gate: {lint_metrics['files']} files, "
        f"{lint_metrics['findings']} finding(s), "
        f"{lint_metrics['suppressed']} suppressed, "
        f"lock_fresh={lint_metrics['lock_fresh']}, "
        f"retrace_sites={lint_metrics['retrace_sites']}, "
        f"plan_dependent={lint_metrics['retrace_plan_dependent']} "
        f"{'OK' if gates['static_gate'] else 'FAIL'}"
    )
    print("# --- session_api (smoke) ---")
    from repro.api.selfcheck import smoke_session_check

    check: dict = {}

    def _session_gate() -> None:
        check.update(smoke_session_check())  # raises on schema violation

    _gate("session_api", _session_gate)
    print(f"# session_api: {check.get('summary', 'FAILED')}")

    headline = {
        "host_speedup": f"{algo_metrics['host_speedup']:.2f}x",
        "drift_speedup": f"{adapt_metrics['drift']['adaptive_speedup']:.3f}x",
        "mutual_drift_win": f"{md['win']:.4f}x",
        "four_tenant_jain": f"{fairness_metrics['four_tenant']['jain_index']:.4f}",
        "flap_recovery": f"{fault_metrics['flap']['recovery_windows']}w",
        "crash_survivor": (
            f"{fault_metrics['tenant_crash']['survivor_solo_ratio']:.4f}x"
        ),
        "serve_steady": f"{serve_metrics['steady']['win']:.4f}x",
        "serve_elephant": f"{serve_metrics['elephant_victim']['win']:.4f}x",
        "serve_flap": f"{serve_metrics['flap_under_load']['win']:.4f}x",
        "serve_churn_tail": f"{serve_metrics['churn']['tail_ratio']:.4f}x",
        "obs_overhead": f"{obs_metrics['overhead_ratio']:.4f}x",
        "lint": (
            f"{'clean' if lint_metrics['clean'] else 'DIRTY'}"
            f"({lint_metrics['files']}f/"
            f"{lint_metrics['retrace_sites']}s)"
        ),
    }
    stamp = _append_trajectory_row(gates, headline)
    print(f"# trajectory: appended {stamp} row to {RESULTS_CSV}")
    print(
        f"# wrote {len(common.ROWS)} rows; metrics -> {out}, {out2}, "
        f"{out3}, {out4}, {out5}, {out6}, {out7}"
    )
    if gate_errors:
        name, exc = gate_errors[0]
        raise RuntimeError(f"smoke gate {name!r} failed: {exc}") from exc


def main() -> None:
    from . import (
        bench_algo_overhead,
        bench_alltoallv_skew,
        bench_fairness,
        bench_faults,
        bench_kernels,
        bench_lint,
        bench_moe_e2e,
        bench_multitenant,
        bench_obs,
        bench_p2p_async,
        bench_p2p_inter,
        bench_p2p_intra,
        bench_runtime_adapt,
        bench_serve,
        common,
    )

    sections = [
        ("fig6_intra", bench_p2p_intra),
        ("fig6_inter", bench_p2p_inter),
        ("async_p2p", bench_p2p_async),
        ("fig7_alltoallv", bench_alltoallv_skew),
        ("fig8_moe", bench_moe_e2e),
        ("table1_overhead", bench_algo_overhead),
        ("vE_multitenant", bench_multitenant),
        ("runtime_adapt", bench_runtime_adapt),
        ("fairness", bench_fairness),
        ("faults", bench_faults),
        ("serve", bench_serve),
        ("obs", bench_obs),
        ("lint", bench_lint),
        ("kernels", bench_kernels),
    ]
    metric_files = {
        "runtime_adapt": ("BENCH_runtime_adapt.json", "bench_runtime_adapt"),
        "fairness": ("BENCH_fairness.json", "bench_fairness"),
        "faults": ("BENCH_faults.json", "bench_faults"),
        "serve": ("BENCH_serve.json", "serve"),
        "obs": ("BENCH_obs.json", "bench_obs"),
        "lint": ("BENCH_lint.json", "bench_lint"),
    }
    print("name,us_per_call,derived")
    for name, mod in sections:
        print(f"# --- {name} ---")
        metrics = mod.run()
        if name in metric_files and metrics:
            fname, kind = metric_files[name]
            _write_metrics(fname, metrics, kind=kind)
    # rewrite the bench rows but carry over the accumulated cross-PR
    # trajectory rows --smoke appends
    trajectory: list = []
    if os.path.exists(RESULTS_CSV):
        with open(RESULTS_CSV) as f:
            trajectory = [
                line for line in f if line.startswith("trajectory/")
            ]
    with open(RESULTS_CSV, "w") as f:
        f.write(CSV_HEADER)
        for row in common.ROWS:
            f.write(f"{row[0]},{row[1]:.3f},{row[2]}\n")
        f.writelines(trajectory)
    print(
        f"# wrote {len(common.ROWS)} rows to {RESULTS_CSV} "
        f"(+{len(trajectory)} trajectory rows preserved)"
    )


#: the committed per-PR bench baselines --compare diffs against
BENCH_FILES = (
    "BENCH_algo_overhead.json",
    "BENCH_runtime_adapt.json",
    "BENCH_fairness.json",
    "BENCH_faults.json",
    "BENCH_serve.json",
    "BENCH_obs.json",
    "BENCH_lint.json",
)

#: metric-path fragments whose values are wall-clock (machine-dependent)
#: — reported in the delta table but never gated
VOLATILE_FRAGMENTS = ("wall", "_us", "us_per", "overhead", "elapsed",
                      "host_speedup", "jit_trace_ms")

#: default relative-delta gate for --compare
COMPARE_THRESHOLD = 0.10


def _numeric_leaves(obj, prefix: str = ""):
    """Yield ``(dotted.path, float)`` for every numeric leaf (bools are
    config, not metrics; the schema envelope is identity, not data)."""
    if isinstance(obj, dict):
        for k in sorted(obj):
            if k == "schema":
                continue
            yield from _numeric_leaves(obj[k], f"{prefix}{k}." if prefix
                                       else f"{k}.")
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _numeric_leaves(v, f"{prefix}{i}.")
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        yield prefix.rstrip("."), float(obj)


def _is_volatile(path: str) -> bool:
    return any(frag in path for frag in VOLATILE_FRAGMENTS)


def compare(threshold: float = COMPARE_THRESHOLD) -> int:
    """Re-run the smoke benches and diff against the committed baselines.

    Loads the repo-root ``BENCH_*.json`` snapshots *before* the rerun
    overwrites them, then prints a per-metric delta table (relative
    change against the committed value).  Non-volatile metrics whose
    relative delta exceeds ``threshold`` are regressions: each is named,
    and the exit status is nonzero if any exist.  Wall-clock metrics
    (``*_us``, ``*wall*``, ``overhead``, ``host_speedup``,
    ``jit_trace_ms`` — anything derived from machine timing) are shown
    for context but never gated — they measure the machine, not the code.
    """
    import json

    baselines: dict = {}
    for fname in BENCH_FILES:
        path = os.path.join(ROOT, fname)
        if os.path.exists(path):
            with open(path) as f:
                baselines[fname] = dict(_numeric_leaves(json.load(f)))
    if not baselines:
        print("# --compare: no committed BENCH_*.json baselines found")
        return 2

    smoke()  # rewrites the BENCH files with this machine's numbers

    regressions: list = []
    print(f"\n# --- compare vs committed baselines "
          f"(threshold {threshold:.0%}) ---")
    print("file,metric,committed,current,delta,gated")
    for fname, base in sorted(baselines.items()):
        with open(os.path.join(ROOT, fname)) as f:
            fresh = dict(_numeric_leaves(json.load(f)))
        for path in sorted(set(base) & set(fresh)):
            old, new = base[path], fresh[path]
            if old == new:
                continue
            rel = abs(new - old) / max(abs(old), 1e-12)
            gated = not _is_volatile(path)
            flag = "gated" if gated else "volatile"
            if gated and rel > threshold:
                regressions.append((fname, path, old, new, rel))
                flag = "REGRESSION"
            print(f"{fname},{path},{old:.6g},{new:.6g},{rel:+.2%},{flag}")
        for path in sorted(set(base) - set(fresh)):
            regressions.append((fname, path, base[path], None, float("inf")))
            print(f"{fname},{path},{base[path]:.6g},MISSING,,REGRESSION")
    if regressions:
        print(f"# compare: {len(regressions)} metric(s) moved more than "
              f"{threshold:.0%} vs the committed baselines:")
        for fname, path, old, new, rel in regressions:
            print(f"#   {fname}:{path}  {old:.6g} -> "
                  f"{'MISSING' if new is None else f'{new:.6g}'}")
        return 1
    print("# compare: all gated metrics within threshold")
    return 0


def _parse_threshold(argv) -> float:
    for i, arg in enumerate(argv):
        if arg == "--threshold" and i + 1 < len(argv):
            return float(argv[i + 1])
        if arg.startswith("--threshold="):
            return float(arg.split("=", 1)[1])
    return COMPARE_THRESHOLD


if __name__ == "__main__":
    if "--compare" in sys.argv[1:]:
        sys.exit(compare(_parse_threshold(sys.argv[1:])))
    elif "--smoke" in sys.argv[1:]:
        smoke()
    else:
        main()
