"""§V-E multi-tenant interference: NIMBLE under background fabric load.

The paper argues NIMBLE complements the fabric's congestion-control layer:
by re-slicing a job's traffic over live link costs it avoids per-job
hotspotting even when *other tenants* load part of the fabric.  We model a
background tenant as elephant flows pinned (direct-routed) onto a subset of
rails, feed the live per-resource load into NIMBLE's planner (the
``prev_loads`` hysteresis input), and compare the combined fabric drain
time against load-oblivious direct routing and static striping.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import CostModel
from repro.core.mcf import solve_direct, solve_mwu, solve_static_striping
from repro.core.planner import PlannerConfig, plan_flows, plan_flows_batch
from repro.core.schedule import build_planner_tables
from repro.core.topology import Topology

from .common import emit, time_fn

MB = 1 << 20


def _drain(topo_rm, *resource_bytes) -> float:
    """Combined max drain time over resources (seconds)."""
    total = np.zeros_like(topo_rm.capacity)
    for b in resource_bytes:
        total = total + b
    return float(np.max(total / topo_rm.capacity))


def run() -> None:
    cm = CostModel()
    topo = Topology(8, group_size=4)

    # our job: skewed All-to-Allv (hotspot 0.7 onto rank 0)
    D = {}
    for s in range(8):
        for d in range(8):
            if s != d:
                D[(s, d)] = 64 * MB * (0.7 if d == 0 else 0.3 / 6)

    for bg_mb in (0, 128, 512, 1024):
        # background tenant: elephants on rails 0 and 1 (ranks 0<->4, 1<->5)
        bg_D = {(0, 4): bg_mb * MB, (4, 0): bg_mb * MB,
                (1, 5): bg_mb * MB, (5, 1): bg_mb * MB}
        bg = solve_direct(topo, bg_D, cm) if bg_mb else None
        bg_bytes = bg.resource_bytes if bg else 0.0

        plans = {
            # NIMBLE sees live load via prev_loads (x2 undoes the 0.5 EMA)
            "nimble": solve_mwu(topo, D, cm, prev_loads=2.0 * bg_bytes)
            if bg_mb else solve_mwu(topo, D, cm),
            "direct": solve_direct(topo, D, cm),
            "stripe": solve_static_striping(topo, D, cm),
        }
        times = {}
        for name, plan in plans.items():
            own = plan.resource_bytes
            if bg_mb and name == "nimble":
                # remove the EMA-carried bg bytes so only job traffic counts
                own = own - 0.5 * 2.0 * bg_bytes
            times[name] = _drain(plan.rm, own, bg_bytes) * 1e3
        emit(
            f"vE/bg{bg_mb}MB",
            times["nimble"] * 1e3,
            f"nimble={times['nimble']:.2f}ms direct={times['direct']:.2f}ms "
            f"stripe={times['stripe']:.2f}ms "
            f"speedup={times['direct'] / times['nimble']:.2f}x",
        )

    batched_planning(topo)


def batched_planning(topo: Topology, n_tenants: int = 8, reps: int = 20) -> None:
    """Plan every tenant's demand matrix in ONE jit call (incidence core).

    A co-located deployment re-plans each tenant per step; with the vmapped
    MWU all tenants share one planner dispatch over the same cached tables.
    """
    n = topo.n_devices
    tables = build_planner_tables(topo)
    cfg = PlannerConfig(chunk_bytes=float(MB))
    rng = np.random.default_rng(0)
    Ds = (rng.integers(1, 64, size=(n_tenants, n, n)) * MB).astype(np.float32)
    hot = rng.integers(0, n, size=n_tenants)
    for b in range(n_tenants):
        Ds[b, :, hot[b]] *= 8
        np.fill_diagonal(Ds[b], 0)

    single = jax.jit(lambda d: plan_flows(d, tables, cfg)[0])
    batched = jax.jit(lambda d: plan_flows_batch(d, tables, cfg)[0])
    single(jnp.asarray(Ds[0])).block_until_ready()
    batched(jnp.asarray(Ds)).block_until_ready()

    us_seq = time_fn(
        lambda: [single(jnp.asarray(Ds[b])).block_until_ready()
                 for b in range(n_tenants)],
        n=reps,
    )
    us_bat = time_fn(lambda: batched(jnp.asarray(Ds)).block_until_ready(),
                     n=reps)
    emit(
        f"vE/batched_plan/B{n_tenants}",
        us_bat,
        f"batched={us_bat / 1e3:.3f}ms sequential={us_seq / 1e3:.3f}ms "
        f"({us_seq / max(us_bat, 1e-9):.2f}x fewer-dispatch win)",
    )


if __name__ == "__main__":
    run()
