"""§V-E multi-tenant interference: NIMBLE under background fabric load.

The paper argues NIMBLE complements the fabric's congestion-control layer:
by re-slicing a job's traffic over live link costs it avoids per-job
hotspotting even when *other tenants* load part of the fabric.  We model a
background tenant as elephant flows pinned (direct-routed) onto a subset
of rails, joined to an arbitrated :class:`repro.api.Session`'s fabric
ledger; the session's ``plan()`` solves our job with the arbiter's
exported prices (``ext_loads`` — priced during the solve, excluded from
the plan's own accounting).  Combined fabric drain time is compared
against load-oblivious direct routing and static striping (both also
served by the same session, unpriced by construction).

Historical note: before the arbiter this bench injected the background
load as ``prev_loads=2.0 * bg_bytes`` — the factor 2 *undoing* the
planner's own-load EMA (``CostModel.hysteresis = 0.5``, the single place
that factor is defined) — and then subtracted the EMA-carried bytes back
out of the plan's accounting.  ``ext_loads`` replaces both halves of that
hack: external load is never EMA-folded and never accounted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Session, SessionSpec
from repro.core.cost import CostModel
from repro.core.mcf import solve_direct
from repro.core.planner import PlannerConfig, plan_flows, plan_flows_batch
from repro.core.schedule import build_planner_tables
from repro.core.topology import Topology

from .common import emit, time_fn

MB = 1 << 20


def _drain(topo_rm, *resource_bytes) -> float:
    """Combined max drain time over resources (seconds)."""
    total = np.zeros_like(topo_rm.capacity)
    for b in resource_bytes:
        total = total + b
    return float(np.max(total / topo_rm.capacity))


def run() -> None:
    cm = CostModel()
    topo = Topology(8, group_size=4)

    # our job: skewed All-to-Allv (hotspot 0.7 onto rank 0)
    D = {}
    for s in range(8):
        for d in range(8):
            if s != d:
                D[(s, d)] = 64 * MB * (0.7 if d == 0 else 0.3 / 6)

    for bg_mb in (0, 128, 512, 1024):
        # background tenant: elephants on rails 0 and 1 (ranks 0<->4, 1<->5)
        bg_D = {(0, 4): bg_mb * MB, (4, 0): bg_mb * MB,
                (1, 5): bg_mb * MB, (5, 1): bg_mb * MB}
        bg = solve_direct(topo, bg_D, cm) if bg_mb else None
        bg_bytes = bg.resource_bytes if bg else 0.0

        spec = SessionSpec(topology=topo, cost=cm, adaptivity="arbitrated",
                           tenant="job")
        with Session(spec) as sess:
            if bg_mb:
                sess.join_static_tenant("bg", bg)
            plans = {
                # NIMBLE sees live load via the fabric's exported prices
                # (None when the fabric is otherwise empty — identical
                # solve); the static baselines are unpriced by definition
                "nimble": sess.plan(D),
                "direct": sess.plan(D, mode="direct"),
                "stripe": sess.plan(D, mode="stripe"),
            }
        times = {}
        for name, plan in plans.items():
            # resource_bytes is own traffic only — ext prices are priced
            # during the solve but never folded into the accounting
            times[name] = _drain(plan.rm, plan.resource_bytes, bg_bytes) * 1e3
        emit(
            f"vE/bg{bg_mb}MB",
            times["nimble"] * 1e3,
            f"nimble={times['nimble']:.2f}ms direct={times['direct']:.2f}ms "
            f"stripe={times['stripe']:.2f}ms "
            f"speedup={times['direct'] / times['nimble']:.2f}x",
        )

    batched_planning(topo)


def batched_planning(topo: Topology, n_tenants: int = 8, reps: int = 20) -> None:
    """Plan every tenant's demand matrix in ONE jit call (incidence core).

    A co-located deployment re-plans each tenant per step; with the vmapped
    MWU all tenants share one planner dispatch over the same cached tables.
    """
    n = topo.n_devices
    tables = build_planner_tables(topo)
    cfg = PlannerConfig(chunk_bytes=float(MB))
    rng = np.random.default_rng(0)
    Ds = (rng.integers(1, 64, size=(n_tenants, n, n)) * MB).astype(np.float32)
    hot = rng.integers(0, n, size=n_tenants)
    for b in range(n_tenants):
        Ds[b, :, hot[b]] *= 8
        np.fill_diagonal(Ds[b], 0)

    single = jax.jit(lambda d: plan_flows(d, tables, cfg)[0])
    batched = jax.jit(lambda d: plan_flows_batch(d, tables, cfg)[0])
    single(jnp.asarray(Ds[0])).block_until_ready()
    batched(jnp.asarray(Ds)).block_until_ready()

    us_seq = time_fn(
        lambda: [single(jnp.asarray(Ds[b])).block_until_ready()
                 for b in range(n_tenants)],
        n=reps,
    )
    us_bat = time_fn(lambda: batched(jnp.asarray(Ds)).block_until_ready(),
                     n=reps)
    emit(
        f"vE/batched_plan/B{n_tenants}",
        us_bat,
        f"batched={us_bat / 1e3:.3f}ms sequential={us_seq / 1e3:.3f}ms "
        f"({us_seq / max(us_bat, 1e-9):.2f}x fewer-dispatch win)",
    )


if __name__ == "__main__":
    run()
