"""Kernel micro-benchmarks (interpret-mode correctness + jnp-path timing).

Wall times on CPU are NOT TPU predictions — the derived column carries the
analytic FLOPs/bytes so the roofline context is explicit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import chunked_attention
from repro.kernels.grouped_ffn.ops import grouped_ffn_scan
from repro.kernels.token_scatter.ref import token_gather_ref

from .common import emit, time_fn

RNG = np.random.default_rng(0)


def run() -> None:
    # chunked/flash attention
    B, H, Hkv, S, Dh = 1, 8, 2, 4096, 64
    q = jnp.asarray(RNG.normal(size=(B, H, S, Dh)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, Dh)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, Dh)).astype(np.float32))
    f = jax.jit(lambda q, k, v: chunked_attention(q, k, v, causal=True))
    us = time_fn(lambda: f(q, k, v).block_until_ready(), n=10)
    flops = 4 * B * H * S * S * Dh / 2
    emit("kernels/attention_4k", us, f"flops={flops:.2e}")

    # grouped ffn
    N, D, F, E = 8192, 512, 1024, 8
    x = jnp.asarray(RNG.normal(size=(N, D)).astype(np.float32) * 0.1)
    eid = jnp.asarray(RNG.integers(0, E, size=(N,)).astype(np.int32))
    wg = jnp.asarray(RNG.normal(size=(E, D, F)).astype(np.float32) * 0.02)
    wu = jnp.asarray(RNG.normal(size=(E, D, F)).astype(np.float32) * 0.02)
    wd = jnp.asarray(RNG.normal(size=(E, F, D)).astype(np.float32) * 0.02)
    g = jax.jit(lambda x, e: grouped_ffn_scan(x, e, wg, wu, wd))
    us = time_fn(lambda: g(x, eid).block_until_ready(), n=5)
    emit("kernels/grouped_ffn_8k", us, f"flops={6*N*D*F:.2e}")

    # mlstm chunkwise scan (Pallas interpret on CPU)
    from repro.kernels.mlstm_scan import mlstm_scan_ref

    B, H, S, dh = 2, 4, 512, 64
    qm = jnp.asarray(RNG.normal(size=(B, H, S, dh)).astype(np.float32) * 0.3)
    km = jnp.asarray(RNG.normal(size=(B, H, S, dh)).astype(np.float32) * 0.3)
    vm = jnp.asarray(RNG.normal(size=(B, H, S, dh)).astype(np.float32) * 0.3)
    igm = jnp.asarray(RNG.normal(size=(B, H, S)).astype(np.float32))
    lfm = jnp.asarray(
        np.log(1 / (1 + np.exp(-(RNG.normal(size=(B, H, S)) + 2))))
        .astype(np.float32))
    ms = jax.jit(lambda *a: mlstm_scan_ref(*a))
    us = time_fn(lambda: ms(qm, km, vm, igm, lfm).block_until_ready(), n=5)
    emit("kernels/mlstm_scan_512", us,
         f"state_bytes={B*H*dh*dh*4:.2e} per chunk (Pallas keeps in VMEM)")

    # token gather
    xg = jnp.asarray(RNG.normal(size=(8192, 512)).astype(np.float32))
    idx = jnp.asarray(RNG.integers(0, 8192, size=(16384,)).astype(np.int32))
    tg = jax.jit(token_gather_ref)
    us = time_fn(lambda: tg(xg, idx).block_until_ready(), n=10)
    emit("kernels/token_gather_16k", us, f"bytes={16384*512*4:.2e}")


if __name__ == "__main__":
    run()
