"""Batched serving across architecture families.

Drives the ServeEngine (prefill + autoregressive decode with per-family
caches: KV ring buffers, Mamba/xLSTM recurrent states, whisper cross-attn)
for one reduced model per family, with batched requests and greedy +
temperature sampling.  Demonstrates the serving substrate the decode input
shapes (decode_32k / long_500k) lower in the dry-run.

Run:
    PYTHONPATH=src python examples/serve_multiarch.py

With ``--adaptive``, additionally routes a drifting expert-traffic trace
through the execution-time orchestration runtime (telemetry -> estimate ->
replan -> hot swap) and reports the adaptive-vs-static completion-time
ratio — the serving-side view of DESIGN.md §3.
"""

import sys
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.registry import build_model
from repro.serve.engine import ServeEngine
from repro.sharding.context import SINGLE

FAMILIES = [
    ("smollm-135m", "dense"),
    ("granite-moe-1b-a400m", "moe"),
    ("zamba2-1.2b", "hybrid"),
    ("xlstm-125m", "ssm"),
]


def adaptive_demo():
    """Orchestration-runtime demo: serve a drifting expert-routing trace.

    Models the communication side of MoE serving under shifting request
    mix: the receive hotspot (the popular expert's device) migrates, the
    runtime's telemetry/estimator detect the drift, and plans are re-solved
    off the hot path and hot-swapped between rounds.
    """
    from repro.core.topology import Topology
    from repro.runtime import (
        OrchestrationRuntime,
        drifting_skew_trace,
        run_static,
    )

    n = 8
    topo = Topology(n, group_size=4)
    trace = drifting_skew_trace(n, windows=36, dwell=9)
    runtime = OrchestrationRuntime(topo)
    adaptive = runtime.run_trace(trace)
    static = run_static(topo, trace)
    speedup = static.total_completion_s / adaptive.total_completion_s
    agg = runtime.telemetry.aggregate()
    print(
        f"[serve] adaptive runtime: {len(trace)} windows, "
        f"{len(adaptive.replan_windows)} replans "
        f"({adaptive.replan_fraction:.0%}), "
        f"{runtime.cache_info()['hits']} cache hits, "
        f"speedup vs static plan {speedup:.2f}x, "
        f"link-util imbalance {agg['utilization_imbalance']:.2f}"
    )
    return speedup


def main(adaptive: bool = False):
    rng = np.random.default_rng(0)
    for arch, family in FAMILIES:
        cfg = get_config(arch).reduced()
        model = build_model(cfg, SINGLE)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, max_len=48)

        prompts = rng.integers(0, cfg.vocab, (4, 8)).astype(np.int32)
        t0 = time.time()
        greedy = engine.generate(prompts, n_new=16, temperature=0.0)
        sampled = engine.generate(prompts, n_new=16, temperature=0.8, seed=1)
        dt = time.time() - t0
        assert greedy.shape == (4, 16) and sampled.shape == (4, 16)
        # greedy decode is deterministic
        again = engine.generate(prompts, n_new=16, temperature=0.0)
        assert np.array_equal(greedy, again), "greedy decode not deterministic"
        print(f"[serve] {family:7s} {cfg.name:28s} "
              f"batch=4 new=16x2 in {dt:5.1f}s  "
              f"greedy[0,:6]={greedy[0, :6].tolist()}")
    print("[serve] all families served batched requests deterministically")
    if adaptive:
        adaptive_demo()


if __name__ == "__main__":
    main(adaptive="--adaptive" in sys.argv[1:])
