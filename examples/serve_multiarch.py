"""Batched serving across architecture families.

Drives the ServeEngine (prefill + autoregressive decode with per-family
caches: KV ring buffers, Mamba/xLSTM recurrent states, whisper cross-attn)
for one reduced model per family, with batched requests and greedy +
temperature sampling.  Demonstrates the serving substrate the decode input
shapes (decode_32k / long_500k) lower in the dry-run.

Run:
    PYTHONPATH=src python examples/serve_multiarch.py

With ``--adaptive``, additionally routes a drifting expert-traffic trace
through the execution-time orchestration runtime (telemetry -> estimate ->
replan -> hot swap) and reports the adaptive-vs-static completion-time
ratio — the serving-side view of DESIGN.md §3 — then re-runs the serving
tenant as a fabric-arbitrated session next to a background elephant job
and reports the arbitrated combined-drain win and Jain fairness (DESIGN.md
§4).  All stacks are built through ``repro.api.Session`` (DESIGN.md §5):
one ``SessionSpec`` field — ``adaptivity`` — selects static / adaptive /
arbitrated, replacing the runtime + arbiter + telemetry hand-wiring.
"""

import sys
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.registry import build_model
from repro.serve.engine import ServeEngine
from repro.sharding.context import SINGLE

FAMILIES = [
    ("smollm-135m", "dense"),
    ("granite-moe-1b-a400m", "moe"),
    ("zamba2-1.2b", "hybrid"),
    ("xlstm-125m", "ssm"),
]


def adaptive_demo():
    """Orchestration-runtime demo: serve a drifting expert-routing trace.

    Models the communication side of MoE serving under shifting request
    mix: the receive hotspot (the popular expert's device) migrates, the
    runtime's telemetry/estimator detect the drift, and plans are re-solved
    off the hot path and hot-swapped between rounds.  The adaptive and
    static stacks differ by one ``SessionSpec`` field.
    """
    from repro.api import Session, SessionSpec, TopologySpec
    from repro.runtime import drifting_skew_trace

    n = 8
    tspec = TopologySpec(n_devices=n, group_size=4)
    trace = drifting_skew_trace(n, windows=36, dwell=9)
    with Session(SessionSpec(topology=tspec, adaptivity="adaptive",
                             tenant="serve")) as sess:
        adaptive = sess.run_trace(trace)
        rec = sess.report()
    with Session(SessionSpec(topology=tspec)) as static_sess:
        static = static_sess.run_trace(trace)
    speedup = static.total_completion_s / adaptive.total_completion_s
    print(
        f"[serve] adaptive runtime: {len(trace)} windows, "
        f"{len(adaptive.replan_windows)} replans "
        f"({adaptive.replan_fraction:.0%}), "
        f"{rec['cache']['hits']} cache hits, "
        f"speedup vs static plan {speedup:.2f}x, "
        f"link-util imbalance "
        f"{rec['telemetry']['utilization_imbalance']:.2f}"
    )
    multitenant_demo(tspec, trace)
    return speedup


def multitenant_demo(tspec, trace):
    """Fabric-arbiter demo: the same serving tenant sharing the fabric.

    A second tenant's elephant flows (direct-routed, e.g. a legacy job the
    arbiter cannot re-plan) join the session's fabric as a static tenant;
    the serving session runs arbitrated, so its replans price the
    background in and route around it.  Reports the combined-fabric win
    over oblivious replanning plus the fairness account (DESIGN.md §4).
    """
    from repro.api import Session, SessionSpec
    from repro.core.mcf import solve_direct
    from repro.fabric import jains_index

    MB = float(1 << 20)
    bg_D = {(0, 4): 160 * MB, (4, 0): 160 * MB,
            (1, 5): 160 * MB, (5, 1): 160 * MB}
    bg = solve_direct(tspec.build(), bg_D)
    bg_time = bg.resource_bytes / bg.rm.capacity

    def replay(arbitrated):
        spec = SessionSpec(
            topology=tspec,
            adaptivity="arbitrated" if arbitrated else "adaptive",
            tenant="serve",
        )
        with Session(spec) as sess:
            if arbitrated:
                sess.join_static_tenant("bg", bg)
            combined = own = 0.0
            for w in range(len(trace)):
                sess.step(trace[w])
                t = sess.runtime.telemetry.latest(1)[0].per_resource_time
                combined += float(np.max(t + bg_time))
                own += float(t.max())
            commits = sess.fabric.stats.commits if arbitrated else 0
        return combined, own, commits

    oblivious, _, _ = replay(False)
    arbitrated, serve_drain, commits = replay(True)
    # Jain over *accumulated* per-tenant drains (the ledger only holds the
    # serving tenant's last window, so fairness_report() would compare one
    # window of serve traffic against the whole background job)
    jain = jains_index([serve_drain, float(bg_time.max()) * len(trace)])
    print(
        f"[serve] multi-tenant arbiter: combined drain "
        f"{oblivious * 1e3:.1f}ms oblivious -> {arbitrated * 1e3:.1f}ms "
        f"arbitrated ({oblivious / arbitrated:.2f}x), "
        f"Jain {jain:.3f}, "
        f"{commits} ledger commits"
    )


def main(adaptive: bool = False):
    rng = np.random.default_rng(0)
    for arch, family in FAMILIES:
        cfg = get_config(arch).reduced()
        model = build_model(cfg, SINGLE)
        params = model.init(jax.random.PRNGKey(0))
        engine = ServeEngine(model, params, max_len=48)

        prompts = rng.integers(0, cfg.vocab, (4, 8)).astype(np.int32)
        t0 = time.time()
        greedy = engine.generate(prompts, n_new=16, temperature=0.0)
        sampled = engine.generate(prompts, n_new=16, temperature=0.8, seed=1)
        dt = time.time() - t0
        assert greedy.shape == (4, 16) and sampled.shape == (4, 16)
        # greedy decode is deterministic
        again = engine.generate(prompts, n_new=16, temperature=0.0)
        assert np.array_equal(greedy, again), "greedy decode not deterministic"
        print(f"[serve] {family:7s} {cfg.name:28s} "
              f"batch=4 new=16x2 in {dt:5.1f}s  "
              f"greedy[0,:6]={greedy[0, :6].tolist()}")
    print("[serve] all families served batched requests deterministically")
    if adaptive:
        adaptive_demo()


if __name__ == "__main__":
    main(adaptive="--adaptive" in sys.argv[1:])
