"""Quickstart — NIMBLE's control plane in 60 seconds.

One :class:`repro.api.Session` is the whole setup: a declarative
``SessionSpec`` names the paper's testbed fabric (2 nodes x 4 GPUs, 4
rails) and the session hands out ready-wired planning for the three
routing policies compared on the calibrated fabric simulator:

  * ``direct``  — static least-hop routing (NCCL/PXN-like baseline),
  * ``stripe``  — static even multi-rail striping (UCX-like baseline),
  * ``nimble``  — the paper's execution-time multiplicative-weights MCF.

(The old hand-wired path — ``Topology`` + ``mcf.solve_*`` — still works
and produces bit-identical plans; the Session is the recommended front
door.  See DESIGN.md §5.)

Then attaches a :class:`repro.obs.FlightRecorder` to a short adaptive run
— one object captures a Perfetto-openable trace, a metrics snapshot, and
a plan-provenance audit trail (DESIGN.md §11) — instantiates one of the
assigned model architectures (reduced size) and runs a forward pass, and
closes with the static invariant checker (DESIGN.md §12) flagging a
deliberately broken fixture, the same engine that keeps ``src/repro``
clean via ``python -m repro.analysis``.

Run:
    PYTHONPATH=src python examples/quickstart.py
"""

from repro.api import Session, SessionSpec, TopologySpec
from repro.core import fabsim, mcf


def skewed_demand(n: int, total_bytes: float, hotspot: float, hot_dst: int = 0):
    """Paper Fig. 7 traffic model: each rank sends `hotspot` of its payload
    to one hot destination, the rest spread evenly."""
    d = {}
    for s in range(n):
        peers = [p for p in range(n) if p != s]
        hd = hot_dst if hot_dst != s else (hot_dst + 1) % n
        for p in peers:
            d[(s, p)] = total_bytes * (1 - hotspot) / (len(peers) - 1) \
                if p != hd else total_bytes * hotspot
    return d


def main():
    # ---- 1. control plane: plan + simulate a skewed exchange ---------------
    spec = SessionSpec(topology=TopologySpec(n_devices=8, group_size=4))
    with Session(spec) as sess:                    # 2 "nodes" x 4 "GPUs"
        topo = sess.topo
        print(f"topology: {topo.n_devices} devices, {topo.n_groups} groups, "
              f"{len(topo.links)} directed links")

        msg = 64 * 2**20                           # 64 MB per source
        print(f"\n{'hotspot':>8s} {'direct':>10s} {'stripe':>10s} "
              f"{'nimble':>10s} {'speedup':>8s}  bottleneck")
        for hot in [0.125, 0.3, 0.5, 0.7, 0.9]:
            demands = skewed_demand(8, msg, hot)
            plans = {
                mode: sess.plan(demands, mode=mode)
                for mode in ("direct", "stripe", "nimble")
            }
            res = fabsim.compare(plans)
            t = {k: r.completion_time * 1e3 for k, r in res.items()}
            speed = t["direct"] / t["nimble"]
            print(f"{hot:8.3f} {t['direct']:9.2f}ms {t['stripe']:9.2f}ms "
                  f"{t['nimble']:9.2f}ms {speed:7.2f}x  "
                  f"{res['nimble'].bottleneck_kind(plans['nimble'])}")

        # optimality: compare against the capacity-normalized congestion LB
        demands = skewed_demand(8, msg, 0.7)
        plan = sess.plan(demands)
        lb = mcf.congestion_lower_bound(topo, demands)
        z = fabsim.simulate(plan).completion_time
        print(f"\nMWU congestion vs lower bound: {z:.4f}s vs {lb:.4f}s "
              f"(gap {100 * (z / lb - 1):.1f}%)")

    # ---- 2. flight recorder: trace one adaptive run (DESIGN.md §11) --------
    from repro.obs import FlightRecorder, validate_trace
    from repro.runtime import drifting_skew_trace

    rec = FlightRecorder()
    adaptive_spec = SessionSpec(
        topology=TopologySpec(n_devices=8, group_size=4),
        adaptivity="adaptive",
    )
    with Session(adaptive_spec, recorder=rec) as sess:
        sess.run_trace(drifting_skew_trace(8, 12, dwell=4))
    info = validate_trace(rec.export_trace())
    swapped = rec.provenance.swapped()
    print(f"\nflight recorder: {info['events']} trace events, "
          f"{info['spans']} spans, layers={info['cats']}, "
          f"corr={info['correlation_id']}; "
          f"{len(rec.provenance)} plans issued, {len(swapped)} swapped")
    # open the trace in Perfetto / chrome://tracing:
    #   write_json_file("trace.json", rec.export_trace())

    # ---- 3. model registry: one assigned arch, reduced, forward pass -------
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models.registry import build_model
    from repro.sharding.context import SINGLE

    cfg = get_config("granite-moe-1b-a400m").reduced()
    model = build_model(cfg, SINGLE)
    params = model.init(jax.random.PRNGKey(0))
    n_par = sum(x.size for x in jax.tree.leaves(params))
    toks = jnp.zeros((2, 16), jnp.int32)
    logits, _ = model.forward(params, {"tokens": toks})
    print(f"\nmodel {cfg.name}: {n_par / 1e6:.2f}M params, "
          f"logits {logits.shape}, finite={bool(jnp.isfinite(logits).all())}")

    # ---- 4. static invariant checker: lint a fixture (DESIGN.md §12) -------
    from repro.analysis import analyze_source

    fixture = (
        "import time\n"
        "def schedule(tenants):\n"
        "    return time.time()\n"        # wall-clock in a core/ path
    )
    report = analyze_source(fixture, path="repro/core/fixture.py")
    print(f"\nstatic checker: {len(report.findings)} finding(s) in a "
          "deliberately broken fixture")
    for f in report.findings:
        print(f"  {f}")
    # the committed tree must stay clean — the same engine gates the repo:
    #   PYTHONPATH=src python -m repro.analysis


if __name__ == "__main__":
    main()
