"""Skewed All-to-Allv on the REAL NIMBLE dataplane (8 forced host devices).

This is the executable counterpart of quickstart.py: instead of simulating a
plan, it runs the actual ``shard_map`` dataplane — live demand matrix ->
jittable MWU planner -> scheduled ``lax.ppermute`` rounds — and verifies the
result bit-exactly against a numpy oracle for all three modes, under a
hotspot-ratio sweep (paper Fig. 7 setup: 8 ranks = 2 nodes x 4 GPUs).
The dataplane endpoints come ready-wired from one ``repro.api.Session``
(``session.all_to_all``, DESIGN.md §5).

Because the container is CPU-only, wall-clock here is NOT bandwidth — the
projected completion times come from the planner's own link-time model
(printed alongside), which benchmarks/bench_alltoallv_skew.py validates
against the paper's 5.2x claim.

Run:
    PYTHONPATH=src python examples/skewed_alltoallv.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.api import Session, SessionSpec, TopologySpec
from repro.core import fabsim
from repro.core.dataplane import ref_all_to_allv
from repro.core.jax_compat import shard_map


def skewed_counts(n, max_chunks, hotspot, rng):
    """Per (src, dst) chunk counts with a hot destination (Fig. 7)."""
    counts = np.zeros((n, n), dtype=np.int32)
    for s in range(n):
        hd = 0 if s != 0 else 1
        budget = max_chunks
        counts[s, hd] = int(round(budget * hotspot))
        others = [d for d in range(n) if d not in (s, hd)]
        for d in others:
            counts[s, d] = int(budget * (1 - hotspot) / len(others))
    return counts


def main():
    n, C, E = 8, 32, 64               # 8 ranks, <=32 chunks/dst, 64 floats each
    mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
    rng = np.random.default_rng(0)

    spec = SessionSpec(topology=TopologySpec(n_devices=n, group_size=4))
    with Session(spec) as sess:
        for hotspot in [0.3, 0.7, 0.9]:
            counts = skewed_counts(n, C, hotspot, rng)
            x_all = rng.normal(size=(n, n, C, E)).astype(np.float32)
            for s in range(n):
                for d in range(n):
                    x_all[s, d, counts[s, d]:] = 0.0
            yref, rref = ref_all_to_allv(x_all, counts)

            print(f"\nhotspot={hotspot}")
            for mode in ["direct", "stripe", "nimble"]:
                comm = sess.all_to_all("x", max_chunks=C, chunk_bytes=E * 4,
                                       mode=mode)
                fn = shard_map(lambda x, c: comm(x, c), mesh=mesh,
                               in_specs=(P("x"), P("x")),
                               out_specs=(P("x"), P("x")))
                y, r = jax.jit(fn)(jnp.asarray(x_all.reshape(n * n, C, E)),
                                   jnp.asarray(counts.reshape(n * n)))
                ok = (np.allclose(np.asarray(y).reshape(n, n, C, E), yref)
                      and np.array_equal(np.asarray(r).reshape(n, n), rref))

                # projected completion time on the calibrated fabric
                demands = {(s, d): float(counts[s, d]) * E * 4 * 2**14
                           for s in range(n) for d in range(n)
                           if counts[s, d]}
                t = fabsim.simulate(
                    sess.plan(demands, mode=mode)
                ).completion_time
                print(f"  {mode:7s} bit-exact={'OK' if ok else 'FAIL'}   "
                      f"projected completion {t * 1e3:8.3f} ms")
                assert ok, f"dataplane {mode} mismatch"
    print("\nall modes bit-exact vs oracle")


if __name__ == "__main__":
    main()
