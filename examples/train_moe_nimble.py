"""End-to-end driver: expert-parallel MoE training with NIMBLE dispatch.

Trains a granite-family MoE LM on a (data=2, model=4) mesh of 8 forced host
devices.  The experts are sharded over the model axis; every train step's
token dispatch/combine is a skewed All-to-Allv executed by the NIMBLE
dataplane (live demand -> jittable MWU plan -> scheduled ppermute rounds).
Exactly the paper's §V-D workload, end to end in JAX.  The dispatch stack
is wired through one ``repro.api.Session`` describing the EP fabric
(``ParallelContext.session``, DESIGN.md §5) — no per-application planner
or telemetry plumbing.

Presets:
    default : ~8M params,  200 steps  — a couple of minutes on CPU
    --big   : ~100M params, 300 steps — the brief's "train ~100M for a few
              hundred steps" driver (expect ~1h on CPU; instant on a pod)

Run:
    PYTHONPATH=src python examples/train_moe_nimble.py [--big] [--mode direct]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Session, SessionSpec, TopologySpec
from repro.configs.base import get_config
from repro.core.jax_compat import set_mesh
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.registry import build_model
from repro.optim import adamw
from repro.sharding.context import ParallelContext
from repro.sharding.specs import build_param_shardings
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true", help="~100M params preset")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--mode", default="nimble",
                    choices=["nimble", "direct", "stripe"],
                    help="dispatch/combine routing mode")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    base = get_config("granite-moe-1b-a400m")
    if args.big:
        cfg = dataclasses.replace(
            base, name="granite-moe-100m", n_layers=10, d_model=512,
            n_heads=8, n_kv_heads=4, d_ff=512, vocab=16384,
            n_experts=8, top_k=2,
        )
        steps = args.steps or 300
        seq = args.seq or 256
    else:
        cfg = dataclasses.replace(
            base, name="granite-moe-8m", n_layers=4, d_model=256,
            n_heads=4, n_kv_heads=2, d_ff=256, vocab=4096,
            n_experts=8, top_k=2,
        )
        steps = args.steps or 200
        seq = args.seq or 128

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    # one declarative session describes the EP fabric (4 chips = 2 "nodes"
    # x 2) and hands the model zoo ready-wired NIMBLE dispatchers
    session = Session(SessionSpec(
        topology=TopologySpec(n_devices=4, group_size=2), tenant="moe-train",
    ))
    ctx = ParallelContext(mesh=mesh, data_axes=("data",), ep_size=4,
                          group_size=2, moe_mode=args.mode, session=session)
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_par = sum(x.size for x in jax.tree.leaves(params))
    print(f"[moe-train] {cfg.name}: {n_par / 1e6:.1f}M params, "
          f"{cfg.n_experts}e top-{cfg.top_k}, mesh=(data=2, model=4), "
          f"mode={args.mode}")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=steps)
    opt = adamw.init(params)
    step_fn = make_train_step(model, opt_cfg)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=args.batch, seed=args.seed))

    with set_mesh(mesh):
        params = jax.device_put(params, build_param_shardings(params, ctx))
        jf = jax.jit(step_fn, donate_argnums=(0, 1))
        losses, t0 = [], time.time()
        for s in range(steps):
            b = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
            params, opt, m = jf(params, opt, b)
            losses.append(float(m["loss"]))
            if s % 20 == 0 or s == steps - 1:
                print(f"[moe-train] step {s:4d} loss {losses[-1]:.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} "
                      f"({time.time() - t0:.1f}s)", flush=True)

    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"[moe-train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    assert last < first, "training did not reduce loss"
    session.close()
    return losses


if __name__ == "__main__":
    main()
