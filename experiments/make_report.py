"""Regenerate the EXPERIMENTS.md roofline tables from experiments/dryrun/*.json.

    PYTHONPATH=src python experiments/make_report.py > experiments/roofline_tables.md

Also appends the execution-time orchestration section when the repo root
holds a ``BENCH_runtime_adapt.json`` (tagged ``nimble.bench_runtime_adapt``
via the shared ``repro.jsonio`` schema), the fabric-arbiter fairness
section from ``BENCH_fairness.json`` (``nimble.bench_fairness``), the
fault-drill section from ``BENCH_faults.json`` (``nimble.bench_faults``),
the serving-control-plane SLO table from ``BENCH_serve.json``
(``nimble.serve``, DESIGN.md §10), and the static-analysis verdict line
from ``BENCH_lint.json`` (``nimble.bench_lint``, DESIGN.md §12).
"""

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)


def load(pattern):
    out = {}
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun", pattern))):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_row(r):
    ro = r.get("roofline")
    if not ro:
        return None
    mx = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
    frac = ro["compute_s"] / mx if mx else 0.0
    return (
        f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3e} "
        f"| {ro['memory_s']:.3e} | {ro['collective_s']:.3e} "
        f"| {ro['dominant']} | {ro['useful_flops_ratio']:.3f} | {frac:.4f} |"
    )


def table(recs, title):
    print(f"\n### {title}\n")
    print("| arch | shape | compute (s) | memory (s) | collective (s) "
          "| dominant | 6ND/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    skips = []
    for (a, s), r in sorted(recs.items()):
        row = fmt_row(r)
        if row is None:
            skips.append((a, s, r["status"]))
            continue
        print(row)
    for a, s, st in skips:
        print(f"| {a} | {s} | — | — | — | {st} | — | — |")


def multipod_status(recs):
    print("\n### Multi-pod (2x16x16 = 512 chips) compile status\n")
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    sk = [(a, s) for (a, s), r in recs.items() if r["status"] != "ok"]
    print(f"{ok}/{len(recs)} lower+compile OK; skips: "
          + ", ".join(f"{a}x{s}" for a, s in sk))
    print("\n| arch | shape | peak bytes/device | collective (s) | dominant |")
    print("|---|---|---|---|---|")
    for (a, s), r in sorted(recs.items()):
        ro = r.get("roofline")
        if not ro:
            continue
        pk = r["bytes_per_device"]["peak"]
        print(f"| {a} | {s} | {pk:.2e} | {ro['collective_s']:.3e} "
              f"| {ro['dominant']} |")


def runtime_adapt_section():
    """Orchestration-runtime adaptation table from BENCH_runtime_adapt.json."""
    rec = _load_tagged("BENCH_runtime_adapt.json", "bench_runtime_adapt")
    if rec is None:
        return
    print("\n### Execution-time orchestration (drift / balance / fault)\n")
    d, b, l = rec["drift"], rec["balanced"], rec["linkdown"]
    print("| scenario | windows | result |")
    print("|---|---|---|")
    print(
        f"| drifting skew | {d['windows']} | adaptive {d['adaptive_speedup']:.2f}x "
        f"vs static (oracle {d['oracle_speedup']:.2f}x), "
        f"{d['replans']} replans ({d['replan_fraction']:.0%}), "
        f"{d['cache_hits']} cache hits"
        + (
            f", confidence {d['confidence_end']:.2f}, "
            f"{d['telemetry_rejected']} rejected"
            if "confidence_end" in d
            else ""
        )
        + " |"
    )
    print(
        f"| balanced | {b['windows']} | adaptive/static = "
        f"{b['balanced_ratio']:.4f}, {b['balanced_replans']} replans |"
    )
    print(
        f"| link down | {l['windows']} | fault@w{l['fail_window']}, "
        f"replacement plan in {l['recovery_windows']} window(s) |"
    )


def _load_tagged(fname, expect_kind):
    path = os.path.join(ROOT, fname)
    if not os.path.exists(path):
        return None
    try:
        from repro.jsonio import read_json_file, schema_kind
        rec = read_json_file(path)
        kind = schema_kind(rec)
    except ImportError:  # no PYTHONPATH=src; same on-disk format
        rec = json.load(open(path))
        kind = rec.get("schema", "").split(".", 1)[-1].rsplit("/", 1)[0]
    return rec if kind == expect_kind else None


def fairness_section():
    """Fabric-arbiter fairness table from BENCH_fairness.json."""
    rec = _load_tagged("BENCH_fairness.json", "bench_fairness")
    if rec is None:
        return
    print("\n### Fabric arbiter (multi-tenant congestion pricing)\n")
    h, r, f = rec["host_coplan"], rec["runtime_adaptive"], rec["four_tenant"]
    print("| scenario | combined drain (independent -> arbitrated) "
          "| win | Jain |")
    print("|---|---|---|---|")
    for name, s in (
        ("skew vs elephant (host)", h),
        (f"arbitrated runtime ({r['windows']}w)", r),
        ("four tenants", f),
    ):
        print(
            f"| {name} | {s['independent_combined_drain_s'] * 1e3:.2f}ms -> "
            f"{s['arbitrated_combined_drain_s'] * 1e3:.2f}ms "
            f"| {s['win']:.2f}x | {s['jain_index']:.3f} |"
        )
    pts = rec["weights_sweep"]["points"]
    print(
        "\nweight sweep (skew tenant): "
        + ", ".join(
            f"w={p['weight']:g}: own {p['skew_drain_s'] * 1e3:.2f}ms / "
            f"combined {p['combined_drain_s'] * 1e3:.2f}ms"
            for p in pts
        )
    )
    md = rec.get("mutual_drift")
    if md is not None:
        arms = md["arms"]
        print(
            f"\nmutual drift ({md['windows']}w, dwell {md['dwell']}): "
            f"unpriced {arms['unpriced']['combined_drain_s'] * 1e3:.1f}ms, "
            f"raw-ledger prices {md['win_legacy']:.3f}x, "
            f"calibrated recency {md['win']:.3f}x "
            f"({arms['calibrated']['reprices']} swap-boundary reprices, "
            f"{arms['calibrated']['price_hints']} hints; gate: >= 1.0x)"
        )
    # gated vs no-trigger windows (WindowReport.trigger_reason): "gated"
    # means a real trigger fired and the fabric gate suppressed it — not
    # the same as a window where nothing triggered at all
    gated = r.get("gated_windows")
    if gated is not None:
        triggers = r.get("gated_triggers") or {}
        detail = (
            " (" + ", ".join(
                f"{k} x{v}" for k, v in sorted(triggers.items())
            ) + ")"
            if triggers
            else ""
        )
        print(
            f"\narbitrated runtime: {len(gated)} gated window(s) "
            f"{detail or '(none)'} out of {r['windows']} — triggers "
            "suppressed by the admission gate, distinct from "
            "trigger-free windows"
        )


def faults_section():
    """Fault-drill table from BENCH_faults.json (DESIGN.md §9)."""
    rec = _load_tagged("BENCH_faults.json", "bench_faults")
    if rec is None:
        return
    print("\n### Fault drills (graceful degradation)\n")
    print("| drill | windows | result |")
    print("|---|---|---|")
    fl = rec["flap"]
    print(
        f"| link flap | {fl['windows']} | {fl['flap_events']} events, "
        f"{fl['topology_replans_backoff']} topology replans with backoff "
        f"(vs {fl['topology_replans_storm']} without, "
        f"{fl['suppressed_windows']} suppressed), recovered "
        f"{fl['recovery_windows']} window(s) after the final restore, "
        f"availability {fl['availability']:.2f} |"
    )
    bl = rec["blackout"]
    print(
        f"| telemetry blackout | {bl['windows']} | "
        f"{bl['blackout_windows']}-window blackout across a drift phase: "
        f"adaptive stayed {bl['adaptive_static_ratio']:.2f}x static on "
        f"last-good demand, confidence back to "
        f"{bl['confidence_end']:.2f}, availability "
        f"{bl['availability']:.2f} |"
    )
    cr = rec["tenant_crash"]
    print(
        f"| tenant crash | {cr['windows']} | crash@w{cr['crash_window']}, "
        f"{cr['evictions']} staleness eviction; survivor tail "
        f"{cr['survivor_solo_ratio']:.4f}x the never-joined reference; "
        f"double teardown "
        f"{'OK' if cr['double_teardown_ok'] else 'FAILED'} |"
    )
    pt = rec["perturb"]
    print(
        f"| straggler+elephant+dropout | {pt['windows']} | straggler "
        f"inflation {pt['straggler_ratio']:.2f}x visible, "
        f"{pt['telemetry_rejected']} telemetry records rejected |"
    )


def serve_section():
    """Serving control-plane SLO table from BENCH_serve.json (§10)."""
    rec = _load_tagged("BENCH_serve.json", "serve")
    if rec is None:
        return
    print("\n### Serving control plane (scenario SLO drills)\n")
    print("| scenario | windows | tenants | SLO | adaptive vs static "
          "| Jain | availability |")
    print("|---|---|---|---|---|---|---|")
    for name in ("steady", "elephant_victim", "flap_under_load"):
        s = rec.get(name)
        if s is None:
            continue
        rec_w = s.get("recovery_windows")
        extra = f", recovery {rec_w}w" if rec_w is not None else ""
        print(
            f"| {name} | {s['windows']} | {s['tenants']} "
            f"| {'PASS' if s['slo_pass'] else 'FAIL'} | {s['win']:.3f}x "
            f"| {s['jain']:.3f} | {s['availability']:.3f}{extra} |"
        )
    ch = rec.get("churn")
    if ch is not None:
        print(
            f"\nchurn storm ({ch['windows']}w, {ch['churned_tenants']} "
            f"scavengers, last leave w{ch['last_leave_window']}): survivor "
            f"steady-state {ch['tail_ratio']:.4f}x the never-churned "
            f"control (gate |r-1| <= 0.02), whole run "
            f"{ch['total_ratio']:.4f}x (gate <= 1.02)"
        )
    gates = rec.get("steady", {}).get("gates")
    if gates:
        print(
            "\nsteady gate values: "
            + ", ".join(
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in sorted(gates.items())
            )
        )


def obs_section():
    """Flight-recorder contract table from BENCH_obs.json (§11)."""
    rec = _load_tagged("BENCH_obs.json", "bench_obs")
    if rec is None:
        return
    print("\n### Observability (flight recorder)\n")
    print(
        f"traced drift run ({rec['windows']}w): overhead "
        f"{rec['overhead_ratio']:.4f}x the untraced loop (gate <= 1.03), "
        f"recorded arm byte-identical: {rec['identical']}; trace "
        f"{rec['trace_events']} events / {rec['trace_spans']} spans across "
        f"{', '.join(rec['layers'])}; provenance {rec['plans_issued']} "
        f"plans issued, {rec['plans_swapped']} swapped"
    )


def lint_section():
    """One-line static-analysis verdict from BENCH_lint.json (§12)."""
    rec = _load_tagged("BENCH_lint.json", "bench_lint")
    if rec is None:
        return
    print("\n### Static analysis (invariant checker)\n")
    line = (
        f"{'clean' if rec['clean'] else 'DIRTY'}: {rec['files']} files, "
        f"{rec['rules']} rules, {rec['findings']} live finding(s) "
        f"({rec['suppressed']} suppressed, {rec['baselined']} baselined), "
        f"schema lock {'fresh' if rec['lock_fresh'] else 'STALE'}"
    )
    if "retrace_sites" in rec:  # ISSUE 10 fields, absent in older records
        line += (
            f"; retrace inventory {rec['retrace_sites']} sites "
            f"({rec['retrace_plan_dependent']} plan-dependent, "
            f"{rec['retrace_window_dependent']} window-dependent), "
            f"retrace lock "
            f"{'fresh' if rec['retrace_lock_fresh'] else 'STALE'}"
        )
    print(line)


def main():
    base = load("*_16x16_nimble.json")
    opt = load("*_16x16_nimble_alt0.25_opt.json")
    mp = load("*_2x16x16_nimble.json")
    table(base, "Baseline roofline — single pod (16x16), paper-faithful "
                "defaults (alt_frac 0.5, scan FFN path captured pre-§Perf)")
    if opt:
        table(opt, "Post-§Perf roofline — single pod, optimized defaults "
                   "(dense grouped FFN, segment dataplane, chunked/assoc "
                   "xLSTM, alt_frac 0.25, last_only prefill)")
        print("\n### Baseline vs optimized, dominant term\n")
        print("| arch | shape | baseline max-term (s) | optimized (s) "
              "| speedup |")
        print("|---|---|---|---|---|")
        for key in sorted(base):
            rb, ro_ = base[key], opt.get(key)
            if not ro_ or "roofline" not in rb or "roofline" not in ro_:
                continue
            b = max(rb["roofline"][k] for k in
                    ("compute_s", "memory_s", "collective_s"))
            o = max(ro_["roofline"][k] for k in
                    ("compute_s", "memory_s", "collective_s"))
            if b <= 0:
                continue
            print(f"| {key[0]} | {key[1]} | {b:.3e} | {o:.3e} "
                  f"| {b / o:.2f}x |")
    multipod_status(mp)
    runtime_adapt_section()
    fairness_section()
    faults_section()
    serve_section()
    obs_section()
    lint_section()


if __name__ == "__main__":
    main()
