"""TinyLlama 1.1B [arXiv:2401.02385] — llama2-arch small, GQA kv=4."""
from .base import ModelConfig, register

register(ModelConfig(
    name="tinyllama-1.1b",
    arch_type="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32000,
    rope_theta=10000.0,
    window=4096,               # SWA variant for long_500k (DESIGN.md §7)
))
