"""Granite-3.0 1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base].

32 experts, top-8; small MoE — exercises EP skew at low expert counts.
"""
from .base import ModelConfig, register

register(ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    n_experts=32,
    top_k=8,
    window=4096,
))
