"""Whisper-small [arXiv:2212.04356] — enc-dec; conv/mel frontend STUBBED.

``input_specs`` provides precomputed frame embeddings [B, 1500, d] (the
conv frontend output), per the assignment carve-out.  ``long_500k`` is
skipped: a 30 s-context enc-dec has no 500k-token decode semantics
(DESIGN.md §7).
"""
from .base import ModelConfig, register

register(ModelConfig(
    name="whisper-small",
    arch_type="audio",
    n_layers=12,               # decoder layers
    n_enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    n_audio_frames=1500,
    skip_shapes=("long_500k",),
))
