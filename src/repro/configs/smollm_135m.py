"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small, kv=3."""
from .base import ModelConfig, register

register(ModelConfig(
    name="smollm-135m",
    arch_type="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    window=4096,
))
