"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B family] — GQA kv=8, QKV bias."""
from .base import ModelConfig, register

register(ModelConfig(
    name="qwen2.5-14b",
    arch_type="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    window=4096,
))
