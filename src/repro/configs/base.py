"""Config system: model / run / parallelism dataclasses + registry.

One ``configs/<arch>.py`` per assigned architecture registers its exact
published configuration (source cited in the file).  Shapes (the four
assigned input shapes) are defined here and are arch-independent.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

# --------------------------------------------------------------------------- #
# model config
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 2.0
    # ssm / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0          # hybrid: shared attn block period
    # xlstm
    slstm_every: int = 2         # alternate sLSTM / mLSTM
    mlstm_chunk: int = 0         # 0 = per-step scan; >0 = chunkwise-parallel
    #                              mLSTM (§Perf memory-term optimization)
    slstm_assoc: bool = False    # sLSTM via associative_scan (§Perf)
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None  # sliding-window size (sub-quadratic mode)
    # enc-dec (audio)
    n_enc_layers: int = 0
    n_audio_frames: int = 1500
    # vlm
    n_patches: int = 0           # image patch tokens prepended (stub frontend)
    head_dim_override: Optional[int] = None
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # which input shapes this arch supports (DESIGN.md §7 skips)
    skip_shapes: Tuple[str, ...] = ()

    @property
    def head_dim(self) -> int:
        if self.head_dim_override:
            return self.head_dim_override
        return self.d_model // self.n_heads

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                max_experts: int = 4) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims (brief: 2L, d<=512)."""
        heads = max(1, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        d = max(d_model // heads, 8) * heads
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            d_ff=max(64, d * 2) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, max_experts) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=min(self.ssm_heads, 2) if self.ssm_heads else 0,
            n_enc_layers=min(self.n_enc_layers, 2) if self.n_enc_layers else 0,
            n_audio_frames=min(self.n_audio_frames, 64),
            n_patches=min(self.n_patches, 16) if self.n_patches else 0,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            window=min(self.window, 64) if self.window else None,
            head_dim_override=None,
        )


# --------------------------------------------------------------------------- #
# input shapes (assigned)
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# --------------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------------- #

ARCH_IDS: List[str] = [
    "qwen3-moe-235b-a22b",
    "tinyllama-1.1b",
    "zamba2-1.2b",
    "internvl2-2b",
    "qwen2.5-14b",
    "llama3-8b",
    "granite-moe-1b-a400m",
    "xlstm-125m",
    "smollm-135m",
    "whisper-small",
    # the paper's own evaluation model (§V-D): 8-expert MoE block testbed
    "paper-moe-8e",
]

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        mod = arch_id.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[arch_id]


def all_configs() -> Dict[str, ModelConfig]:
    for a in ARCH_IDS:
        get_config(a)
    return dict(_REGISTRY)
