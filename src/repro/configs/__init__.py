from .base import (
    ARCH_IDS,
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    all_configs,
    get_config,
    register,
)

__all__ = ["ModelConfig", "InputShape", "INPUT_SHAPES", "ARCH_IDS",
           "get_config", "all_configs", "register"]
