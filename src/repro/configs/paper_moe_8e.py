"""The paper's own MoE evaluation block (§V-D).

Two-node, eight-GPU EP: 8 experts, token dim 4096 bf16, two-layer FFN with
4x expansion, top-2 routing — the Fig. 8 testbed reproduced as a config.
"""
from .base import ModelConfig, register

register(ModelConfig(
    name="paper-moe-8e",
    arch_type="moe",
    n_layers=1,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,                # 4x expansion
    vocab=32000,
    n_experts=8,
    top_k=2,
    window=4096,
))
