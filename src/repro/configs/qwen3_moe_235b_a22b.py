"""Qwen3-MoE 235B-A22B family [hf:Qwen/Qwen3-30B-A3B scaled per assignment].

128 experts, top-8 routing, GQA with 4 KV heads, per-expert FFN 1536.
Primary NIMBLE target: EP dispatch/combine is the paper's skewed
All-to-Allv (§V-D).
"""
from .base import ModelConfig, register

register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,                 # per-expert intermediate size
    vocab=151936,
    n_experts=128,
    top_k=8,
    head_dim_override=128,
    qkv_bias=False,
    rope_theta=1e6,
    window=4096,               # sub-quadratic variant enables long_500k
))
