"""InternVL2-2B [arXiv:2404.16821] — InternViT (stub) + InternLM2 backbone.

The vision encoder + projector is a STUB per the assignment carve-out:
``input_specs`` provides precomputed patch embeddings [B, n_patches, d].
"""
from .base import ModelConfig, register

register(ModelConfig(
    name="internvl2-2b",
    arch_type="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    n_patches=256,             # one tile of ViT patch tokens after projector
    window=4096,
))
