"""xLSTM-125M [arXiv:2405.04517] — alternating sLSTM + mLSTM blocks.

Attention-free recurrence: NIMBLE inapplicable (balanced collectives only);
built without the technique per DESIGN.md §7.  Runs long_500k natively
(O(1) state decode).
"""
from .base import ModelConfig, register

register(ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                    # blocks carry their own projection factors
    vocab=50304,
    ssm_state=64,
    ssm_heads=4,
    slstm_every=2,             # even layers sLSTM, odd mLSTM
    # §Perf A1/A2 (EXPERIMENTS.md): chunkwise-parallel mLSTM + associative-
    # scan sLSTM — 208x lower memory roofline term vs the per-step scan
    # baseline (selectable back via mlstm_chunk=0 / slstm_assoc=False).
    mlstm_chunk=64,
    slstm_assoc=True,
))
