"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 backbone + shared attention."""
from .base import ModelConfig, register

register(ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,             # shared attn block is MHA
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_heads=32,
    ssm_expand=2,
    attn_every=6,              # shared attention block invoked every 6 layers
))
