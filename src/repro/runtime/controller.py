"""Execution-time orchestration controller (DESIGN.md §3).

:class:`OrchestrationRuntime` owns the full monitor -> estimate -> replan ->
swap loop on one endpoint:

  * every window's realized traffic executes under the **active** plan's
    split ratios (``mcf.apply_plan_fractions``) — that is what a dataplane
    between replans actually does — and the resulting per-resource busy
    times feed :class:`~repro.runtime.telemetry.LinkTelemetry`;
  * the :class:`~repro.runtime.estimator.DemandEstimator` turns observed
    per-pair bytes into the next window's predicted demand;
  * the :class:`~repro.runtime.policy.ReplanPolicy` compares the active
    plan's predicted-congestion ratio against its solve-time baseline and
    decides, with hysteresis, whether to replan;
  * replans are **double-buffered**: the new plan is solved off the hot
    path (modeled as ``solve_delay_windows`` of latency) via the existing
    jitted ``planner.plan_flows_batch``, parked in the *pending* buffer,
    and swapped in **atomically at a window boundary** — never mid-round,
    so the deterministic slot -> chunk ordering contract of the dataplane
    (sender and receiver derive indices from the same replicated plan) is
    preserved by construction;
  * solved plans are cached under ``(topology fingerprint, quantized
    demand signature)``, so a returning traffic pattern (periodic tenants,
    A/B phases) swaps in a cached plan with zero solve latency;
  * topology events (:mod:`~repro.runtime.events`) rebuild the cached
    incidence tables for the degraded fabric and force an immediate
    replan, discarding any in-flight pending plan solved for the old
    capacities;
  * when bound to a :class:`~repro.fabric.FabricArbiter`
    (``register_runtime``, DESIGN.md §4), solves price in peers' committed
    load (``ext_loads``), replans pass the fabric admission gate (throttled
    decisions surface as ``replan_reason="gated"``), executed loads are
    exported to the shared ledger every window (window-stamped, so peers'
    price-recency decay can fade them), broadcast link events arrive
    through the shared bus, and a pending plan whose exported prices moved
    materially between issue and swap boundary is re-solved against live
    prices before it is allowed in (``FabricArbiter.reprice``).  Unbound
    (or solo-tenant) behavior is bit-identical to the standalone runtime.

``run_trace`` drives the loop over a ``[W, n, n]`` traffic trace as a
discrete-event simulation through ``fabsim``; ``run_static`` and
``run_oracle`` are the evaluation bookends (one-shot plan vs per-window
clairvoyant replan).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..jsonio import tag
from ..core.cost import CostModel, ResourceModel
from ..core.fabsim import simulate
from ..core.mcf import (
    PairKey,
    Plan,
    apply_plan_fractions,
    congestion_lower_bound,
    plan_from_flows,
)
from ..core.planner import PlannerConfig, plan_flows_batch, planner_provenance
from ..core.schedule import build_planner_tables
from ..core.topology import Topology
from .estimator import DemandEstimator
from .events import EventLog, LinkEvent
from .policy import ReplanDecision, ReplanPolicy
from .telemetry import LinkTelemetry


def demand_dict(D: np.ndarray) -> Dict[PairKey, float]:
    """[n, n] array -> sparse {(s, d): bytes} with zero/self pairs dropped."""
    n = D.shape[0]
    return {
        (s, d): float(D[s, d])
        for s in range(n)
        for d in range(n)
        if s != d and D[s, d] > 0
    }


# jitted batch-planner closures, memoized per (tables identity, config) so
# repeated run_static / run_oracle / controller solves on the same topology
# reuse one traced+compiled callable instead of re-tracing every call.  The
# cached tables object is pinned by the entry, keeping its id stable.
_JIT_PLANNER_CACHE: dict = {}
_JIT_PLANNER_CAP = 16


def _batch_planner(tables, pcfg: PlannerConfig, priced: bool = False):
    key = (id(tables), pcfg, priced)
    hit = _JIT_PLANNER_CACHE.get(key)
    if hit is not None and hit[0] is tables:
        # LRU: refresh recency so the hot replan-path closure survives
        del _JIT_PLANNER_CACHE[key]
        _JIT_PLANNER_CACHE[key] = hit
        return hit[1]
    import jax

    if priced:
        # arbitrated variant: external per-resource prices injected into
        # the solve (fabric arbiter), excluded from the plan's accounting
        fn = jax.jit(
            lambda d, e: plan_flows_batch(d, tables, pcfg, ext_loads=e)[0]
        )
    else:
        fn = jax.jit(lambda d: plan_flows_batch(d, tables, pcfg)[0])
    while len(_JIT_PLANNER_CACHE) >= _JIT_PLANNER_CAP:
        _JIT_PLANNER_CACHE.pop(next(iter(_JIT_PLANNER_CACHE)))
    _JIT_PLANNER_CACHE[key] = (tables, fn)
    return fn


def solve_plans_batch(
    topo: Topology,
    demands: np.ndarray,            # [B, n, n]
    cost_model: CostModel | None = None,
    planner_cfg: PlannerConfig | None = None,
    ext_loads: np.ndarray | None = None,   # [B, R] external prices or None
) -> List[Plan]:
    """Solve B demand matrices in ONE jitted ``plan_flows_batch`` call.

    ``ext_loads`` (per-entry external committed load over the ``[R]``
    real resources, e.g. ``FabricArbiter.prices_for``) is priced into the
    solve but excluded from each returned plan's accounting.  ``None``
    takes the exact unarbitrated closure — bit-identical plans.
    """
    import jax.numpy as jnp

    tables = build_planner_tables(topo, cost_model)
    pcfg = planner_cfg or PlannerConfig()
    if ext_loads is None:
        flows = np.asarray(
            _batch_planner(tables, pcfg)(
                jnp.asarray(demands, dtype=jnp.float32)
            )
        )
    else:
        # pad each price row with the trailing dummy-resource slot
        ext = np.zeros((len(demands), tables.n_resources), dtype=np.float32)
        ext[:, :-1] = np.asarray(ext_loads, dtype=np.float32)
        flows = np.asarray(
            _batch_planner(tables, pcfg, priced=True)(
                jnp.asarray(demands, dtype=jnp.float32), jnp.asarray(ext)
            )
        )
    return [
        plan_from_flows(
            topo, flows[b], demand_dict(demands[b]), cost_model,
            iterations=pcfg.n_iters,
        )
        for b in range(len(demands))
    ]


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    chunk_bytes: float = float(1 << 20)
    planner: PlannerConfig = dataclasses.field(
        default_factory=lambda: PlannerConfig(n_iters=32)
    )
    solve_delay_windows: int = 1   # replan latency before the swap boundary
    signature_levels: int = 8      # demand-signature quantization resolution
    cache_capacity: int = 64       # LRU entries in the plan cache
    telemetry_windows: int = 256   # ring-buffer capacity
    # pending-plan watchdog (DESIGN.md §9): a buffered plan older than this
    # many windows past its issue is abandoned and re-solved against live
    # state instead of swapping in stale.  Healthy pendings become ready
    # after at most solve_delay_windows + 1, so the default never fires in
    # normal operation; None disables the watchdog entirely.
    pending_deadline_windows: Optional[int] = 8


@dataclasses.dataclass
class PlanHandle:
    """One buffered plan: the routing policy plus its provenance.

    ``solved_demand`` / ``solved_prices`` record what the plan was solved
    *against*, so the swap boundary can re-price it (DESIGN.md §4.3): when
    the fabric's exported prices moved materially between issue and swap,
    the pending plan is re-solved on the same demand under live prices.
    ``repriced`` marks a handle that already went through one re-price
    round — the retry swaps at its boundary regardless, so a continuously
    drifting fabric delays a swap by at most one re-solve.
    """

    plan: Plan
    signature: tuple
    version: int
    solved_window: int
    source: str   # "initial" | "solve" | "cache" | "reprice" | "watchdog"
    baseline_ratio: float  # Z/Z* on its own solve demand, for the policy
    solved_demand: Optional[np.ndarray] = None
    solved_prices: Optional[np.ndarray] = None
    repriced: bool = False
    # flight-recorder audit record (repro.obs.PlanProvenance) when a
    # recorder is attached; None on unrecorded runs
    provenance: Optional[object] = None


@dataclasses.dataclass(frozen=True)
class WindowReport:
    window: int
    completion_s: float
    payload_bytes: float
    bandwidth_gbs: float
    bottleneck: str
    congestion_ratio: float
    plan_version: int
    plan_source: str
    swapped: bool
    replan_issued: bool
    replan_reason: str
    cache_hit: bool
    events: Tuple[str, ...]
    # the policy's raw trigger before fabric-gate rewriting: a window with
    # ``replan_reason="gated"`` keeps its underlying trigger ("congestion",
    # "staleness", "fabric") here, so report consumers can tell a gated
    # trigger from a window where no trigger fired at all
    trigger_reason: str = "none"
    # health signals surfaced from the estimator / telemetry layers
    # (DESIGN.md §11): prediction confidence after this window (decays
    # through blackouts) and the cumulative count of telemetry records
    # rejected as non-finite/negative.  Bookends (static/oracle) report
    # the healthy defaults.
    confidence: float = 1.0
    telemetry_rejected: int = 0

    def to_json_obj(self) -> dict:
        return tag("runtime_window", dataclasses.asdict(self))


@dataclasses.dataclass
class RuntimeStats:
    windows: int = 0
    replans: int = 0        # replan triggers issued (switch decisions)
    solves: int = 0         # actual MWU solves (cache misses)
    cache_hits: int = 0
    swaps: int = 0
    events: int = 0
    reprices: int = 0       # stale pendings re-solved on live prices at swap
    watchdog_abandons: int = 0   # pendings past deadline, re-solved live
    gated: int = 0          # fired triggers throttled by the fabric gate

    def to_json_obj(self) -> dict:
        return tag("runtime_stats", dataclasses.asdict(self))


@dataclasses.dataclass
class TraceResult:
    reports: List[WindowReport]
    stats: RuntimeStats

    @property
    def total_completion_s(self) -> float:
        return float(sum(r.completion_s for r in self.reports))

    @property
    def replan_windows(self) -> List[int]:
        return [r.window for r in self.reports if r.replan_issued]

    @property
    def replan_fraction(self) -> float:
        if not self.reports:
            return 0.0
        return len(self.replan_windows) / len(self.reports)

    @property
    def gated_windows(self) -> List[int]:
        """Windows whose fired trigger was throttled by the fabric gate."""
        return [r.window for r in self.reports if r.replan_reason == "gated"]

    def to_json_obj(self) -> dict:
        return tag(
            "runtime_trace",
            {
                "total_completion_s": self.total_completion_s,
                "replan_windows": self.replan_windows,
                "replan_fraction": self.replan_fraction,
                "gated_windows": self.gated_windows,
                "stats": self.stats.to_json_obj(),
                "windows": [r.to_json_obj() for r in self.reports],
            },
        )


class OrchestrationRuntime:
    """Endpoint-driven monitor -> estimate -> replan -> swap loop."""

    @classmethod
    def from_session(cls, session) -> "OrchestrationRuntime":
        """Build the runtime for a :class:`repro.api.Session`.

        Narrow construction hook (DESIGN.md §5): the session is duck-typed
        — only ``.topo``, ``.cost_model``, and ``.spec`` (with
        ``runtime_config()``, ``policy``, ``estimator``,
        ``initial_demand``) are read — so this module never imports
        ``repro.api``.  ``None`` spec fields fall through to the exact
        constructor defaults, keeping Session-built runtimes bit-identical
        to hand-wired ``OrchestrationRuntime(topo)`` stacks.
        """
        spec = session.spec
        # policy_config() folds the spec-level calibrated fabric_staleness
        # into the policy for arbitrated sessions
        pcfg = spec.policy_config()
        policy = ReplanPolicy(pcfg) if pcfg is not None else None
        estimator = (
            DemandEstimator(session.topo.n_devices, spec.estimator)
            if spec.estimator is not None
            else None
        )
        return cls(
            session.topo,
            session.cost_model,
            cfg=spec.runtime_config(),
            policy=policy,
            estimator=estimator,
            initial_demand=spec.initial_demand,
            # flight recorder (DESIGN.md §11): passed at construction so
            # the *initial* solve is traced and provenance-recorded too
            recorder=getattr(session, "_recorder", None),
            tenant_label=spec.tenant,
        )

    def __init__(
        self,
        topo: Topology,
        cost_model: CostModel | None = None,
        cfg: RuntimeConfig | None = None,
        policy: ReplanPolicy | None = None,
        estimator: DemandEstimator | None = None,
        events: EventLog | None = None,
        initial_demand: Optional[np.ndarray] = None,
        recorder=None,
        tenant_label: Optional[str] = None,
    ):
        self.topo = topo
        self.cm = cost_model or CostModel()
        self.cfg = cfg or RuntimeConfig()
        self.policy = policy or ReplanPolicy()
        self.estimator = estimator or DemandEstimator(topo.n_devices)
        # copy, matching run_trace: the caller's log stays reusable
        self.events = events.copy() if events is not None else EventLog()
        self.stats = RuntimeStats()
        self.telemetry = LinkTelemetry(
            ResourceModel(topo, self.cm).capacity,
            window_capacity=self.cfg.telemetry_windows,
        )
        self._window = 0
        self._version = 0
        self._cache: "collections.OrderedDict[tuple, Plan]" = (
            collections.OrderedDict()
        )
        self._pending: Optional[Tuple[PlanHandle, int]] = None
        # fabric-arbiter binding (FabricArbiter.register_runtime): when set,
        # solves take arbiter-exported prices, replans pass the admission
        # gate, and executed loads are committed to the shared ledger
        self._arbiter = None
        self._tenant: Optional[str] = None
        self._fabric_window_offset = 0
        # flight recorder (repro.obs, DESIGN.md §11): every hook below is
        # guarded by one ``self._obs is None`` check, so a run without a
        # recorder executes the exact pre-obs instruction stream
        self._obs = None
        self._obs_label = tenant_label or "runtime"
        self._fault_context: Tuple[str, ...] = ()
        if recorder is not None and getattr(recorder, "enabled", False):
            self._obs = recorder
        self._rebuild_planner()

        if initial_demand is None:
            # uniform warm plan: every pair ships 64 chunks; scale-free
            # enough that the first windows are served sanely pre-telemetry
            n = topo.n_devices
            initial_demand = np.full((n, n), 64.0 * self.cfg.chunk_bytes)
            np.fill_diagonal(initial_demand, 0.0)
        self._active, _ = self._solve_handle(
            np.asarray(initial_demand, dtype=np.float64),
            window=0,
            source="initial",
        )

    # -- fabric-arbiter binding -------------------------------------------------
    def bind_arbiter(self, arbiter, tenant: Optional[str]) -> None:
        """Attach/detach this runtime to a :class:`~repro.fabric.FabricArbiter`.

        Called by ``FabricArbiter.register_runtime`` / ``unregister`` — use
        those entry points rather than calling this directly, so the
        ledger, admission gate, and event-bus subscription stay in sync.
        """
        self._arbiter = arbiter
        self._tenant = tenant
        if arbiter is not None:
            # align this runtime's window counter with the fabric clock:
            # commits are stamped in *fabric* windows, so a tenant joining
            # a fabric that has already run N windows is not priced as N
            # windows stale (and decayed to nothing) just because its own
            # counter starts at zero.  On a fresh fabric the offset is 0 —
            # stamps equal local windows, the pre-offset behavior.
            self._fabric_window_offset = arbiter.state.clock - self._window
            # warm the priced jitted closure alongside the unpriced one
            _batch_planner(self.tables, self.cfg.planner, priced=True)
        else:
            self._fabric_window_offset = 0

    # -- flight recorder --------------------------------------------------------
    def attach_recorder(self, recorder, tenant: Optional[str] = None) -> None:
        """Attach a :class:`repro.obs.FlightRecorder` after construction.

        Prefer passing ``recorder=`` to the constructor (or building via a
        recorded Session) so the initial solve is traced too; this hook
        exists for already-built runtimes and backfills a provenance
        record for the current active plan so the audit trail still covers
        every plan.  A disabled recorder (or ``None``) detaches.
        """
        if tenant is not None:
            self._obs_label = tenant
        if recorder is None or not getattr(recorder, "enabled", False):
            self._obs = None
            return
        self._obs = recorder
        if self._active.provenance is None:
            self._active.provenance = recorder.provenance.issue(
                tenant=self._obs_label,
                version=self._active.version,
                source=self._active.source,
                trigger="initial",
                cache_hit=False,
                issued_window=self._active.solved_window,
                signature=self._active.signature,
                demand_bytes=(
                    float(self._active.solved_demand.sum())
                    if self._active.solved_demand is not None else 0.0
                ),
                baseline_ratio=self._active.baseline_ratio,
                planner=planner_provenance(self.cfg.planner),
                prices=self._active.solved_prices,
            )

    def _arbiter_prices(self) -> Optional[np.ndarray]:
        """Exported prices for this tenant (None when unbound or alone)."""
        if self._arbiter is None:
            return None
        return self._arbiter.prices_for(self._tenant)

    # -- planner / tables -------------------------------------------------------
    def _rebuild_planner(self) -> None:
        self.tables = build_planner_tables(self.topo, self.cm)
        # warm the memoized jitted closure(s) for the (possibly new) tables
        _batch_planner(self.tables, self.cfg.planner)
        if self._arbiter is not None:
            _batch_planner(self.tables, self.cfg.planner, priced=True)

    def _solve_batch(
        self, demands: np.ndarray, ext_loads: np.ndarray | None = None
    ) -> List[Plan]:
        """B demand matrices -> B host plans via one jitted batch solve."""
        self.stats.solves += len(demands)
        return solve_plans_batch(
            self.topo, demands, self.cm, self.cfg.planner,
            ext_loads=ext_loads,
        )

    _PRICES_UNSET = object()   # sentinel: "fetch prices from the arbiter"

    def _solve_handle(self, demand: np.ndarray, window: int,
                      source: str,
                      repriced: bool = False,
                      prices=_PRICES_UNSET,
                      trigger: Optional[str] = None) -> Tuple[PlanHandle, bool]:
        """Probe the plan cache, solving on a miss; returns (handle, hit).

        ``prices`` lets a caller that already holds the live price vector
        (the swap-boundary reprice verdict) pass it through instead of
        recomputing the decayed external load.  ``trigger`` is the replan
        reason recorded in the provenance audit trail (defaults to
        ``source``).
        """
        if prices is OrchestrationRuntime._PRICES_UNSET:
            prices = self._arbiter_prices()
        sig = self.demand_signature(demand, prices)
        plan = self._cache_get(sig)
        cache_hit = plan is not None
        if plan is None:
            ext = None if prices is None else prices[None]
            if self._obs is not None:
                # the planner-layer span: the host boundary of the jitted
                # plan_flows_batch dispatch (tracing cannot live inside
                # the traced/jitted function itself)
                with self._obs.tracer.span(
                    "solve", "planner", self._obs_label,
                    {"window": window, "source": source,
                     "priced": prices is not None},
                ):
                    plan = self._solve_batch(demand[None], ext_loads=ext)[0]
            else:
                plan = self._solve_batch(demand[None], ext_loads=ext)[0]
            self._cache_put(sig, plan)
        self._version += 1
        handle = PlanHandle(
            plan=plan,
            signature=sig,
            version=self._version,
            solved_window=window,
            source="cache" if cache_hit else source,
            baseline_ratio=self._ratio(plan, demand),
            solved_demand=demand,
            solved_prices=prices,
            repriced=repriced,
        )
        if self._obs is not None:
            handle.provenance = self._obs.provenance.issue(
                tenant=self._obs_label,
                version=handle.version,
                source=handle.source,
                trigger=trigger or source,
                cache_hit=cache_hit,
                issued_window=window,
                signature=sig,
                demand_bytes=float(demand.sum()),
                baseline_ratio=handle.baseline_ratio,
                planner=planner_provenance(self.cfg.planner),
                prices=prices,
                repriced=repriced,
                fault_context=self._fault_context,
            )
        return handle, cache_hit

    # -- plan cache -------------------------------------------------------------
    def demand_signature(
        self, demand: np.ndarray, prices: Optional[np.ndarray] = None
    ) -> tuple:
        """(topology fingerprint, scale bucket, quantized shape) cache key.

        The shape is quantized to ``signature_levels`` relative levels and
        the magnitude to a power-of-two bucket: MWU split ratios are (up to
        chunk quantization) scale-invariant, so nearby demands share a
        plan; a changed fingerprint (capacities, faults) never matches.

        Arbitrated solves extend the key with the exported price vector,
        quantized the same way — a plan solved under peers' load must not
        be served to a solve under different prices (and vice versa).
        ``prices=None`` leaves the key identical to the unarbitrated one.
        """
        def quantize(v: np.ndarray) -> tuple:
            v = np.asarray(v, dtype=np.float64)
            m = float(v.max())
            if m <= 0:
                return ("zero",)
            q = np.round(v / m * self.cfg.signature_levels).astype(np.int16)
            return (int(round(np.log2(max(m, 1.0)))), q.tobytes())

        sig = (self.topo.fingerprint,) + quantize(demand)
        if prices is None:
            return sig
        return sig + quantize(prices)

    def _cache_get(self, sig: tuple) -> Optional[Plan]:
        plan = self._cache.get(sig)
        if plan is not None:
            self._cache.move_to_end(sig)
            self.stats.cache_hits += 1
        return plan

    def _cache_put(self, sig: tuple, plan: Plan) -> None:
        self._cache[sig] = plan
        self._cache.move_to_end(sig)
        while len(self._cache) > self.cfg.cache_capacity:
            self._cache.popitem(last=False)

    def cache_info(self) -> dict:
        return {
            "size": len(self._cache),
            "hits": self.stats.cache_hits,
            "solves": self.stats.solves,
        }

    def prefill_cache(self, demands: Sequence[np.ndarray]) -> int:
        """Batch-solve and cache several anticipated demand matrices in one
        ``plan_flows_batch`` dispatch (e.g. known tenant phases)."""
        fresh: List[np.ndarray] = []
        sigs: List[tuple] = []
        for D in demands:
            sig = self.demand_signature(np.asarray(D, dtype=np.float64))
            if sig not in self._cache and sig not in sigs:
                fresh.append(np.asarray(D, dtype=np.float64))
                sigs.append(sig)
        if fresh:
            for sig, plan in zip(sigs, self._solve_batch(np.stack(fresh))):
                self._cache_put(sig, plan)
        return len(fresh)

    # -- signals ----------------------------------------------------------------
    def _ratio(self, plan: Plan, demand: np.ndarray) -> float:
        """Predicted congestion ratio: stale-plan Z over the cut bound Z*."""
        dem = demand_dict(demand)
        if not dem:
            return 1.0
        z = apply_plan_fractions(
            plan, dem, topo=self.topo, cost_model=self.cm
        ).max_normalized_load()
        lb = congestion_lower_bound(self.topo, dem, self.cm)
        return z / lb if lb > 0 else 1.0

    # -- event handling ---------------------------------------------------------
    def _apply_events(self, due: List[LinkEvent]) -> None:
        overrides = dict(self.events.overrides(due))
        self.topo = self.topo.with_link_scale(overrides)
        self._rebuild_planner()
        # telemetry capacities follow the fabric; the ring buffer persists
        self.telemetry.capacity_bps = ResourceModel(
            self.topo, self.cm
        ).capacity
        self.stats.events += len(due)
        # a pending plan was solved against the old capacities — discard
        self._pending = None

    # -- the loop ----------------------------------------------------------------
    def _maybe_swap(self, window: int) -> bool:
        """Atomic plan swap at the window boundary (never mid-round).

        Arbitrated runtimes re-price the pending plan here (DESIGN.md
        §4.3): the plan was solved ``solve_delay_windows`` ago under the
        prices of its issue window, and on a fabric whose peers moved
        meanwhile those prices describe where everyone *was* — exactly the
        mutual over-avoidance failure.  When the arbiter's ``reprice``
        verdict says the prices moved past ``price_hint_rel`` since issue,
        the plan **still swaps in** — it was solved on fresher demand than
        whatever it replaces, and holding the older active plan an extra
        window is strictly worse — but the same demand is immediately
        re-solved against live prices and the *refined* plan parked as the
        new pending (swap-and-refine).  One refine round per replan chain
        (``PlanHandle.repriced``): the refined plan swaps at its own
        boundary regardless, so continuous drift costs at most one extra
        solve per replan and can never starve the dataplane of swaps.
        Refines never charge the admission gate — they complete an
        already-admitted replan rather than issuing a new one.

        A **pending-plan watchdog** (DESIGN.md §9) guards the issue-to-swap
        path: a pending whose solve is older than
        ``pending_deadline_windows`` describes a fabric that no longer
        exists (window-clock jumps via ``observe_dispatch``, drill-scale
        solve delays), so it is abandoned and the live estimate re-solved
        in its place rather than swapped in stale.  Watchdog-issued
        pendings are exempt from re-abandonment so a slow solver degrades
        to periodic refresh instead of livelock.
        """
        if self._pending is None:
            return False
        handle, ready = self._pending
        deadline = self.cfg.pending_deadline_windows
        if (
            deadline is not None
            and handle.source != "watchdog"
            and window - handle.solved_window > deadline
        ):
            self.stats.watchdog_abandons += 1
            if handle.provenance is not None:
                handle.provenance.mark_abandoned()
            if self._obs is not None:
                self._obs.tracer.instant(
                    "replan", "runtime", self._obs_label,
                    {"window": window, "source": "watchdog",
                     "abandoned_version": handle.version},
                )
            live = (
                self.estimator.predict()
                if self.estimator.initialized
                else handle.solved_demand
            )
            wd_handle, cache_hit = self._solve_handle(
                live, window, "watchdog", trigger="watchdog"
            )
            ready = window + (
                1 if cache_hit else max(1, self.cfg.solve_delay_windows)
            )
            if wd_handle.provenance is not None:
                wd_handle.provenance.mark_ready(ready)
            self._pending = (wd_handle, ready)
            return False
        if ready > window:
            return False
        self._pending = None
        if (
            self._arbiter is not None
            and not handle.repriced
            and handle.solved_demand is not None
        ):
            verdict = self._arbiter.reprice(
                self._tenant, handle.solved_prices
            )
            if verdict.moved:
                re_handle, cache_hit = self._solve_handle(
                    handle.solved_demand, window, "reprice", repriced=True,
                    prices=verdict.prices, trigger="reprice",
                )
                ready = window + (
                    1 if cache_hit else max(1, self.cfg.solve_delay_windows)
                )
                if re_handle.provenance is not None:
                    re_handle.provenance.mark_ready(ready)
                self._pending = (re_handle, ready)
                self.stats.reprices += 1
            if handle.provenance is not None:
                handle.provenance.mark_swapped(
                    window, prices=verdict.prices,
                    rel_change=verdict.rel_change, repriced=verdict.moved,
                )
        elif handle.provenance is not None:
            handle.provenance.mark_swapped(
                window, prices=self._arbiter_prices()
                if self._arbiter is not None else None,
            )
        if self._obs is not None:
            self._obs.tracer.instant(
                "swap", "runtime", self._obs_label,
                {"window": window, "version": handle.version,
                 "source": handle.source, "repriced": handle.repriced},
            )
        self._active = handle
        self.stats.swaps += 1
        # pass the solve provenance: a fabric-pressure hint newer than
        # the swapped plan's solve must survive the swap (the plan was
        # priced before the fabric shifted)
        self.policy.notify_swap(handle.solved_window)
        return True

    def _issue_replan(self, predicted: np.ndarray, window: int,
                      source_hint: str = "solve",
                      trigger: Optional[str] = None) -> Tuple[PlanHandle, bool]:
        handle, cache_hit = self._solve_handle(
            predicted, window, source_hint, trigger=trigger
        )
        # cache hit swaps at the very next boundary (no solve latency);
        # a miss pays the off-hot-path solve delay first
        ready = window + (
            1 if cache_hit else max(1, self.cfg.solve_delay_windows)
        )
        if handle.provenance is not None:
            handle.provenance.mark_ready(ready)
        if self._obs is not None:
            self._obs.tracer.instant(
                "replan", "runtime", self._obs_label,
                {"window": window, "reason": trigger or source_hint,
                 "version": handle.version, "cache_hit": cache_hit,
                 "ready": ready},
            )
        self._pending = (handle, ready)
        self.stats.replans += 1
        return handle, cache_hit

    _OBS_UNSET = object()   # sentinel: "telemetry observed the demand as-is"

    def step(
        self,
        demand: np.ndarray,
        *,
        observed=_OBS_UNSET,
        completion_scale: float = 1.0,
    ) -> WindowReport:
        """Advance one window: execute, observe, predict, decide, buffer.

        ``observed`` is what telemetry *saw* this window when that differs
        from the executed demand (fault drills, DESIGN.md §9): ``None``
        models a full telemetry blackout (the estimator keeps serving its
        last-good prediction with decayed confidence), a partial array may
        carry NaN entries for dropped counters.  ``completion_scale``
        inflates the measured completion time (straggler windows) without
        touching the routed bytes.  Defaults are bit-identical to the
        pre-fault-harness behavior.

        With a flight recorder attached the window runs inside a
        ``window`` trace span on this tenant's track (with ``fault`` /
        ``swap`` / ``replan`` markers nested inside) and observes the
        completion into the per-tenant latency histogram; without one the
        wrapper is a single ``None`` check.
        """
        if self._obs is None:
            return self._step(
                demand, observed=observed, completion_scale=completion_scale
            )
        tr = self._obs.tracer
        tr.advance_to(self._window * 1000)
        span = tr.begin(
            "window", "runtime", self._obs_label, {"window": self._window}
        )
        report = self._step(
            demand, observed=observed, completion_scale=completion_scale
        )
        tr.end(span, {
            "plan_version": report.plan_version,
            "congestion_ratio": round(report.congestion_ratio, 4),
            "reason": report.replan_reason,
        })
        self._obs.metrics.histogram(
            "nimble_runtime_window_completion_s",
            {"tenant": self._obs_label},
        ).observe(report.completion_s)
        return report

    def _step(
        self,
        demand: np.ndarray,
        *,
        observed=_OBS_UNSET,
        completion_scale: float = 1.0,
    ) -> WindowReport:
        w = self._window
        demand = np.asarray(demand, dtype=np.float64)
        if observed is OrchestrationRuntime._OBS_UNSET:
            observed = demand

        due = self.events.pop_due(w)
        self._fault_context = tuple(ev.describe() for ev in due)
        if due:
            if self._obs is not None:
                for ev in due:
                    self._obs.tracer.instant(
                        "fault", "runtime", self._obs_label,
                        {"window": w, "event": ev.describe(),
                         "kind": ev.kind},
                    )
            self._apply_events(due)
        swapped = self._maybe_swap(w)

        # execute the window under the active plan's split ratios
        dem = demand_dict(demand)
        exec_plan = apply_plan_fractions(
            self._active.plan, dem, topo=self.topo, cost_model=self.cm
        )
        sim = simulate(exec_plan, self.cfg.chunk_bytes)
        # telemetry stores only clean pair observations; partial (NaN) and
        # blackout windows record the resource counters with no pair bytes
        pair_obs = (
            observed
            if observed is not None and np.isfinite(observed).all()
            else None
        )
        self.telemetry.record(
            w, sim, pair_bytes=pair_obs, completion_scale=completion_scale
        )
        if self._arbiter is not None:
            # telemetry export: this window's realized per-resource loads
            # become this tenant's committed load in the shared ledger —
            # window-stamped so peers' recency decay can fade it, and
            # fingerprint-tagged so a commit racing a topology rebuild is
            # rejected by name instead of as an opaque shape error
            self._arbiter.commit(
                self._tenant, exec_plan.resource_bytes,
                window=w + self._fabric_window_offset,
                fingerprint=self.topo.fingerprint,
            )

        # estimate next-window demand and evaluate the triggers (the
        # estimator degrades gracefully on None / NaN-masked observations)
        self.estimator.update(observed)
        predicted = self.estimator.predict()
        ratio = self._ratio(self._active.plan, predicted)
        decision: ReplanDecision = self.policy.decide(
            window=w,
            ratio=ratio,
            baseline_ratio=self._active.baseline_ratio,
            plan_age=w - self._active.solved_window,
            pending=self._pending is not None,
            topology_event=bool(due),
        )
        trigger_reason = decision.reason
        if (
            decision.replan
            and self._arbiter is not None
            and decision.reason != "topology"
        ):
            # replan admission gate: a drift burst on one tenant must not
            # monopolize the shared solver or churn peers' price-keyed
            # caches; topology-forced replans always pass
            verdict = self._arbiter.admit(
                self._tenant, window=w, reason=decision.reason
            )
            if not verdict.admitted:
                decision = dataclasses.replace(
                    decision, replan=False, reason="gated"
                )
                self.stats.gated += 1
                # the fired trigger disarmed the policy but no swap will
                # follow — re-arm so the tenant retries once tokens refill
                self.policy.notify_gated()
                if trigger_reason == "fabric":
                    # the pressure that fired was not relieved (no solve
                    # happened) — restart the soft deadline so the tenant
                    # retries once its tokens refill
                    self.policy.notify_fabric_pressure(w)
        cache_hit = False
        if decision.replan:
            _, cache_hit = self._issue_replan(
                predicted, w, trigger=decision.reason
            )

        self.stats.windows += 1
        self._window += 1
        return WindowReport(
            window=w,
            completion_s=float(sim.completion_time) * completion_scale,
            payload_bytes=float(sim.total_payload),
            bandwidth_gbs=sim.bandwidth_gbs(),
            bottleneck=sim.bottleneck_kind(exec_plan),
            congestion_ratio=float(ratio),
            plan_version=self._active.version,
            plan_source=self._active.source,
            swapped=swapped,
            replan_issued=decision.replan,
            replan_reason=decision.reason,
            cache_hit=cache_hit,
            events=tuple(ev.describe() for ev in due),
            trigger_reason=trigger_reason,
            confidence=float(self.estimator.confidence),
            telemetry_rejected=int(self.telemetry.rejected),
        )

    def run_trace(
        self,
        trace: np.ndarray,                     # [W, n, n]
        events: Optional[EventLog] = None,
    ) -> TraceResult:
        """Replay a multi-window traffic trace through the full loop.

        ``events`` (if given) is merged by copy — the caller's log is left
        intact so the same log can parameterize several replays.
        """
        if events is not None:
            for ev in events.snapshot():
                self.events.schedule(ev)
        reports = [self.step(trace[w]) for w in range(len(trace))]
        return TraceResult(reports, dataclasses.replace(self.stats))

    # -- fabric-pressure hook ---------------------------------------------------
    def notify_fabric_pressure(self) -> None:
        """A fabric "prices moved" hint arrived (arbiter broadcast).

        Peers' committed load shifted materially, so the active plan may
        be priced stale even while this tenant's own demand is flat.
        Forwarded to the policy's soft staleness clock; a no-op unless
        ``PolicyConfig.fabric_staleness`` is set.
        """
        self.policy.notify_fabric_pressure(self._window)

    # -- dataplane / dispatcher hook --------------------------------------------
    def observe_dispatch(self, demand_bytes: np.ndarray) -> None:
        """Feed externally-executed demand (e.g. MoE dispatch rounds) into
        telemetry + estimator without driving the fabsim loop.

        Accepts ``[n, n]`` or ``[B, n, n]``; batched entries are recorded
        as consecutive windows.
        """
        demand_bytes = np.asarray(demand_bytes, dtype=np.float64)
        mats = demand_bytes[None] if demand_bytes.ndim == 2 else demand_bytes
        for D in mats:
            dem = demand_dict(D)
            if dem:
                plan = apply_plan_fractions(
                    self._active.plan, dem, topo=self.topo, cost_model=self.cm
                )
                self.telemetry.record_loads(
                    self._window, plan.resource_bytes, pair_bytes=D
                )
                if self._arbiter is not None:
                    self._arbiter.commit(
                        self._tenant, plan.resource_bytes,
                        window=self._window + self._fabric_window_offset,
                        fingerprint=self.topo.fingerprint,
                    )
            self.estimator.update(D)
            self._window += 1

    @property
    def active_plan(self) -> Plan:
        return self._active.plan

    @property
    def active_version(self) -> int:
        return self._active.version


# -- evaluation bookends ---------------------------------------------------------

def run_static(
    topo: Topology,
    trace: np.ndarray,
    cost_model: CostModel | None = None,
    planner_cfg: PlannerConfig | None = None,
    chunk_bytes: float = float(1 << 20),
    solve_window: int = 0,
    events: Optional[EventLog] = None,
) -> TraceResult:
    """One-shot baseline: solve on window ``solve_window``, never replan."""
    pcfg = planner_cfg or PlannerConfig(n_iters=32)
    cur = topo
    plan = solve_plans_batch(
        cur, trace[solve_window][None], cost_model, pcfg
    )[0]
    reports: List[WindowReport] = []
    ev_log = events.copy() if events is not None else EventLog()
    for w in range(len(trace)):
        due = ev_log.pop_due(w)
        if due:
            cur = cur.with_link_scale(dict(ev_log.overrides(due)))
        dem = demand_dict(np.asarray(trace[w], dtype=np.float64))
        sim = simulate(
            apply_plan_fractions(plan, dem, topo=cur, cost_model=cost_model),
            chunk_bytes,
        )
        reports.append(
            WindowReport(
                window=w,
                completion_s=float(sim.completion_time),
                payload_bytes=float(sim.total_payload),
                bandwidth_gbs=sim.bandwidth_gbs(),
                bottleneck="",
                congestion_ratio=0.0,
                plan_version=1,
                plan_source="static",
                swapped=False,
                replan_issued=False,
                replan_reason="none",
                cache_hit=False,
                events=tuple(ev.describe() for ev in due),
            )
        )
    stats = RuntimeStats(windows=len(trace), solves=1)
    return TraceResult(reports, stats)


def run_oracle(
    topo: Topology,
    trace: np.ndarray,
    cost_model: CostModel | None = None,
    planner_cfg: PlannerConfig | None = None,
    chunk_bytes: float = float(1 << 20),
) -> TraceResult:
    """Clairvoyant bound: every window re-solved on its true demand, all
    windows batched through ONE ``plan_flows_batch`` dispatch."""
    pcfg = planner_cfg or PlannerConfig(n_iters=32)
    plans = solve_plans_batch(
        topo, np.asarray(trace, dtype=np.float64), cost_model, pcfg
    )
    reports: List[WindowReport] = []
    for w, plan in enumerate(plans):
        sim = simulate(plan, chunk_bytes)
        reports.append(
            WindowReport(
                window=w,
                completion_s=float(sim.completion_time),
                payload_bytes=float(sim.total_payload),
                bandwidth_gbs=sim.bandwidth_gbs(),
                bottleneck="",
                congestion_ratio=1.0,
                plan_version=w + 1,
                plan_source="oracle",
                swapped=True,
                replan_issued=True,
                replan_reason="oracle",
                cache_hit=False,
                events=(),
                trigger_reason="oracle",
            )
        )
    stats = RuntimeStats(
        windows=len(trace), replans=len(trace), solves=len(trace),
        swaps=len(trace),
    )
    return TraceResult(reports, stats)
