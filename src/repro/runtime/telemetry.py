"""Link/resource telemetry — the *monitor* stage of the runtime loop.

NIMBLE is endpoint-driven (§III): every device observes the traffic it
sources and the utilization of the resources its plans charge, with no
central collector.  :class:`LinkTelemetry` is the per-endpoint counter
store: a fixed-capacity **ring buffer** of per-window records, each holding

  * per-resource busy time and utilization over the window (harvested from
    :class:`~repro.core.fabsim.SimResult` in simulation, or from planned
    resource loads when hooked into live ``NimbleAllToAll.plan_batch``
    executions);
  * the observed per-pair byte counts (the realized demand matrix), which
    feed the demand estimator for the next window's prediction.

Aggregation helpers (`mean_util`, `utilization_imbalance`, `aggregate`)
operate over the last *k* windows so the replan policy can look at smoothed
signals instead of single-window noise.  Serialization goes through the
shared ``repro.jsonio`` schema (``nimble.telemetry_window/v1``).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from ..jsonio import tag


@dataclasses.dataclass(frozen=True)
class TelemetryWindow:
    """One window's harvested counters."""

    window: int
    completion_s: float
    payload_bytes: float
    bottleneck_resource: int
    per_resource_time: np.ndarray    # [R] seconds busy
    per_resource_util: np.ndarray    # [R] fraction of window busy
    pair_bytes: Optional[np.ndarray]  # [n, n] observed demand (or None)

    def to_json_obj(self) -> dict:
        return tag(
            "telemetry_window",
            {
                "window": int(self.window),
                "completion_s": float(self.completion_s),
                "payload_bytes": float(self.payload_bytes),
                "bottleneck_resource": int(self.bottleneck_resource),
                "util_max": float(self.per_resource_util.max())
                if len(self.per_resource_util)
                else 0.0,
                "util_mean_busy": _mean_busy(self.per_resource_util),
                "pair_bytes_total": float(self.pair_bytes.sum())
                if self.pair_bytes is not None
                else None,
            },
        )


def _mean_busy(util: np.ndarray) -> float:
    busy = util[util > 0]
    return float(busy.mean()) if busy.size else 0.0


class LinkTelemetry:
    """Fixed-capacity ring buffer of per-window resource counters."""

    def __init__(self, capacity_bps: np.ndarray, window_capacity: int = 256):
        if window_capacity <= 0:
            raise ValueError("window_capacity must be positive")
        self.capacity_bps = np.asarray(capacity_bps, dtype=np.float64)
        self.n_resources = len(self.capacity_bps)
        self.window_capacity = window_capacity
        R, W = self.n_resources, window_capacity
        self._time = np.zeros((W, R))
        self._util = np.zeros((W, R))
        self._completion = np.zeros(W)
        self._payload = np.zeros(W)
        self._bottleneck = np.full(W, -1, dtype=np.int64)
        self._window_id = np.full(W, -1, dtype=np.int64)
        self._pairs: List[Optional[np.ndarray]] = [None] * W
        self._count = 0   # total records ever written
        self.rejected = 0  # malformed load records refused (NaN/negative)

    # -- recording -------------------------------------------------------------
    def record(self, window: int, sim, pair_bytes: Optional[np.ndarray] = None,
               completion_scale: float = 1.0) -> None:
        """Harvest a :class:`~repro.core.fabsim.SimResult` for one window.

        ``completion_scale`` stretches the measured busy/completion times
        (straggler windows, DESIGN.md §9) without touching utilization —
        the fabric did the same work, it just took longer.
        """
        self._write(
            window,
            per_resource_time=(
                np.asarray(sim.per_resource_time, dtype=np.float64)
                * completion_scale
            ),
            per_resource_util=np.asarray(sim.per_resource_util, dtype=np.float64),
            completion_s=float(sim.completion_time) * completion_scale,
            payload=float(sim.total_payload),
            bottleneck=int(sim.bottleneck_resource),
            pair_bytes=pair_bytes,
        )

    def record_loads(
        self,
        window: Optional[int],
        resource_bytes: np.ndarray,
        pair_bytes: Optional[np.ndarray] = None,
    ) -> None:
        """Harvest planned per-resource loads (dataplane ``plan_batch`` hook).

        Loads are effective bytes; busy time is ``bytes / capacity`` and the
        window "completion" is the slowest resource (the plan's objective Z).
        ``window=None`` self-numbers with the record count (useful when
        several producers share one sink and none owns a window clock).

        A shape mismatch is a caller bug and raises; NaN/Inf/negative
        entries are *producer corruption* (a crashed counter, a torn read)
        and are **rejected whole** — the record is dropped and ``rejected``
        incremented, so one poisoned window can never contaminate
        ``mean_util`` / ``utilization_imbalance`` for everything behind it
        in the ring.
        """
        loads = np.asarray(resource_bytes, dtype=np.float64)
        if loads.shape != (self.n_resources,):
            raise ValueError(
                f"loads shape {loads.shape} != ({self.n_resources},) — the "
                "producer's topology disagrees with this telemetry sink's"
            )
        if not np.isfinite(loads).all() or (loads < 0).any():
            self.rejected += 1
            return
        drain = loads / self.capacity_bps
        t = float(drain.max()) if len(drain) else 0.0
        util = drain / t if t > 0 else np.zeros_like(drain)
        self._write(
            window,
            per_resource_time=drain,
            per_resource_util=util,
            completion_s=t,
            payload=float(pair_bytes.sum()) if pair_bytes is not None else 0.0,
            bottleneck=int(np.argmax(drain)) if len(drain) else -1,
            pair_bytes=pair_bytes,
        )

    def _write(self, window, per_resource_time, per_resource_util,
               completion_s, payload, bottleneck, pair_bytes) -> None:
        if window is None:
            window = self._count
        i = self._count % self.window_capacity
        self._time[i] = per_resource_time
        self._util[i] = per_resource_util
        self._completion[i] = completion_s
        self._payload[i] = payload
        self._bottleneck[i] = bottleneck
        self._window_id[i] = window
        self._pairs[i] = (
            np.asarray(pair_bytes, dtype=np.float64)
            if pair_bytes is not None
            else None
        )
        self._count += 1

    # -- access ----------------------------------------------------------------
    def __len__(self) -> int:
        return min(self._count, self.window_capacity)

    def _live_idx(self, last_k: Optional[int] = None) -> np.ndarray:
        """Ring indices of the last ``k`` records, oldest -> newest."""
        n = len(self)
        k = n if last_k is None else min(last_k, n)
        start = self._count - k
        return np.arange(start, self._count) % self.window_capacity

    def latest(self, k: int = 1) -> List[TelemetryWindow]:
        return [
            TelemetryWindow(
                window=int(self._window_id[i]),
                completion_s=float(self._completion[i]),
                payload_bytes=float(self._payload[i]),
                bottleneck_resource=int(self._bottleneck[i]),
                per_resource_time=self._time[i].copy(),
                per_resource_util=self._util[i].copy(),
                pair_bytes=self._pairs[i],
            )
            for i in self._live_idx(k)
        ]

    # -- aggregation -----------------------------------------------------------
    def mean_util(self, last_k: Optional[int] = None) -> np.ndarray:
        """Per-resource mean utilization over the last ``k`` windows."""
        idx = self._live_idx(last_k)
        if not len(idx):
            return np.zeros(self.n_resources)
        return self._util[idx].mean(axis=0)

    def utilization_imbalance(self, last_k: Optional[int] = None) -> float:
        """max/mean utilization over busy resources — the *skew* signal.

        1.0 means perfectly balanced load (the paper's "symmetry"); large
        values mean traffic is funneling onto few links.
        """
        mu = self.mean_util(last_k)
        busy = mu[mu > 0]
        if not busy.size:
            return 1.0
        return float(busy.max() / busy.mean())

    def observed_demand(self, last_k: Optional[int] = None
                        ) -> Optional[np.ndarray]:
        """Summed per-pair bytes over the last ``k`` windows (None if unset)."""
        mats = [self._pairs[i] for i in self._live_idx(last_k)]
        mats = [m for m in mats if m is not None]
        if not mats:
            return None
        return np.sum(mats, axis=0)

    def health(self) -> dict:
        """Compact numeric-only health snapshot for the metrics registry
        (DESIGN.md §11) — no schema envelope, no arrays, so the flight
        recorder's collectors can map it straight onto gauges."""
        last = self.latest(1)
        return {
            "windows": int(self._count),
            "retained": len(self),
            "rejected": int(self.rejected),
            "utilization_imbalance": self.utilization_imbalance(),
            "last_completion_s": (
                float(last[0].completion_s) if last else 0.0
            ),
        }

    def aggregate(self, last_k: Optional[int] = None) -> dict:
        idx = self._live_idx(last_k)
        return tag(
            "telemetry_aggregate",
            {
                "windows": int(len(idx)),
                "completion_s_total": float(self._completion[idx].sum()),
                "payload_bytes_total": float(self._payload[idx].sum()),
                "utilization_imbalance": self.utilization_imbalance(last_k),
                "util_mean_busy": _mean_busy(self.mean_util(last_k)),
                "rejected_records": int(self.rejected),
            },
        )

    def to_json_obj(self, last_k: Optional[int] = None) -> dict:
        return tag(
            "telemetry_log",
            {
                "aggregate": self.aggregate(last_k),
                "windows": [
                    w.to_json_obj() for w in self.latest(last_k or len(self))
                ],
            },
        )
