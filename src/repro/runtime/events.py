"""Topology events — link degradation, link down, link restore.

The paper's runtime is defined against a fabric that *changes*: congestion
from cross-traffic, but also NIC flaps and switch-port brownouts that no
one-shot plan can anticipate.  A :class:`LinkEvent` rescales one directed
link's capacity at a window boundary; the controller applies due events by
deriving a new :class:`~repro.core.topology.Topology` via
``with_link_scale`` — same geometry, new capacities, new fingerprint — so
the planner core rebuilds (and re-caches) incidence tables for the degraded
fabric, and the policy force-replans.

Scales: ``0.0`` = down (capacity ``topology.DOWN_CAP``), ``(0, 1)`` =
degraded, ``1.0`` = restored.  Events compose by replacement, so a restore
after a degrade returns the link to its calibrated capacity exactly.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Iterable, List, Optional, Tuple


@dataclasses.dataclass(frozen=True, order=True)
class LinkEvent:
    """Rescale link ``src -> dst`` to ``scale`` at ``window``."""

    window: int
    src: int
    dst: int
    scale: float

    @property
    def kind(self) -> str:
        if self.scale <= 0.0:
            return "link_down"
        if self.scale >= 1.0:
            return "link_restored"
        return "link_degraded"

    def describe(self) -> str:
        extra = "" if self.scale in (0.0, 1.0) else f" x{self.scale:g}"
        return f"{self.kind}[{self.src}->{self.dst}]@w{self.window}{extra}"

    def to_json_obj(self) -> dict:
        """Tagged ``nimble.link_event/v1`` record — the structured twin of
        :meth:`describe`, for trace args and provenance fault context."""
        from ..jsonio import tag

        return tag("link_event", {
            "window": int(self.window),
            "src": int(self.src),
            "dst": int(self.dst),
            "scale": float(self.scale),
            "kind": self.kind,
        })


def link_down(window: int, src: int, dst: int) -> LinkEvent:
    return LinkEvent(window, src, dst, 0.0)


def link_degraded(window: int, src: int, dst: int, scale: float) -> LinkEvent:
    if not 0.0 < scale < 1.0:
        raise ValueError(f"degraded scale must be in (0, 1), got {scale}")
    return LinkEvent(window, src, dst, scale)


def link_restored(window: int, src: int, dst: int) -> LinkEvent:
    return LinkEvent(window, src, dst, 1.0)


@dataclasses.dataclass(frozen=True)
class PricesMovedHint:
    """Fabric-pressure broadcast: the shared ledger moved materially.

    Published by the fabric arbiter on the shared
    :class:`~repro.core.topology.LinkEventBus` (next to the
    :class:`LinkEvent` batches it already carries) when a tenant commit
    shifts the total committed load by more than the arbiter's
    ``price_hint_rel`` threshold.  ``tenant`` names the committer whose
    load moved — its *own* runtime skips the hint on delivery, because a
    tenant's own commit never changes its own exported prices.  Receiving
    runtimes forward it to ``ReplanPolicy.notify_fabric_pressure``, which
    treats it as a soft staleness deadline (``PolicyConfig.
    fabric_staleness``): a demand-stable tenant still re-prices a fabric
    that shifted under it.  Hints complement the pull side of the same
    recency machinery: the arbiter's decayed prices and its swap-boundary
    ``reprice`` hook (DESIGN.md §4.3) close the issue→swap staleness
    window for plans already in flight, while the hint wakes tenants whose
    own triggers would otherwise never fire.

    ``clock`` is the fabric ledger clock (newest stamped commit window) at
    publish time — 0 when no stamped commit has landed yet (matching
    ``FabricState.clock``), ``None`` only from publishers that predate
    recency stamps; diagnostic only, receivers key off their own window
    counters.
    """

    tenant: str
    rel_change: float
    clock: Optional[int] = None


def merge_overrides(events: Iterable[LinkEvent]
                    ) -> List[Tuple[Tuple[int, int], float]]:
    """(endpoints, scale) pairs for a batch of events (last one wins).

    The single definition of the override-merge semantics, shared by
    :meth:`EventLog.overrides` (per-runtime application) and the fabric
    arbiter's broadcast path — the ledger and the runtimes must never
    disagree on how same-link events compose.
    """
    merged = {}
    for ev in events:
        merged[(ev.src, ev.dst)] = ev.scale
    return list(merged.items())


class EventLog:
    """Window-ordered queue of scheduled topology events.

    Events due in the same window pop in **schedule order** (a per-log
    sequence number breaks heap ties), so "last one wins" in
    :meth:`overrides` means the last *scheduled*, not an accident of how
    scales happen to sort.
    """

    def __init__(self, events: Iterable[LinkEvent] = ()):
        self._heap: List[tuple] = []   # (window, seq, event)
        self._seq = 0
        for ev in events:
            self.schedule(ev)

    def schedule(self, event: LinkEvent) -> None:
        heapq.heappush(self._heap, (event.window, self._seq, event))
        self._seq += 1

    def pop_due(self, window: int) -> List[LinkEvent]:
        """All events with ``event.window <= window``, in schedule order."""
        due = []
        while self._heap and self._heap[0][0] <= window:
            due.append(heapq.heappop(self._heap)[2])
        return due

    def peek_next_window(self) -> Optional[int]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def snapshot(self) -> List[LinkEvent]:
        """Pending events in pop order, without consuming them."""
        return [ev for _, _, ev in sorted(self._heap)]

    def copy(self) -> "EventLog":
        return EventLog(self.snapshot())

    def overrides(self, events: Iterable[LinkEvent]
                  ) -> List[Tuple[Tuple[int, int], float]]:
        """(endpoints, scale) pairs for a batch of events (last one wins)."""
        return merge_overrides(events)
