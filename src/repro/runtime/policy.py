"""Replan triggers with hysteresis — the *decide* stage of the runtime loop.

A replan costs planner time, a plan-cache probe, and (amortized) jit solve
latency, so the policy's job is asymmetric: fire promptly when the active
plan has genuinely degraded, and **never** fire on balanced traffic — the
paper's "matches baseline under balanced traffic" claim is a statement
about this trigger, not about the planner.

The congestion signal is *self-calibrated*: every plan records its own
``baseline_ratio`` — predicted max normalized load Z over the cut lower
bound Z* — at solve time (even a perfect plan sits somewhat above the
bound, and how far depends on topology and skew).  The trigger compares
the current ratio against ``baseline_ratio * degrade_factor`` rather than
an absolute constant, so a plan is replaced when *it* got worse, not when
the workload is intrinsically hard.

Hysteresis has three guards:

  * **patience** — the threshold must be breached ``patience`` consecutive
    windows (raise above 1 when the demand estimator is noisier than the
    default EWMA, at the cost of one extra stale window per drift);
  * **arming** — after a trigger the policy disarms until the ratio falls
    back under ``baseline_ratio * rearm_factor`` (no re-fire storms while
    a replan is being absorbed);
  * **cooldown** — a minimum number of windows between triggers.

Three triggers bypass the congestion hysteresis: a **staleness deadline**
(optional: plans older than ``max_staleness`` windows replan regardless,
for deployments whose drift is slow but unbounded), **topology events**
(link down/degraded — always replan, immediately), and **fabric
pressure** (a "prices moved" hint from the fabric arbiter — peers'
committed load shifted materially — is treated as a *soft staleness
deadline*: within ``fabric_staleness`` windows of the hint the tenant
replans with ``reason="fabric"`` even if its own demand is perfectly
stable, so it re-prices the fabric it actually shares; see
``FabricArbiter`` price hints, DESIGN.md §4.3).  The constructor default
``fabric_staleness=None`` keeps hand-wired runtimes bit-identical to the
pre-hint behavior; **arbitrated sessions** enable it with the calibrated
``repro.api.FABRIC_STALENESS_DEFAULT`` (2 windows — one boundary of
grace so an in-flight replan can absorb the shift, calibrated on the
mutual-drift scenarios in ``benchmarks/bench_fairness.py``).  The trigger
covers tenants with *no* replan in flight; the complementary issue→swap
staleness window is closed by the controller's swap-boundary re-pricing
(``OrchestrationRuntime._maybe_swap`` + ``FabricArbiter.reprice``).

**Flap backoff** (DESIGN.md §9).  "Topology events always replan" is the
right reflex for a single failure and a replan storm under a *flapping*
link: every down/restore pair would force a fresh solve, churning the
plan cache and the fabric's priced equilibrium faster than either can
converge.  Topology triggers therefore carry an exponential backoff:
after a topology-triggered replan at window *w* with backoff *b*,
further topology events before *w + b* are **suppressed** with
``reason="backoff"`` (the controller still rebuilds its tables — the
fabric view stays truthful — it just keeps serving the current plan's
split ratios on the degraded capacities).  Consecutive topology fires
inside ``flap_reset_windows`` of each other grow the backoff
geometrically (``flap_backoff_base * flap_backoff_factor ** level``, cap
``flap_backoff_max``); a quiet stretch resets it, so an isolated failure
months after a flap train replans immediately again.  A suppressed event
is **deferred, never dropped**: the first ``decide`` at or past the
backoff horizon fires a catch-up ``reason="topology"`` replan against
live state, which is how the fabric re-optimizes after the final restore
of a flap train.  The replan count under an F-event flap train is thus
O(log F + duration / cap) instead of F.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    degrade_factor: float = 1.15  # trigger: ratio > baseline * degrade_factor
    rearm_factor: float = 1.05    # re-arm: ratio < baseline * rearm_factor
    patience: int = 1             # consecutive breaching windows to fire
    cooldown_windows: int = 2     # min windows between congestion triggers
    max_staleness: Optional[int] = None  # windows; None = no deadline
    # windows between a fabric "prices moved" hint and a forced replan
    # (soft staleness deadline); None disables the fabric-pressure trigger
    # (hand-wired default — arbitrated Sessions pass the calibrated
    # repro.api.FABRIC_STALENESS_DEFAULT instead)
    fabric_staleness: Optional[int] = None
    # flap-aware exponential backoff on topology triggers: after a
    # topology replan, further topology events inside the backoff window
    # are suppressed (reason="backoff") and deferred.  base=0 disables
    # (every topology event replans immediately — the pre-backoff
    # behavior).  The default base of 1 is invisible to isolated events:
    # a single down (or down+restore a few windows apart) still replans
    # immediately; only rapid-fire trains hit the growing backoff.
    flap_backoff_base: int = 1
    flap_backoff_factor: float = 2.0
    flap_backoff_max: int = 8
    # a topology-quiet stretch of more than this many windows resets the
    # backoff level, so the next isolated event replans immediately again
    flap_reset_windows: int = 16


@dataclasses.dataclass(frozen=True)
class ReplanDecision:
    replan: bool
    # "topology" | "congestion" | "staleness" | "fabric" | "backoff" |
    # "none"; an arbitrated controller may rewrite a positive decision to
    # replan=False with reason "gated" when the fabric admission gate
    # throttles the tenant.  "backoff" marks a topology event suppressed
    # by the flap backoff (replan deferred to the backoff horizon).
    reason: str
    ratio: float
    threshold: float


class ReplanPolicy:
    """Stateful trigger evaluation; one instance per runtime."""

    def __init__(self, cfg: PolicyConfig | None = None):
        self.cfg = cfg or PolicyConfig()
        self._breach = 0
        self._armed = True
        self._last_trigger: Optional[int] = None
        self._pressure_window: Optional[int] = None
        # flap-backoff state: current escalation level, the window until
        # which topology triggers are suppressed, the last topology fire
        # (for quiet-period reset), and whether a suppressed event is
        # waiting for a deferred catch-up replan
        self._flap_level = 0
        self._topo_block_until: Optional[int] = None
        self._last_topo_fire: Optional[int] = None
        self._deferred_topo = False

    def decide(
        self,
        *,
        window: int,
        ratio: float,
        baseline_ratio: float,
        plan_age: int,
        pending: bool,
        topology_event: bool = False,
    ) -> ReplanDecision:
        """Evaluate the triggers for one window.

        ``ratio`` is the active plan's predicted-congestion ratio on the
        estimator's next-window demand; ``baseline_ratio`` its ratio at
        solve time; ``plan_age`` windows since the active plan was solved;
        ``pending`` whether a replan is already in flight (congestion and
        staleness stand down; topology events do not — the controller
        discards the in-flight plan, which was solved for dead geometry).
        """
        cfg = self.cfg
        threshold = baseline_ratio * cfg.degrade_factor
        if topology_event:
            if self._flap_blocked(window):
                # flap backoff: suppress the replan storm, defer the
                # catch-up solve to the backoff horizon
                self._deferred_topo = True
                return ReplanDecision(False, "backoff", ratio, threshold)
            self._fire_topology(window)
            return ReplanDecision(True, "topology", ratio, threshold)
        if self._deferred_topo and not self._flap_blocked(window):
            # the backoff horizon passed with a suppressed event on the
            # books: catch-up replan against live state (this is how the
            # fabric re-optimizes after a flap train's final restore)
            self._deferred_topo = False
            self._fire_topology(window)
            return ReplanDecision(True, "topology", ratio, threshold)
        if pending:
            return ReplanDecision(False, "none", ratio, threshold)
        if cfg.max_staleness is not None and plan_age >= cfg.max_staleness:
            self._fired(window)
            return ReplanDecision(True, "staleness", ratio, threshold)
        if (
            cfg.fabric_staleness is not None
            and self._pressure_window is not None
            and window - self._pressure_window >= cfg.fabric_staleness
        ):
            # fabric pressure: peers' prices moved while this tenant's own
            # demand stayed flat — re-price even though nothing congested
            self._pressure_window = None
            self._fired(window)
            return ReplanDecision(True, "fabric", ratio, threshold)

        # congestion trigger with hysteresis
        if not self._armed and ratio < baseline_ratio * cfg.rearm_factor:
            self._armed = True
            self._breach = 0
        if self._armed and ratio > threshold:
            self._breach += 1
        else:
            self._breach = 0
        cooled = (
            self._last_trigger is None
            or window - self._last_trigger >= cfg.cooldown_windows
        )
        if self._armed and self._breach >= cfg.patience and cooled:
            self._fired(window)
            return ReplanDecision(True, "congestion", ratio, threshold)
        return ReplanDecision(False, "none", ratio, threshold)

    def _fired(self, window: int) -> None:
        self._armed = False
        self._breach = 0
        self._last_trigger = window

    def state_snapshot(self) -> dict:
        """The trigger state machine as one numeric-only dict (DESIGN.md
        §11) — armed/breach/backoff internals that previously had no
        outward-facing surface, for the flight recorder's gauges and for
        post-mortem "why didn't it replan?" queries."""
        return {
            "armed": bool(self._armed),
            "breach": int(self._breach),
            "last_trigger": self._last_trigger,
            "pressure_window": self._pressure_window,
            "flap_level": int(self._flap_level),
            "topo_block_until": self._topo_block_until,
            "deferred_topo": bool(self._deferred_topo),
        }

    # -- flap backoff ----------------------------------------------------------
    def _flap_blocked(self, window: int) -> bool:
        """Inside the topology-trigger backoff window?"""
        return (
            self.cfg.flap_backoff_base > 0
            and self._topo_block_until is not None
            and window < self._topo_block_until
        )

    def _fire_topology(self, window: int) -> None:
        """Record a topology-triggered replan and arm the next backoff.

        Fires inside ``flap_reset_windows`` of the previous one escalate
        the backoff level (geometric growth toward ``flap_backoff_max``);
        a longer quiet period resets to the base, so isolated failures
        keep replanning immediately.
        """
        cfg = self.cfg
        if cfg.flap_backoff_base > 0:
            if (
                self._last_topo_fire is not None
                and window - self._last_topo_fire <= cfg.flap_reset_windows
            ):
                self._flap_level += 1
            else:
                self._flap_level = 0
            backoff = min(
                cfg.flap_backoff_base
                * cfg.flap_backoff_factor ** self._flap_level,
                float(cfg.flap_backoff_max),
            )
            self._topo_block_until = window + int(round(backoff))
        self._last_topo_fire = window
        # a direct fire subsumes any deferred catch-up: the solve it
        # triggers already sees the latest topology
        self._deferred_topo = False
        self._fired(window)

    def notify_swap(self, solved_window: Optional[int] = None) -> None:
        """Re-arm when a new plan becomes active.

        Disarming exists to stop re-fire storms *while the triggering
        plan is still active*; once the swap lands, the new plan is judged
        against its own baseline from a clean state.  Without this, a plan
        solved on transitional (mid-drift) demand whose ratio never falls
        below the re-arm watermark would pin the policy disarmed forever.

        A swap also satisfies a pending fabric-pressure deadline — but
        only one the incoming plan could actually have seen: the plan was
        priced at ``solved_window``, so a hint that arrived *after* the
        solve was issued describes a fabric shift the plan missed, and its
        clock must keep running.  ``solved_window=None`` (callers without
        solve provenance) conservatively clears.
        """
        self._armed = True
        self._breach = 0
        if (
            solved_window is None
            or self._pressure_window is None
            or self._pressure_window <= solved_window
        ):
            self._pressure_window = None

    def notify_gated(self) -> None:
        """Re-arm when the fabric admission gate cancels a fired trigger.

        :meth:`decide` disarmed on firing, but the gate suppressed the
        replan — no solve, no swap, so :meth:`notify_swap` will never run.
        Without re-arming here, a congestion trigger under persistent
        drift (ratio never falls below the re-arm watermark) would stay
        disarmed forever and the tenant would never replan again even
        after its tokens refill.  The trigger cooldown still spaces the
        retries.
        """
        self._armed = True
        self._breach = 0

    def notify_fabric_pressure(self, window: int) -> None:
        """Start (or keep) the soft fabric-staleness clock at ``window``.

        Called by the controller when a :class:`~repro.runtime.events.
        PricesMovedHint` arrives from the fabric arbiter.  The earliest
        hint wins — repeated hints while the deadline is already running
        must not push it out, or a chatty fabric would starve the trigger.
        No-op unless ``PolicyConfig.fabric_staleness`` is set (the default
        keeps arbitrated runtimes byte-identical to pre-hint behavior).
        """
        if self._pressure_window is None:
            self._pressure_window = window


class NeverReplan(ReplanPolicy):
    """Static one-shot baseline: plan once, never again (topology included)."""

    def decide(self, *, window, ratio, baseline_ratio, plan_age, pending,
               topology_event=False) -> ReplanDecision:
        return ReplanDecision(
            False, "none", ratio, baseline_ratio * self.cfg.degrade_factor
        )
