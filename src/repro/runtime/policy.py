"""Replan triggers with hysteresis — the *decide* stage of the runtime loop.

A replan costs planner time, a plan-cache probe, and (amortized) jit solve
latency, so the policy's job is asymmetric: fire promptly when the active
plan has genuinely degraded, and **never** fire on balanced traffic — the
paper's "matches baseline under balanced traffic" claim is a statement
about this trigger, not about the planner.

The congestion signal is *self-calibrated*: every plan records its own
``baseline_ratio`` — predicted max normalized load Z over the cut lower
bound Z* — at solve time (even a perfect plan sits somewhat above the
bound, and how far depends on topology and skew).  The trigger compares
the current ratio against ``baseline_ratio * degrade_factor`` rather than
an absolute constant, so a plan is replaced when *it* got worse, not when
the workload is intrinsically hard.

Hysteresis has three guards:

  * **patience** — the threshold must be breached ``patience`` consecutive
    windows (raise above 1 when the demand estimator is noisier than the
    default EWMA, at the cost of one extra stale window per drift);
  * **arming** — after a trigger the policy disarms until the ratio falls
    back under ``baseline_ratio * rearm_factor`` (no re-fire storms while
    a replan is being absorbed);
  * **cooldown** — a minimum number of windows between triggers.

Three triggers bypass the congestion hysteresis: a **staleness deadline**
(optional: plans older than ``max_staleness`` windows replan regardless,
for deployments whose drift is slow but unbounded), **topology events**
(link down/degraded — always replan, immediately), and **fabric
pressure** (a "prices moved" hint from the fabric arbiter — peers'
committed load shifted materially — is treated as a *soft staleness
deadline*: within ``fabric_staleness`` windows of the hint the tenant
replans with ``reason="fabric"`` even if its own demand is perfectly
stable, so it re-prices the fabric it actually shares; see
``FabricArbiter`` price hints, DESIGN.md §4.3).  The constructor default
``fabric_staleness=None`` keeps hand-wired runtimes bit-identical to the
pre-hint behavior; **arbitrated sessions** enable it with the calibrated
``repro.api.FABRIC_STALENESS_DEFAULT`` (2 windows — one boundary of
grace so an in-flight replan can absorb the shift, calibrated on the
mutual-drift scenarios in ``benchmarks/bench_fairness.py``).  The trigger
covers tenants with *no* replan in flight; the complementary issue→swap
staleness window is closed by the controller's swap-boundary re-pricing
(``OrchestrationRuntime._maybe_swap`` + ``FabricArbiter.reprice``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    degrade_factor: float = 1.15  # trigger: ratio > baseline * degrade_factor
    rearm_factor: float = 1.05    # re-arm: ratio < baseline * rearm_factor
    patience: int = 1             # consecutive breaching windows to fire
    cooldown_windows: int = 2     # min windows between congestion triggers
    max_staleness: Optional[int] = None  # windows; None = no deadline
    # windows between a fabric "prices moved" hint and a forced replan
    # (soft staleness deadline); None disables the fabric-pressure trigger
    # (hand-wired default — arbitrated Sessions pass the calibrated
    # repro.api.FABRIC_STALENESS_DEFAULT instead)
    fabric_staleness: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ReplanDecision:
    replan: bool
    # "topology" | "congestion" | "staleness" | "fabric" | "none"; an
    # arbitrated controller may rewrite a positive decision to
    # replan=False with reason "gated" when the fabric admission gate
    # throttles the tenant
    reason: str
    ratio: float
    threshold: float


class ReplanPolicy:
    """Stateful trigger evaluation; one instance per runtime."""

    def __init__(self, cfg: PolicyConfig | None = None):
        self.cfg = cfg or PolicyConfig()
        self._breach = 0
        self._armed = True
        self._last_trigger: Optional[int] = None
        self._pressure_window: Optional[int] = None

    def decide(
        self,
        *,
        window: int,
        ratio: float,
        baseline_ratio: float,
        plan_age: int,
        pending: bool,
        topology_event: bool = False,
    ) -> ReplanDecision:
        """Evaluate the triggers for one window.

        ``ratio`` is the active plan's predicted-congestion ratio on the
        estimator's next-window demand; ``baseline_ratio`` its ratio at
        solve time; ``plan_age`` windows since the active plan was solved;
        ``pending`` whether a replan is already in flight (congestion and
        staleness stand down; topology events do not — the controller
        discards the in-flight plan, which was solved for dead geometry).
        """
        cfg = self.cfg
        threshold = baseline_ratio * cfg.degrade_factor
        if topology_event:
            self._fired(window)
            return ReplanDecision(True, "topology", ratio, threshold)
        if pending:
            return ReplanDecision(False, "none", ratio, threshold)
        if cfg.max_staleness is not None and plan_age >= cfg.max_staleness:
            self._fired(window)
            return ReplanDecision(True, "staleness", ratio, threshold)
        if (
            cfg.fabric_staleness is not None
            and self._pressure_window is not None
            and window - self._pressure_window >= cfg.fabric_staleness
        ):
            # fabric pressure: peers' prices moved while this tenant's own
            # demand stayed flat — re-price even though nothing congested
            self._pressure_window = None
            self._fired(window)
            return ReplanDecision(True, "fabric", ratio, threshold)

        # congestion trigger with hysteresis
        if not self._armed and ratio < baseline_ratio * cfg.rearm_factor:
            self._armed = True
            self._breach = 0
        if self._armed and ratio > threshold:
            self._breach += 1
        else:
            self._breach = 0
        cooled = (
            self._last_trigger is None
            or window - self._last_trigger >= cfg.cooldown_windows
        )
        if self._armed and self._breach >= cfg.patience and cooled:
            self._fired(window)
            return ReplanDecision(True, "congestion", ratio, threshold)
        return ReplanDecision(False, "none", ratio, threshold)

    def _fired(self, window: int) -> None:
        self._armed = False
        self._breach = 0
        self._last_trigger = window

    def notify_swap(self, solved_window: Optional[int] = None) -> None:
        """Re-arm when a new plan becomes active.

        Disarming exists to stop re-fire storms *while the triggering
        plan is still active*; once the swap lands, the new plan is judged
        against its own baseline from a clean state.  Without this, a plan
        solved on transitional (mid-drift) demand whose ratio never falls
        below the re-arm watermark would pin the policy disarmed forever.

        A swap also satisfies a pending fabric-pressure deadline — but
        only one the incoming plan could actually have seen: the plan was
        priced at ``solved_window``, so a hint that arrived *after* the
        solve was issued describes a fabric shift the plan missed, and its
        clock must keep running.  ``solved_window=None`` (callers without
        solve provenance) conservatively clears.
        """
        self._armed = True
        self._breach = 0
        if (
            solved_window is None
            or self._pressure_window is None
            or self._pressure_window <= solved_window
        ):
            self._pressure_window = None

    def notify_gated(self) -> None:
        """Re-arm when the fabric admission gate cancels a fired trigger.

        :meth:`decide` disarmed on firing, but the gate suppressed the
        replan — no solve, no swap, so :meth:`notify_swap` will never run.
        Without re-arming here, a congestion trigger under persistent
        drift (ratio never falls below the re-arm watermark) would stay
        disarmed forever and the tenant would never replan again even
        after its tokens refill.  The trigger cooldown still spaces the
        retries.
        """
        self._armed = True
        self._breach = 0

    def notify_fabric_pressure(self, window: int) -> None:
        """Start (or keep) the soft fabric-staleness clock at ``window``.

        Called by the controller when a :class:`~repro.runtime.events.
        PricesMovedHint` arrives from the fabric arbiter.  The earliest
        hint wins — repeated hints while the deadline is already running
        must not push it out, or a chatty fabric would starve the trigger.
        No-op unless ``PolicyConfig.fabric_staleness`` is set (the default
        keeps arbitrated runtimes byte-identical to pre-hint behavior).
        """
        if self._pressure_window is None:
            self._pressure_window = window


class NeverReplan(ReplanPolicy):
    """Static one-shot baseline: plan once, never again (topology included)."""

    def decide(self, *, window, ratio, baseline_ratio, plan_age, pending,
               topology_event=False) -> ReplanDecision:
        return ReplanDecision(
            False, "none", ratio, baseline_ratio * self.cfg.degrade_factor
        )
