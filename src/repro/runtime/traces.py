"""Synthetic multi-window traffic traces for the discrete-event loop.

Each trace is a ``[windows, n, n]`` float64 array of per-pair bytes — one
demand matrix per orchestration window (one all-to-all round).  Three
workload shapes cover the runtime's acceptance scenarios:

  * :func:`balanced_trace` — uniform all-pairs traffic with multiplicative
    jitter: the "NIMBLE must match the static baseline" regime;
  * :func:`drifting_skew_trace` — a receive hotspot that *moves* between
    destinations over the trace, with a linear crossfade so the drift is
    gradual (the unanticipated-cross-traffic regime the congestion
    literature identifies as the dominant latency source);
  * :func:`skew_burst_trace` — balanced background with a sudden persistent
    burst on a few pairs (the estimator's fast-attack scenario).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

MB = float(1 << 20)


def _spread(n: int, hot: Optional[int], hot_frac: float,
            bytes_per_src: float) -> np.ndarray:
    """One demand matrix: ``hot_frac`` of every source's bytes to ``hot``."""
    D = np.zeros((n, n))
    for s in range(n):
        others = [d for d in range(n) if d != s]
        if hot is None or hot == s:
            for d in others:
                D[s, d] = bytes_per_src / len(others)
            continue
        cold = [d for d in others if d != hot]
        D[s, hot] = bytes_per_src * hot_frac
        for d in cold:
            D[s, d] = bytes_per_src * (1.0 - hot_frac) / len(cold)
    return D


def balanced_trace(
    n: int,
    windows: int,
    bytes_per_src: float = 256 * MB,
    jitter: float = 0.05,
    seed: int = 0,
) -> np.ndarray:
    """Uniform all-pairs traffic with per-entry multiplicative jitter."""
    rng = np.random.default_rng(seed)
    base = _spread(n, None, 0.0, bytes_per_src)
    out = np.empty((windows, n, n))
    for w in range(windows):
        noise = 1.0 + jitter * rng.standard_normal((n, n))
        out[w] = base * np.clip(noise, 0.25, 4.0)
        np.fill_diagonal(out[w], 0.0)
    return out


def drifting_skew_trace(
    n: int,
    windows: int,
    bytes_per_src: float = 256 * MB,
    hot_frac: float = 0.7,
    dwell: int = 10,
    ramp: int = 3,
    hot_seq: Optional[Sequence[int]] = None,
    jitter: float = 0.02,
    seed: int = 0,
) -> np.ndarray:
    """Receive hotspot that migrates between destinations.

    The hotspot dwells on one destination for ``dwell`` windows, then
    crossfades linearly onto the next over ``ramp`` windows.  The default
    ``hot_seq`` alternates node groups (assuming group size ~4) so each
    migration re-routes inter-group rails, the paper's worst case.
    """
    rng = np.random.default_rng(seed)
    if hot_seq is None:
        half = max(n // 2, 1)
        hot_seq = [i % 2 * half + (i // 2) % half for i in range(windows)]
    n_phases = (windows + dwell - 1) // dwell
    hots = [hot_seq[p % len(hot_seq)] for p in range(n_phases)]
    out = np.empty((windows, n, n))
    for w in range(windows):
        phase, off = divmod(w, dwell)
        cur = _spread(n, hots[phase], hot_frac, bytes_per_src)
        if 0 < phase and off < ramp:
            # crossfade from the previous hotspot
            mix = (off + 1) / (ramp + 1)
            prev = _spread(n, hots[phase - 1], hot_frac, bytes_per_src)
            cur = mix * cur + (1.0 - mix) * prev
        noise = 1.0 + jitter * rng.standard_normal((n, n))
        out[w] = cur * np.clip(noise, 0.25, 4.0)
        np.fill_diagonal(out[w], 0.0)
    return out


def skew_burst_trace(
    n: int,
    windows: int,
    bytes_per_src: float = 256 * MB,
    burst_window: int = 5,
    burst_pairs: Optional[Sequence[tuple]] = None,
    burst_mult: float = 8.0,
    seed: int = 0,
) -> np.ndarray:
    """Balanced background; selected pairs jump ``burst_mult x`` at
    ``burst_window`` and stay hot for the rest of the trace."""
    out = balanced_trace(n, windows, bytes_per_src, jitter=0.03, seed=seed)
    if burst_pairs is None:
        burst_pairs = [(s, (s + n // 2) % n) for s in range(0, n, 2)]
    for w in range(burst_window, windows):
        for s, d in burst_pairs:
            if s != d:
                out[w, s, d] *= burst_mult
    return out
