"""Execution-time orchestration runtime (DESIGN.md §3).

The monitor -> estimate -> replan -> swap loop on top of the incidence
planner core: per-resource telemetry, EWMA + skew-burst demand estimation,
hysteresis replan triggers, a double-buffered plan cache with atomic
boundary swaps, and link-fault events that rebuild the planner tables.
Multiple runtimes sharing one fabric are coordinated by the fabric
arbiter (``repro.fabric``, DESIGN.md §4) via ``register_runtime``.
"""

from .controller import (
    OrchestrationRuntime,
    PlanHandle,
    RuntimeConfig,
    RuntimeStats,
    TraceResult,
    WindowReport,
    demand_dict,
    run_oracle,
    run_static,
    solve_plans_batch,
)
from .estimator import DemandEstimator, EstimatorConfig
from .events import (
    EventLog,
    LinkEvent,
    PricesMovedHint,
    link_degraded,
    link_down,
    link_restored,
)
from .policy import NeverReplan, PolicyConfig, ReplanDecision, ReplanPolicy
from .telemetry import LinkTelemetry, TelemetryWindow
from .traces import balanced_trace, drifting_skew_trace, skew_burst_trace

__all__ = [
    "OrchestrationRuntime",
    "PlanHandle",
    "RuntimeConfig",
    "RuntimeStats",
    "TraceResult",
    "WindowReport",
    "demand_dict",
    "run_oracle",
    "run_static",
    "solve_plans_batch",
    "DemandEstimator",
    "EstimatorConfig",
    "EventLog",
    "LinkEvent",
    "PricesMovedHint",
    "link_degraded",
    "link_down",
    "link_restored",
    "NeverReplan",
    "PolicyConfig",
    "ReplanDecision",
    "ReplanPolicy",
    "LinkTelemetry",
    "TelemetryWindow",
    "balanced_trace",
    "drifting_skew_trace",
    "skew_burst_trace",
]
