"""Demand estimation — the *estimate* stage of the runtime loop.

Turns the telemetry stream of observed per-pair byte counts into the next
window's predicted demand matrix.  Two estimators compose:

  * **EWMA** — exponentially-weighted average of per-pair observations;
    smooth under jitter, so balanced traffic with noise never looks like
    drift (the paper's "matches baseline under balanced traffic" relies on
    the predictor not chasing noise);
  * **skew-burst attack** — when an entry jumps far above its running
    average (a token-routing hotspot igniting, a tenant arriving), the
    EWMA's slow attack would under-predict for several windows; entries
    whose latest observation exceeds ``burst_ratio x`` the pre-update EWMA
    (plus an absolute floor) snap directly to the observation instead.

Decay stays EWMA-slow in both modes: a hotspot that vanishes is forgotten
gradually, which gives the replan policy hysteresis-friendly inputs.

**Degraded telemetry** (DESIGN.md §9): observation windows can be *lost*
(telemetry blackout — ``LinkTelemetry.observed_demand`` returns ``None``)
or *partial* (dropout — entries arrive as NaN).  The estimator never
poisons its state with either: a missing window (:meth:`DemandEstimator.
observe_missing`) keeps the last-good EWMA/burst state untouched, and a
partial update back-fills NaN entries from the last-good estimate before
folding.  Both decay a ``confidence`` signal (1.0 on a clean window,
halved per fully-missing window by default, proportionally for partial
loss) so consumers can tell "the fabric is calm" from "we are flying
blind on a stale prediction".
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class EstimatorConfig:
    alpha: float = 0.5               # EWMA weight on the newest observation
    burst_ratio: float = 2.5         # obs > ratio * ewma (+floor) => burst
    burst_floor_bytes: float = float(1 << 22)  # ignore bursts below 4 MB
    # confidence retained per fully-missing observation window (blackout);
    # partial windows decay proportionally to the lost-entry fraction
    confidence_decay: float = 0.5


class DemandEstimator:
    """EWMA + skew-burst next-window demand estimator (per endpoint)."""

    def __init__(self, n_devices: int, cfg: EstimatorConfig | None = None):
        self.n = n_devices
        self.cfg = cfg or EstimatorConfig()
        self._ewma: Optional[np.ndarray] = None
        self._burst: Optional[np.ndarray] = None  # [n, n] bool, latest update
        self._last: Optional[np.ndarray] = None
        self._confidence = 1.0
        self._missing_windows = 0

    @property
    def initialized(self) -> bool:
        return self._ewma is not None

    @property
    def confidence(self) -> float:
        """How fresh the estimate is: 1.0 after a clean observation window,
        decayed toward 0 by missing/partial windows (last-good fallback)."""
        return self._confidence

    @property
    def missing_windows(self) -> int:
        """Total observation windows lost (blackout) since construction."""
        return self._missing_windows

    def observe_missing(self) -> None:
        """One observation window was lost entirely (telemetry blackout).

        The last-good EWMA/burst state is kept as-is — :meth:`predict`
        keeps serving the pre-blackout estimate — and only the confidence
        decays, so the runtime can keep planning on last-good demand
        instead of snapping to zeros or crashing.
        """
        self._missing_windows += 1
        self._confidence *= self.cfg.confidence_decay

    def update(self, observed: Optional[np.ndarray]) -> None:
        """Fold one window's observed per-pair bytes into the estimate.

        ``observed=None`` degrades to :meth:`observe_missing`; NaN entries
        (partial telemetry dropout) are back-filled from the last-good
        estimate (zero before the first clean window) so corrupted
        windows never poison the EWMA, and decay confidence by the lost
        fraction.
        """
        if observed is None:
            self.observe_missing()
            return
        obs = np.asarray(observed, dtype=np.float64).copy()
        if obs.shape != (self.n, self.n):
            raise ValueError(
                f"observed shape {obs.shape} != ({self.n}, {self.n})"
            )
        missing = ~np.isfinite(obs)
        if missing.all():
            self.observe_missing()
            return
        if missing.any():
            fill = self._ewma if self._ewma is not None else 0.0
            obs = np.where(missing, fill, obs)
            frac = float(missing.mean())
            self._confidence *= 1.0 - frac * (1.0 - self.cfg.confidence_decay)
        else:
            self._confidence = 1.0
        obs = np.maximum(obs, 0.0)
        np.fill_diagonal(obs, 0.0)
        cfg = self.cfg
        if self._ewma is None:
            self._ewma = obs.copy()
            self._burst = np.zeros_like(obs, dtype=bool)
        else:
            prev = self._ewma
            self._burst = obs > (
                cfg.burst_ratio * prev + cfg.burst_floor_bytes
            )
            self._ewma = cfg.alpha * obs + (1.0 - cfg.alpha) * prev
        self._last = obs

    def predict(self) -> np.ndarray:
        """Predicted demand matrix for the next window ([n, n] bytes)."""
        if self._ewma is None:
            return np.zeros((self.n, self.n))
        pred = self._ewma.copy()
        if self._burst is not None and self._burst.any():
            # fast attack: bursting entries snap to the latest observation
            pred[self._burst] = self._last[self._burst]
        return pred

    def burst_pairs(self) -> np.ndarray:
        """Bool [n, n] mask of entries in burst mode after the last update."""
        if self._burst is None:
            return np.zeros((self.n, self.n), dtype=bool)
        return self._burst.copy()

    def reset(self) -> None:
        self._ewma = None
        self._burst = None
        self._last = None
        self._confidence = 1.0
