"""Partition-spec rules: FSDP over "data", tensor/expert parallel over "model".

Rules are keyed by parameter leaf name (path suffix) with rank templates;
stacked-layer leading axes get ``None`` prefixes automatically.  Any dim
whose size is smaller than its assigned axis falls back to replication (so
reduced smoke configs and ragged dims never fault).

The "pod" axis never appears in param specs — pods are pure data-parallel
replicas (DESIGN.md §8): parameters are replicated across pods and gradient
all-reduce crosses the DCI, which is the balanced-collective regime the
paper leaves to stock ring/tree (§IV-E).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape
from repro.sharding.context import ParallelContext

# leaf-name -> spec template (rightmost dims; missing leading dims -> None)
_RULES = {
    # embeddings / heads
    "embed": ("*", "model"),
    "lm_head": ("*", "model"),
    "dec_pos": ("*", "model"),
    # attention (col-parallel in, row-parallel out)
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    # dense mlp
    "wg": ("data", "model"),
    "wu": ("data", "model"),
    "wd": ("model", "data"),
    "w1": ("data", "model"),
    "b1": ("model",),
    "w2": ("model", "data"),
    "b2": ("*",),
    "up": ("data", "model"),
    "down": ("model", "data"),
    # router (small, replicated)
    "router": ("*", "*"),
    # mamba
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "conv_w": ("*", "model"),
    "conv_b": ("model",),
    "A_log": ("*",),
    "D": ("*",),
    "dt_bias": ("*",),
    "gate_norm": ("model",),
    # xlstm gates
    "wi": ("data", "model"),
    "wf": ("data", "model"),
    "wz": ("data", "model"),
    "wo_gate": ("data", "model"),
    "wg_x": ("data", "model"),
    "bi": ("*",),
    "bf": ("*",),
}

# MoE expert tensors: leading expert dim -> model axis (expert parallelism).
_MOE_EXPERT_LEAVES = {"wg", "wu", "wd"}


def _axis_size(ctx: ParallelContext, axis: str) -> int:
    if ctx.mesh is None:
        return 1
    return dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))[axis]


def spec_for_path(path: Tuple, leaf, ctx: ParallelContext) -> P:
    names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    leaf_name = str(names[-1])
    shape = leaf.shape
    rank = len(shape)

    template = _RULES.get(leaf_name)
    if template is None:
        return P()  # norms, scalars, unknown leaves -> replicate

    # MoE experts: [L, E, D, F]-shaped leaves (layer-stacked + expert dim)
    is_expert = (
        leaf_name in _MOE_EXPERT_LEAVES
        and any(str(n) == "blocks" for n in names)
        and rank - len(template) >= 2
    )
    if is_expert:
        # [L, E, ...]: expert dim gets the model axis, inner dims get fsdp
        inner = ["data" if i == 0 else None for i in range(len(template))]
        spec = [None] * (rank - len(template) - 1) + ["model"] + inner
    else:
        spec = [None] * (rank - len(template)) + [
            None if a == "*" else a for a in template
        ]

    # drop axes that don't divide the dim exactly (jit enforces divisibility)
    out = []
    for dim, axis in zip(shape, spec):
        if axis is None or dim % _axis_size(ctx, axis) != 0:
            out.append(None)
        else:
            out.append(axis)
    return P(*out)


def build_param_specs(params, ctx: ParallelContext):
    """Pytree of PartitionSpec matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_path(path, leaf, ctx), params
    )


def build_param_shardings(params, ctx: ParallelContext):
    specs = build_param_specs(params, ctx)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs)


# --------------------------------------------------------------------------- #
# batch / cache specs
# --------------------------------------------------------------------------- #


def batch_axes(ctx: ParallelContext) -> Tuple[str, ...]:
    """Axes that shard the batch dim (pod + data)."""
    return tuple(a for a in ctx.data_axes)


def batch_spec(ctx: ParallelContext, global_batch: int) -> P:
    axes = []
    remaining = global_batch
    for a in batch_axes(ctx):
        sz = _axis_size(ctx, a)
        if remaining % sz == 0 and sz > 1:
            axes.append(a)
            remaining //= sz
    if not axes:
        return P(None)
    return P(tuple(axes))


def input_specs_sharding(model_inputs, ctx: ParallelContext,
                         shape: InputShape):
    """NamedShardings for a dict of ShapeDtypeStructs (dry-run inputs)."""
    bspec = batch_spec(ctx, shape.global_batch)

    def one(name, s):
        if s.ndim == 0:
            return NamedSharding(ctx.mesh, P())
        parts = [bspec[0] if bspec != P(None) else None]
        parts += [None] * (s.ndim - 1)
        # modality stubs: shard embedding dim over model
        if name in ("frames", "patches") and s.ndim == 3:
            parts[-1] = "model" if _axis_size(ctx, "model") <= s.shape[-1] else None
        return NamedSharding(ctx.mesh, P(*parts))

    return {k: one(k, v) for k, v in model_inputs.items()}


def cache_spec_rules(ctx: ParallelContext):
    """KV / state caches: heads (or inner channels) over model, batch over data."""
    def spec(path, leaf):
        shape = leaf.shape
        names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
        leaf_name = names[-1] if names else ""
        if leaf_name in ("k", "v") and len(shape) >= 4:
            # [L, B, Hkv, S, dh] or [B, Hkv, S, dh]
            parts = [None] * len(shape)
            if shape[-4] % _axis_size(ctx, "data") == 0:
                parts[-4] = "data"
            m = _axis_size(ctx, "model")
            if shape[-3] % m == 0:
                parts[-3] = "model"          # shard KV heads (GQA permitting)
            elif shape[-2] % m == 0:
                parts[-2] = "model"          # else sequence-shard the cache
            return P(*parts)
        if leaf_name in ("C", "n", "ssm", "conv") and len(shape) >= 2:
            parts = [None] * len(shape)
            # batch dim position: [L?, B, ...] — find the first dim >= data size
            ds = _axis_size(ctx, "data")
            for i, d in enumerate(shape):
                if ds > 1 and d % ds == 0 and d >= ds:
                    parts[i] = "data"
                    break
            # shard the channel dim over model if divisible
            ms = _axis_size(ctx, "model")
            if parts[-1] is None and shape[-1] % ms == 0 and shape[-1] >= ms:
                parts[-1] = "model"
            return P(*parts)
        return P()
    return spec


def build_cache_specs(cache, ctx: ParallelContext):
    return jax.tree_util.tree_map_with_path(cache_spec_rules(ctx), cache)
