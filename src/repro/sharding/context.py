"""Parallelism context threaded through the model zoo.

Carries the mesh + axis roles so model code stays declarative:

  * ``data_axes`` — axes sharding batch/tokens (includes "pod": the pod axis
    is pure data-parallel, DESIGN.md §8);
  * ``model_axis`` — tensor/expert-parallel axis; this is also the NIMBLE
    orchestration axis (the paper's technique rides the EP all-to-all);
  * ``ep_size``/``moe_mode``/``group_size`` — expert-parallel group geometry
    for :class:`repro.core.MoEDispatcher` (group_size chips = one "node").

``ParallelContext(None)`` (default) means single-device execution — used by
CPU smoke tests; the MoE layer then computes experts locally.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Optional[object] = None          # jax.sharding.Mesh
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    ep_size: int = 1
    group_size: int = 4
    moe_mode: str = "nimble"               # nimble | direct | stripe
    moe_chunk_tokens: int = 16
    moe_alt_frac: float = 0.5
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    remat: bool = False                    # activation checkpoint per block
    # optional repro.api.Session supplying ready-wired MoE dispatchers
    # (cost model, planner config, runtime telemetry); None keeps the
    # historical hand-wired MoEDispatcher construction (DESIGN.md §5)
    session: Optional[object] = None

    @property
    def token_axes(self) -> Tuple[str, ...]:
        """All axes across which flattened tokens are sharded for EP."""
        return tuple(self.data_axes) + (self.model_axis,)


def constrain_tokens(x, ctx: "ParallelContext"):
    """Pin a [B, S, D] activation's batch dim to the data axes.

    XLA's sharding propagation sometimes trades the batch sharding away to
    shard attention heads instead — replicating the FULL global batch per
    device (observed on zamba2's shared-attention block: 768 GB/device
    peak, EXPERIMENTS.md §Perf PAIR D).  A no-op without a mesh.
    """
    if ctx.mesh is None:
        return x
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = P(tuple(ctx.data_axes), None, None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


SINGLE = ParallelContext()
