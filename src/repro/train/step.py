"""Train step factory: value_and_grad + AdamW update + metrics."""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.optim import adamw


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig, *,
                    window=None):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch, window=window)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, metrics = adamw.update(
            opt_cfg, params, grads, opt_state
        )
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model, *, window=None):
    def eval_step(params, batch):
        return model.loss(params, batch, window=window)
    return eval_step
