"""AdamW from scratch (no optax in this environment) + schedules + clipping.

Standard decoupled weight decay (Loshchilov & Hutter), bias-corrected
moments, global-norm gradient clipping, cosine schedule with linear warmup.
State is a pytree mirroring params: {m, v, step}.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(
    cfg: AdamWConfig, params, grads, state: OptState
) -> Tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, step), metrics
