"""Fabric arbiter — weighted congestion pricing over N tenants (DESIGN.md §4).

The per-tenant planners (host ``mcf.solve_mwu``, the runtime's jitted
``plan_flows_batch``) are endpoint-greedy: each minimizes *its own* max
normalized load on a fabric it believes is empty.  With several tenants on
one fabric that belief is wrong, and independent replanning stacks every
tenant onto the same cheap paths.  :class:`FabricArbiter` is the thin
coordination layer above those planners:

  * it owns the shared :class:`~repro.fabric.state.FabricState` ledger of
    per-tenant committed load;
  * it exports **prices** — a tenant's external load scaled by its weight —
    which the solvers accept via ``ext_loads`` (priced during the solve,
    excluded from the plan's own accounting);
  * :meth:`arbitrate` iterates sequential-greedy sweeps over all tenants in
    a canonical order until plans stop moving, a best-response dynamic
    whose fixed point is a weighted congestion equilibrium;
  * :meth:`admit` is the replan admission gate (token bucket + QoS), and
    :meth:`broadcast` fans link events out to every registered tenant via
    the shared :class:`~repro.core.topology.LinkEventBus`.

Zero-overhead degradation: with a single registered tenant the external
load is identically zero, :meth:`prices_for` returns ``None``, the gate
admits everything, and every solve takes the exact unarbitrated code path
— plans are bit-identical to today's ``solve_mwu`` /
``OrchestrationRuntime`` output (enforced by ``tests/test_fabric.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional

import numpy as np

from ..core.cost import CostModel
from ..core.mcf import PairKey, Plan, solve_mwu
from ..core.topology import LinkEventBus, Topology
from ..jsonio import tag
from ..runtime.events import PricesMovedHint, merge_overrides
from .admission import AdmissionConfig, AdmissionDecision, TokenBucket
from .fairness import fairness_report
from .state import FabricState

#: canonical planning/priority order of QoS classes (lower rank first)
QOS_RANK = {"gold": 0, "standard": 1, "scavenger": 2}


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """Per-tenant share and service class.

    ``weight`` scales exported prices by ``1/weight``: a weight-2 tenant
    sees peers' load at half price, bids more aggressively for contested
    resources, and converges to roughly twice the share — weighted
    congestion pricing.  ``qos`` orders the greedy sweeps and selects
    admission-gate bypass (``gold``).
    """

    weight: float = 1.0
    qos: str = "standard"
    admission: AdmissionConfig = dataclasses.field(
        default_factory=AdmissionConfig
    )

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {self.weight}")
        if self.qos not in QOS_RANK:
            raise ValueError(
                f"unknown qos class {self.qos!r}; one of {sorted(QOS_RANK)}"
            )


@dataclasses.dataclass(frozen=True)
class ArbiterConfig:
    n_sweeps: int = 3   # max sequential-greedy sweeps per arbitrate() call
    # publish a "prices moved" hint on the bus when a commit shifts the
    # total committed load by more than this fraction of the peak load
    # (the arbiter-aware replan trigger, DESIGN.md §4.3); <= 0 disables —
    # and also disables swap-boundary re-pricing, which reuses this
    # threshold to decide whether a pending plan's prices went stale
    price_hint_rel: float = 0.25
    # recency half-life (windows) for exported prices: a peer's *stamped*
    # committed load is weighted by 0.5 ** (staleness / price_decay) in
    # prices_for, so telemetry that stops refreshing fades out of every
    # other tenant's solve.  None = raw ledger prices, byte-identical to
    # the undecayed arbiter; unstamped (host) commits never decay.
    price_decay: Optional[float] = None
    # crash eviction (DESIGN.md §9): a tenant whose last commit is at
    # least this many fabric windows stale has stopped heartbeating and is
    # unregistered outright — its ledger entry withdrawn so survivors stop
    # pricing around a ghost.  None disables (a silent tenant is only ever
    # faded by price_decay, never dropped).  Unstamped (host) commits have
    # no staleness and are never evicted.
    evict_staleness: Optional[float] = None


@dataclasses.dataclass
class ArbiterStats:
    solves: int = 0        # tenant solves issued by arbitrate()
    sweeps: int = 0        # greedy sweeps executed
    admitted: int = 0      # gate passes (incl. bypasses)
    throttled: int = 0     # gate denials
    broadcasts: int = 0    # link-event batches published
    commits: int = 0       # ledger commits
    price_hints: int = 0   # "prices moved" hints published
    reprices: int = 0      # swap-boundary re-price verdicts (stale pendings)
    evictions: int = 0     # tenants dropped for heartbeat staleness

    def to_json_obj(self) -> dict:
        return tag("fabric_arbiter_stats", dataclasses.asdict(self))


@dataclasses.dataclass(frozen=True)
class RepriceDecision:
    """Verdict of a swap-boundary re-price check (:meth:`FabricArbiter.
    reprice`): whether the prices a pending plan was solved under moved
    materially (past ``price_hint_rel``) since issue, the relative move,
    and the live price vector to re-solve against."""

    moved: bool
    rel_change: float
    prices: Optional[np.ndarray]


def _same_prices(a: Optional[np.ndarray], b: Optional[np.ndarray]) -> bool:
    if a is None or b is None:
        return a is None and b is None
    return np.array_equal(a, b)


def _price_rel_change(a: Optional[np.ndarray], b: Optional[np.ndarray]) -> float:
    """Relative movement between two price vectors: peak absolute change
    over the peak price across both (``None`` counts as all-zero), the
    same normalization the publish-side hint uses on committed loads."""
    if a is None and b is None:
        return 0.0
    if a is None:
        a = np.zeros_like(b)
    elif b is None:
        b = np.zeros_like(a)
    scale = max(float(a.max()), float(b.max()))
    if scale <= 0.0:
        return 0.0
    return float(np.max(np.abs(a - b))) / scale


class FabricArbiter:
    """Shared congestion-pricing layer above per-tenant MWU planners."""

    def __init__(
        self,
        topo: Topology,
        cost_model: CostModel | None = None,
        cfg: ArbiterConfig | None = None,
    ):
        self.cfg = cfg or ArbiterConfig()
        self.state = FabricState(topo, cost_model)
        self.bus = LinkEventBus()
        self.stats = ArbiterStats()
        self._tenants: Dict[str, TenantConfig] = {}
        self._gates: Dict[str, TokenBucket] = {}
        self._runtimes: Dict[str, object] = {}
        self._bus_tokens: Dict[str, int] = {}
        self._hinted_load: Optional[np.ndarray] = None
        # flight recorder (repro.obs, DESIGN.md §11) — None keeps every
        # hook below a single branch; fabric events land on one "fabric"
        # trace track shared by all tenants
        self._obs = None

    def attach_recorder(self, recorder) -> None:
        """Attach a :class:`repro.obs.FlightRecorder` (None/disabled detaches).

        Idempotent — every Session joining a shared fabric attaches the
        same recorder; last attach wins, which is a no-op for one recorder.
        """
        if recorder is None or not getattr(recorder, "enabled", False):
            self._obs = None
        else:
            self._obs = recorder

    @classmethod
    def from_session(cls, session) -> "FabricArbiter":
        """Build the shared arbiter for a :class:`repro.api.Session`.

        Narrow construction hook (DESIGN.md §5): duck-typed on
        ``session.topo`` / ``session.cost_model`` / ``session.spec.
        arbiter``, so this module never imports ``repro.api``.  Sessions
        that *join* an existing fabric pass it via ``SessionSpec.fabric``
        instead of constructing one here.  ``spec.arbiter_config()`` folds
        the session-level calibrated ``price_decay`` into the arbiter
        config.
        """
        return cls(
            session.topo, session.cost_model,
            cfg=session.spec.arbiter_config(),
        )

    # -- registration -----------------------------------------------------------
    def register(self, name: str, cfg: TenantConfig | None = None) -> str:
        """Register a tenant by name; returns the name for chaining."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        cfg = cfg or TenantConfig()
        self._tenants[name] = cfg
        self._gates[name] = TokenBucket(cfg.admission)
        return name

    def register_runtime(
        self, name: str, runtime, cfg: TenantConfig | None = None
    ) -> str:
        """Register an :class:`~repro.runtime.OrchestrationRuntime` tenant.

        Binds the runtime to this arbiter (its solves pick up exported
        prices, its replans pass through the gate, its executed loads are
        committed to the ledger every window) and subscribes it to the
        event bus so broadcast link events land in its own event log.
        """
        # structural check: same geometry and base capacities.  The final
        # fingerprint component (per-link degradation scales) is excluded —
        # a broadcast event rebuilds the ledger's scales immediately while
        # runtimes apply theirs at window boundaries, so transient scale
        # divergence between the two views is expected, not an error.
        if runtime.topo.fingerprint[:-1] != self.state.fingerprint[:-1]:
            raise ValueError(
                f"tenant {name!r} topology disagrees with the fabric's — "
                "all tenants must share one fabric geometry"
            )
        self.register(name, cfg)
        runtime.bind_arbiter(self, name)
        self._runtimes[name] = runtime

        def _deliver(events, rt=runtime, me=name):
            # one bus, two payload kinds: LinkEvents land in the tenant's
            # own event log (applied at its window boundaries), while
            # "prices moved" hints go straight to the fabric-pressure
            # clock — skipping the committer itself, whose own commit
            # never moves its own exported prices
            for ev in events:
                if isinstance(ev, PricesMovedHint):
                    if ev.tenant != me:
                        rt.notify_fabric_pressure()
                else:
                    rt.events.schedule(ev)

        self._bus_tokens[name] = self.bus.subscribe(_deliver)
        return name

    def unregister(self, name: str) -> None:
        """Drop a tenant: withdraw its load, unbind, unsubscribe.

        **Idempotent** (pinned by ``tests/test_faults.py``): unregistering
        a name that is unknown — or already unregistered by a racing
        teardown path (session close vs. staleness eviction) — is a no-op
        end to end; every sub-step tolerates the missing entry, including
        ``FabricState.withdraw``.
        """
        self._tenants.pop(name, None)
        self._gates.pop(name, None)
        self.state.withdraw(name)
        runtime = self._runtimes.pop(name, None)
        if runtime is not None:
            runtime.bind_arbiter(None, None)
        token = self._bus_tokens.pop(name, None)
        if token is not None:
            self.bus.unsubscribe(token)
        # a departing tenant's withdrawn load is a price move for every
        # survivor — without this, a demand-stable tenant keeps routing
        # around a peer that is long gone.  ``require_peers=False``: the
        # hint matters even (especially) when one tenant remains.
        self._maybe_publish_price_hint(name, require_peers=False)

    def tenants(self) -> List[str]:
        return list(self._tenants)

    def tenant_order(self, names: Iterable[str] | None = None) -> List[str]:
        """Canonical sweep order: QoS rank, then name.

        Registration order is deliberately *not* part of the key, so two
        arbiters registered in different orders produce identical plans
        (ordering-determinism invariant, ``tests/test_fabric.py``).
        """
        names = self.tenants() if names is None else list(names)
        for t in names:
            if t not in self._tenants:
                raise KeyError(f"tenant {t!r} not registered")
        return sorted(names, key=lambda t: (QOS_RANK[self._tenants[t].qos], t))

    # -- pricing ----------------------------------------------------------------
    def prices_for(self, name: str) -> Optional[np.ndarray]:
        """Exported prices for ``name``: external load over tenant weight.

        ``None`` (not a zero vector) when no peer has committed load, so
        callers can take the exact unarbitrated solve path — the
        single-tenant zero-overhead contract.  Prices are non-negative and
        elementwise monotone in peers' committed load by construction.

        With ``ArbiterConfig.price_decay`` set, each peer's contribution is
        recency-weighted (``FabricState.decay_factor``): stale telemetry
        fades with a ``price_decay``-window half-life instead of steering
        this tenant's solve forever, and the decayed prices are monotone
        non-increasing in staleness.  ``price_decay=None`` exports the raw
        ledger — byte-identical to the pre-recency arbiter.
        """
        if name not in self._tenants:
            raise KeyError(f"tenant {name!r} not registered")
        ext = self.state.external_load(name, half_life=self.cfg.price_decay)
        if not ext.any():
            return None
        return ext / self._tenants[name].weight

    def reprice(
        self, name: str, solved_prices: Optional[np.ndarray]
    ) -> RepriceDecision:
        """Swap-boundary re-price check (DESIGN.md §4.3).

        ``OrchestrationRuntime`` calls this when a pending plan reaches its
        swap boundary, passing the prices the plan was *solved* under.  The
        verdict compares them against the live ``prices_for(name)``: when
        the peak relative move is at least ``price_hint_rel``, the plan is
        priced stale — the fabric shifted inside the issue→swap window —
        and the caller should swap it in anyway (it is fresher than the
        active plan) but immediately re-solve the same demand against
        ``decision.prices`` and park the refinement as the next pending
        (swap-and-refine, see ``OrchestrationRuntime._maybe_swap``).
        ``price_hint_rel <= 0`` disables repricing (never moved),
        mirroring the publish-side hint switch.  Read-only: no ledger or
        gate state changes; only ``stats.reprices`` counts the stale
        verdicts.
        """
        prices = self.prices_for(name)
        rel = _price_rel_change(solved_prices, prices)
        moved = self.cfg.price_hint_rel > 0 and rel >= self.cfg.price_hint_rel
        if moved:
            self.stats.reprices += 1
        if self._obs is not None:
            self._obs.tracer.instant(
                "reprice", "fabric", "fabric",
                {"tenant": name, "moved": moved,
                 "rel_change": round(rel, 4)},
            )
        return RepriceDecision(moved=moved, rel_change=rel, prices=prices)

    def commit(
        self,
        name: str,
        resource_bytes: np.ndarray,
        window: Optional[int] = None,
        fingerprint: Optional[tuple] = None,
    ) -> None:
        """Telemetry export: replace ``name``'s committed load in the ledger.

        ``window`` stamps the commit for recency decay (runtime tenants
        pass their window counter; host commits stay unstamped/timeless);
        ``fingerprint`` is validated against the fabric's — see
        ``FabricState.commit``.
        """
        if name not in self._tenants:
            raise KeyError(f"tenant {name!r} not registered")
        self.state.commit(
            name, resource_bytes, window=window, fingerprint=fingerprint
        )
        self.stats.commits += 1
        if self._obs is not None:
            self._obs.tracer.instant(
                "commit", "fabric", "fabric",
                {"tenant": name, "window": window},
            )
        self._maybe_publish_price_hint(name)
        self._maybe_evict()

    def _maybe_evict(self) -> None:
        """Unregister tenants whose heartbeat went stale (DESIGN.md §9).

        Piggybacked on :meth:`commit` — a live tenant's heartbeat is what
        advances the fabric clock, so eviction needs no timer of its own.
        A crashed tenant's committed load first fades under ``price_decay``
        (survivors gradually stop routing around it) and is withdrawn
        outright once ``evict_staleness`` windows pass with no commit;
        ``unregister`` makes a later teardown of the crashed session a
        harmless double-unregister.
        """
        threshold = self.cfg.evict_staleness
        if threshold is None:
            return
        stale = [
            t for t in self._tenants
            if (s := self.state.staleness(t)) is not None and s >= threshold
        ]
        for t in stale:
            self.unregister(t)
            self.stats.evictions += 1
            if self._obs is not None:
                self._obs.tracer.instant(
                    "evict", "fabric", "fabric",
                    {"tenant": t, "staleness": self.state.clock},
                )

    def _maybe_publish_price_hint(
        self, committer: str, require_peers: bool = True
    ) -> None:
        """Publish a :class:`~repro.runtime.events.PricesMovedHint` when
        the ledger moved materially since the last hint.

        The relative change is measured against the peak committed load
        (``max`` over both snapshots), so a fabric ramping up from idle
        registers as a full move while steady-state telemetry jitter stays
        under the threshold.  With ``require_peers`` (the commit path),
        solo fabrics never hint — part of the single-tenant zero-overhead
        contract; withdrawal passes ``False`` because the survivors of a
        departure must learn about it no matter how few remain.

        A hint with nobody listening is pure noise: when the bus has no
        subscribers (``unregister`` removes the departing tenant's
        subscription *before* hinting, so the last runtime's own departure
        leaves the bus empty), nothing is published, ``stats.price_hints``
        stays put, and the hinted-load watermark is left alone — a
        subscriber arriving later still sees the accumulated move against
        the last snapshot that was actually delivered.
        """
        if self.cfg.price_hint_rel <= 0:
            return
        if require_peers and len(self._tenants) < 2:
            return
        if len(self.bus) == 0:
            return
        total = self.state.total_load()
        rel = _price_rel_change(total, self._hinted_load)
        if rel < self.cfg.price_hint_rel:
            return
        self._hinted_load = total.copy()
        self.stats.price_hints += 1
        self.bus.publish([
            PricesMovedHint(
                tenant=committer, rel_change=rel, clock=self.state.clock
            )
        ])

    # -- admission --------------------------------------------------------------
    def admit(
        self, name: str, window: int, reason: str = "congestion"
    ) -> AdmissionDecision:
        """Gate one replan request (see :mod:`repro.fabric.admission`)."""
        if name not in self._tenants:
            raise KeyError(f"tenant {name!r} not registered")
        gate = self._gates[name]
        if reason == "topology":
            verdict = AdmissionDecision(True, "topology", gate.tokens(window))
        elif len(self._tenants) < 2:
            verdict = AdmissionDecision(True, "solo", gate.tokens(window))
        elif self._tenants[name].qos == "gold":
            verdict = AdmissionDecision(True, "qos", gate.tokens(window))
        elif gate.try_take(window):
            verdict = AdmissionDecision(True, "ok", gate.tokens(window))
        else:
            verdict = AdmissionDecision(False, "throttled", gate.tokens(window))
        if verdict.admitted:
            self.stats.admitted += 1
        else:
            self.stats.throttled += 1
        if self._obs is not None:
            self._obs.tracer.instant(
                "admit", "fabric", "fabric",
                {"tenant": name, "window": window, "reason": reason,
                 "admitted": verdict.admitted, "verdict": verdict.reason},
            )
        return verdict

    # -- link events ------------------------------------------------------------
    def broadcast(self, events) -> int:
        """Fan one event (or a batch) out to the fabric and every tenant.

        The arbiter has no window clock, so the ledger's topology rebuilds
        **immediately** regardless of ``LinkEvent.window`` — its capacities
        feed only drain/fairness accounting, where reflecting the latest
        known fabric state is the useful behavior.  Registered runtimes
        receive the events on the bus and apply them **at their own window
        boundaries**, exactly like locally-scheduled events; same-link
        batches compose by the shared last-wins rule
        (:func:`repro.runtime.events.merge_overrides`), so the two views
        converge once the events fall due.  Returns the listener count.
        """
        evs = list(events) if isinstance(events, (list, tuple)) else [events]
        if self._obs is not None:
            for ev in evs:
                self._obs.tracer.instant(
                    "fault", "fabric", "fabric",
                    {"event": ev.describe(), "kind": ev.kind},
                )
        self.state.apply_link_overrides(dict(merge_overrides(evs)))
        self.stats.broadcasts += 1
        return self.bus.publish(evs)

    # -- host-level co-planning -------------------------------------------------
    def arbitrate(
        self,
        demands: Mapping[str, Mapping[PairKey, float]],
        n_sweeps: int | None = None,
    ) -> Dict[str, Plan]:
        """Co-plan all tenants to a priced equilibrium (sequential greedy).

        Each sweep walks the canonical tenant order; a tenant whose prices
        are unchanged since its last solve is at its best response already
        and is skipped.  Converges in practice within 2-3 sweeps (demand
        decays geometrically inside each MWU); capped at ``n_sweeps``.
        """
        order = self.tenant_order(demands)
        span = None
        if self._obs is not None:
            span = self._obs.tracer.begin(
                "arbitrate", "fabric", "fabric", {"tenants": len(order)},
            )
        plans: Dict[str, Plan] = {}
        solved_prices: Dict[str, Optional[np.ndarray]] = {}
        for _ in range(n_sweeps or self.cfg.n_sweeps):
            moved = False
            for t in order:
                prices = self.prices_for(t)
                if t in plans and _same_prices(prices, solved_prices[t]):
                    continue
                plan = solve_mwu(
                    self.state.topo, demands[t], self.state.cm,
                    ext_loads=prices,
                )
                plans[t] = plan
                solved_prices[t] = prices
                self.commit(t, plan.resource_bytes)
                self.stats.solves += 1
                moved = True
            self.stats.sweeps += 1
            if not moved:
                break
        if span is not None:
            self._obs.tracer.end(span, {"solves": self.stats.solves})
        return plans

    # -- accounting -------------------------------------------------------------
    def weights(self) -> Dict[str, float]:
        return {t: cfg.weight for t, cfg in self._tenants.items()}

    def combined_drain_s(self) -> float:
        return self.state.combined_drain_s()

    def fairness_report(self) -> dict:
        """Tagged ``nimble.fabric_fairness/v1`` record for the current ledger."""
        return fairness_report(self.state, self.weights())

    def to_json_obj(self) -> dict:
        return tag(
            "fabric_arbiter",
            {
                "tenants": self.tenant_order(),
                "weights": {t: w for t, w in sorted(self.weights().items())},
                "stats": self.stats.to_json_obj(),
                "state": self.state.to_json_obj(),
                "fairness": self.fairness_report(),
            },
        )
