"""Fairness accounting over the shared fabric ledger.

Two scalar summaries of how the fabric's capacity is split, both computed
over **weighted per-tenant drain times** ``x_i = drain_i * weight_i``
(a tenant with weight 2 is entitled to finish twice as fast on the same
demand, so scaling by the weight normalizes entitlement away):

  * **Jain's index** ``J = (sum x)^2 / (N * sum x^2)`` — 1.0 when every
    tenant drains in (weighted) lockstep, ``1/N`` when one tenant starves
    all others;
  * **weighted max-min violation** ``(max x - min x) / max x`` — 0 when
    weighted max-min fair; 1 when some tenant is fully crowded out.

Reports are emitted through the shared ``repro.jsonio`` schema
(``nimble.fabric_fairness/v1``) so benches and ``experiments/make_report``
consume them like any other record.
"""

from __future__ import annotations

from typing import Dict, Iterable, Mapping

import numpy as np

from ..jsonio import tag
from .state import FabricState


def jains_index(values: Iterable[float]) -> float:
    """Jain's fairness index over ``values`` (1.0 for empty/uniform)."""
    x = np.asarray(list(values), dtype=np.float64)
    if x.size == 0:
        return 1.0
    if (x < 0).any():
        raise ValueError("Jain's index is defined over non-negative values")
    sq = float((x * x).sum())
    if sq <= 0.0:
        return 1.0
    s = float(x.sum())
    return s * s / (x.size * sq)


def maxmin_violation(values: Iterable[float]) -> float:
    """Relative spread ``(max - min) / max``; 0.0 = max-min fair."""
    x = np.asarray(list(values), dtype=np.float64)
    if x.size <= 1:
        return 0.0
    hi = float(x.max())
    if hi <= 0.0:
        return 0.0
    return (hi - float(x.min())) / hi


def weighted_drains(
    drains: Mapping[str, float], weights: Mapping[str, float]
) -> Dict[str, float]:
    """``drain_i * weight_i`` per tenant (missing weights default to 1)."""
    return {t: d * float(weights.get(t, 1.0)) for t, d in drains.items()}


def fairness_report(
    state: FabricState, weights: Mapping[str, float] | None = None
) -> dict:
    """Tagged fairness record for the current ledger contents.

    Fairness is accounted over the **raw** committed loads — drain times
    measure bytes a tenant actually put on the fabric, so price-recency
    decay never touches them.  The record carries the recency view
    alongside (``clock``, per-tenant ``staleness``; ``None`` = unstamped)
    so report consumers can tell a fresh ledger from one whose prices have
    largely faded.
    """
    weights = weights or {}
    drains = state.drain_times()
    wd = weighted_drains(drains, weights)
    order = sorted(drains)
    return tag(
        "fabric_fairness",
        {
            "tenants": order,
            "drain_s": {t: drains[t] for t in order},
            "weights": {t: float(weights.get(t, 1.0)) for t in order},
            "weighted_drain_s": {t: wd[t] for t in order},
            "jain_index": jains_index(wd.values()),
            "maxmin_violation": maxmin_violation(wd.values()),
            "combined_drain_s": state.combined_drain_s(),
            "clock": int(state.clock),
            "staleness": {t: state.staleness(t) for t in order},
        },
    )
