"""Shared fabric ledger — per-tenant committed load over one resource vector.

:class:`FabricState` is the arbiter's view of the fabric: one resource
vector (``cost.ResourceModel``: links, relay caps, inject caps) and, per
registered tenant, the *effective bytes* that tenant currently has
committed onto each resource.  Commitments come from two producers:

  * host-level co-planning (:meth:`~repro.fabric.FabricArbiter.arbitrate`)
    commits each tenant's solved ``Plan.resource_bytes``;
  * runtime tenants export telemetry every window — the executed plan's
    per-resource loads land here via ``OrchestrationRuntime.step``.

The ledger is what congestion pricing reads: a tenant's *external load* is
everyone else's committed bytes, which the MWU solvers accept via
``ext_loads`` (priced, never accounted).  Loads are effective bytes — they
depend only on the cost model's charge multipliers, not on link capacities
— so they stay valid across link down/degrade/restore events; only the
capacity vector (used for drain-time fairness accounting) is rebuilt, keyed
by the new topology fingerprint.
"""

from __future__ import annotations

import collections
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..core.cost import CostModel, ResourceModel
from ..core.topology import Topology
from ..jsonio import tag


class FabricState:
    """Per-resource committed-load ledger shared by all tenants."""

    def __init__(self, topo: Topology, cost_model: CostModel | None = None):
        self.cm = cost_model or CostModel()
        self._committed: "collections.OrderedDict[str, np.ndarray]" = (
            collections.OrderedDict()
        )
        self._set_topology(topo)

    def _set_topology(self, topo: Topology) -> None:
        self.topo = topo
        self.rm = ResourceModel(topo, self.cm)

    # -- identity ---------------------------------------------------------------
    @property
    def fingerprint(self) -> Tuple:
        return self.topo.fingerprint

    @property
    def n_resources(self) -> int:
        return self.rm.n_resources

    # -- ledger -----------------------------------------------------------------
    def commit(self, tenant: str, resource_bytes: np.ndarray) -> None:
        """Replace ``tenant``'s committed load with ``resource_bytes`` [R]."""
        loads = np.asarray(resource_bytes, dtype=np.float64)
        if loads.shape != (self.rm.n_resources,):
            raise ValueError(
                f"committed loads shape {loads.shape} != "
                f"({self.rm.n_resources},) — tenant topology disagrees with "
                "the fabric's"
            )
        if (loads < 0).any():
            raise ValueError(f"negative committed load from tenant {tenant!r}")
        self._committed[tenant] = loads.copy()

    def withdraw(self, tenant: str) -> None:
        self._committed.pop(tenant, None)

    def committed_load(self, tenant: str) -> Optional[np.ndarray]:
        loads = self._committed.get(tenant)
        return None if loads is None else loads.copy()

    def tenants(self) -> List[str]:
        return list(self._committed)

    def total_load(self) -> np.ndarray:
        """Sum of all tenants' committed loads [R] (zeros when empty)."""
        total = np.zeros(self.rm.n_resources, dtype=np.float64)
        for loads in self._committed.values():
            total += loads
        return total

    def external_load(self, tenant: str) -> np.ndarray:
        """Everyone-but-``tenant``'s committed load [R] (always >= 0)."""
        total = self.total_load()
        own = self._committed.get(tenant)
        if own is not None:
            total -= own
        # float cancellation can leave tiny negatives; prices must not
        return np.maximum(total, 0.0)

    # -- drain accounting -------------------------------------------------------
    def drain_time_s(self, loads: np.ndarray) -> float:
        """Seconds to drain ``loads`` at current capacities (max resource)."""
        return float(np.max(loads / self.rm.capacity)) if len(loads) else 0.0

    def drain_times(self) -> Dict[str, float]:
        """Per-tenant drain time of each tenant's own committed load."""
        return {t: self.drain_time_s(l) for t, l in self._committed.items()}

    def combined_drain_s(self) -> float:
        """Drain time of the *stacked* fabric load — the co-planning metric."""
        return self.drain_time_s(self.total_load())

    # -- link events ------------------------------------------------------------
    def apply_link_overrides(
        self, overrides: Mapping[Tuple[int, int], float]
    ) -> Tuple:
        """Rescale link capacities; returns the new topology fingerprint.

        Geometry is unchanged (same resource vector length), so committed
        loads remain valid; drain accounting follows the new capacities.
        """
        self._set_topology(self.topo.with_link_scale(overrides))
        return self.fingerprint

    # -- serialization ----------------------------------------------------------
    def to_json_obj(self) -> dict:
        drains = self.drain_times()
        return tag(
            "fabric_state",
            {
                "n_resources": int(self.rm.n_resources),
                "tenants": sorted(self._committed),
                "drain_s": {t: drains[t] for t in sorted(drains)},
                "combined_drain_s": self.combined_drain_s(),
                "down_links": [int(l) for l in self.topo.down_link_ids()],
            },
        )
