"""Shared fabric ledger — per-tenant committed load over one resource vector.

:class:`FabricState` is the arbiter's view of the fabric: one resource
vector (``cost.ResourceModel``: links, relay caps, inject caps) and, per
registered tenant, the *effective bytes* that tenant currently has
committed onto each resource.  Commitments come from two producers:

  * host-level co-planning (:meth:`~repro.fabric.FabricArbiter.arbitrate`)
    commits each tenant's solved ``Plan.resource_bytes``;
  * runtime tenants export telemetry every window — the executed plan's
    per-resource loads land here via ``OrchestrationRuntime.step``.

The ledger is what congestion pricing reads: a tenant's *external load* is
everyone else's committed bytes, which the MWU solvers accept via
``ext_loads`` (priced, never accounted).  Loads are effective bytes — they
depend only on the cost model's charge multipliers, not on link capacities
— so they stay valid across link down/degrade/restore events; only the
capacity vector (used for drain-time fairness accounting) is rebuilt, keyed
by the new topology fingerprint.

**Recency.**  A committed load is only a faithful congestion signal at the
timescale it was measured, so commits may carry a **window stamp**
(telemetry exports do; host co-planning commits are *unstamped* — a solved
plan with no window clock is timeless).  The ledger keeps a fabric
``clock`` (the newest stamped window it has seen) and exposes per-tenant
``staleness``; :meth:`external_load` can apply exponential recency decay
(``half_life`` in windows, weight ``0.5 ** (staleness / half_life)``) so a
peer's load fades unless refreshed by telemetry.  ``half_life=None`` takes
the exact raw-ledger code path — byte-identical prices to the undecayed
ledger — and unstamped entries never decay at any half-life.
"""

from __future__ import annotations

import collections
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..core.cost import CostModel, ResourceModel
from ..core.topology import Topology
from ..jsonio import tag


class FabricState:
    """Per-resource committed-load ledger shared by all tenants."""

    def __init__(self, topo: Topology, cost_model: CostModel | None = None):
        self.cm = cost_model or CostModel()
        self._committed: "collections.OrderedDict[str, np.ndarray]" = (
            collections.OrderedDict()
        )
        # window stamp of each tenant's last commit (None = unstamped /
        # timeless) and the fabric clock: the newest stamped window seen
        self._stamp: Dict[str, Optional[int]] = {}
        self._clock = 0
        self._set_topology(topo)

    def _set_topology(self, topo: Topology) -> None:
        self.topo = topo
        self.rm = ResourceModel(topo, self.cm)

    # -- identity ---------------------------------------------------------------
    @property
    def fingerprint(self) -> Tuple:
        return self.topo.fingerprint

    @property
    def n_resources(self) -> int:
        return self.rm.n_resources

    # -- ledger -----------------------------------------------------------------
    def commit(
        self,
        tenant: str,
        resource_bytes: np.ndarray,
        window: Optional[int] = None,
        fingerprint: Optional[Tuple] = None,
    ) -> None:
        """Replace ``tenant``'s committed load with ``resource_bytes`` [R].

        ``window`` stamps the commit for recency accounting (telemetry
        exports pass their window counter; ``None`` leaves the entry
        unstamped/timeless — the host co-planning path).  ``fingerprint``,
        when given, is the topology fingerprint the load was *solved
        against*: a geometry/base-capacity mismatch with the fabric's is
        rejected with an error naming both fingerprints (the tenant
        exported telemetry for a different fabric — typically a
        fingerprint-keyed capacity rebuild racing a window export), while
        a mismatch only in the trailing per-link scale component is
        accepted — runtimes apply broadcast link events at their own
        window boundaries, so transient scale divergence is expected and
        effective-bytes loads stay valid across it.
        """
        if fingerprint is not None and fingerprint[:-1] != self.fingerprint[:-1]:
            raise ValueError(
                f"tenant {tenant!r} committed loads solved against topology "
                f"fingerprint {fingerprint!r}, but the fabric ledger is at "
                f"{self.fingerprint!r} — geometry/base capacities disagree "
                "(stale export across a topology rebuild?)"
            )
        loads = np.asarray(resource_bytes, dtype=np.float64)
        if loads.shape != (self.rm.n_resources,):
            raise ValueError(
                f"committed loads shape {loads.shape} != "
                f"({self.rm.n_resources},) — tenant topology disagrees with "
                "the fabric's (pass the solve's topology fingerprint to "
                "commit() to get the mismatch named explicitly)"
            )
        if (loads < 0).any():
            raise ValueError(f"negative committed load from tenant {tenant!r}")
        self._committed[tenant] = loads.copy()
        self._stamp[tenant] = None if window is None else int(window)
        if window is not None:
            self._clock = max(self._clock, int(window))

    def withdraw(self, tenant: str) -> None:
        """Remove ``tenant``'s ledger entry (load and stamp).

        Withdrawing an unknown — or already-withdrawn — tenant is a
        documented **no-op**, not an error: teardown paths race (session
        close vs. arbiter staleness eviction vs. explicit unregister), and
        "this tenant contributes nothing to the ledger" is already true.
        Pinned by ``tests/test_faults.py``.
        """
        self._committed.pop(tenant, None)
        self._stamp.pop(tenant, None)

    def committed_load(self, tenant: str) -> Optional[np.ndarray]:
        loads = self._committed.get(tenant)
        return None if loads is None else loads.copy()

    def tenants(self) -> List[str]:
        return list(self._committed)

    def total_load(self) -> np.ndarray:
        """Sum of all tenants' committed loads [R] (zeros when empty)."""
        total = np.zeros(self.rm.n_resources, dtype=np.float64)
        for loads in self._committed.values():
            total += loads
        return total

    def external_load(
        self, tenant: str, half_life: Optional[float] = None
    ) -> np.ndarray:
        """Everyone-but-``tenant``'s committed load [R] (always >= 0).

        With ``half_life`` set, each peer's contribution is scaled by its
        recency weight (:meth:`decay_factor`) — stamped entries fade as the
        fabric clock runs past them, unstamped entries count in full.
        ``half_life=None`` is the raw-ledger path, byte-identical to the
        pre-recency ledger (total minus own, no per-peer arithmetic).
        """
        if half_life is None:
            total = self.total_load()
            own = self._committed.get(tenant)
            if own is not None:
                total -= own
            # float cancellation can leave tiny negatives; prices must not
            return np.maximum(total, 0.0)
        ext = np.zeros(self.rm.n_resources, dtype=np.float64)
        for peer, loads in self._committed.items():
            if peer == tenant:
                continue
            factor = self.decay_factor(peer, half_life)
            # factor == 1.0 skips the multiply so fresh/unstamped peers
            # contribute their exact committed bytes
            ext += loads if factor == 1.0 else loads * factor
        return ext

    # -- recency ----------------------------------------------------------------
    @property
    def clock(self) -> int:
        """The fabric clock: newest stamped commit window seen (0 when no
        stamped commit has landed yet)."""
        return self._clock

    def staleness(self, tenant: str) -> Optional[float]:
        """Windows since ``tenant``'s last stamped commit, against the
        fabric clock; ``None`` for unstamped (timeless) or unknown
        tenants.  Never negative — a commit stamped ahead of the clock
        advances the clock instead."""
        stamp = self._stamp.get(tenant)
        if stamp is None:
            return None
        return float(max(self._clock - stamp, 0))

    def decay_factor(self, tenant: str, half_life: Optional[float]) -> float:
        """Recency weight of ``tenant``'s ledger entry in decayed prices:
        ``0.5 ** (staleness / half_life)``, monotone non-increasing in
        staleness, exactly 1.0 for fresh or unstamped entries (and for
        ``half_life=None`` / non-positive half-lives, which disable
        decay)."""
        if half_life is None or half_life <= 0:
            return 1.0
        stale = self.staleness(tenant)
        if stale is None or stale == 0.0:
            return 1.0
        return float(0.5 ** (stale / float(half_life)))

    # -- drain accounting -------------------------------------------------------
    def drain_time_s(self, loads: np.ndarray) -> float:
        """Seconds to drain ``loads`` at current capacities (max resource)."""
        return float(np.max(loads / self.rm.capacity)) if len(loads) else 0.0

    def drain_times(self) -> Dict[str, float]:
        """Per-tenant drain time of each tenant's own committed load."""
        return {t: self.drain_time_s(l) for t, l in self._committed.items()}

    def combined_drain_s(self) -> float:
        """Drain time of the *stacked* fabric load — the co-planning metric."""
        return self.drain_time_s(self.total_load())

    # -- observability ----------------------------------------------------------
    def summary(self) -> dict:
        """Compact health snapshot for the metrics registry (DESIGN.md §11).

        Unlike :meth:`to_json_obj` this stays numeric-only (no schema
        envelope, no per-tenant drain map) so the flight recorder can map
        it straight onto gauges; unstamped tenants report staleness 0.0 —
        a timeless entry is never stale.
        """
        return {
            "clock": int(self._clock),
            "tenants": len(self._committed),
            "combined_drain_s": self.combined_drain_s(),
            "staleness": {
                t: (self.staleness(t) or 0.0) for t in self._committed
            },
        }

    # -- link events ------------------------------------------------------------
    def apply_link_overrides(
        self, overrides: Mapping[Tuple[int, int], float]
    ) -> Tuple:
        """Rescale link capacities; returns the new topology fingerprint.

        Geometry is unchanged (same resource vector length), so committed
        loads remain valid; drain accounting follows the new capacities.
        """
        self._set_topology(self.topo.with_link_scale(overrides))
        return self.fingerprint

    # -- serialization ----------------------------------------------------------
    def to_json_obj(self) -> dict:
        drains = self.drain_times()
        return tag(
            "fabric_state",
            {
                "n_resources": int(self.rm.n_resources),
                "tenants": sorted(self._committed),
                "drain_s": {t: drains[t] for t in sorted(drains)},
                "combined_drain_s": self.combined_drain_s(),
                "down_links": [int(l) for l in self.topo.down_link_ids()],
                "clock": int(self._clock),
                "staleness": {
                    t: self.staleness(t) for t in sorted(self._committed)
                },
            },
        )
