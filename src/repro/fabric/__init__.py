"""Fabric arbiter — shared congestion-pricing layer for multi-tenant
runtimes (DESIGN.md §4).

One fabric, N tenants (serving jobs, MoE layer groups), each with its own
MWU planner: this package coordinates them.  ``FabricState`` is the ledger
of per-tenant committed load; ``FabricArbiter`` exports weighted congestion
prices into every tenant's solve (``ext_loads``), iterates sequential-
greedy sweeps to a priced equilibrium, gates replans (token bucket + QoS),
broadcasts link events over the shared ``LinkEventBus``, and accounts
fairness (Jain's index, weighted max-min violation) through
``repro.jsonio``.
"""

from .admission import AdmissionConfig, AdmissionDecision, TokenBucket
from .arbiter import (
    ArbiterConfig,
    ArbiterStats,
    FabricArbiter,
    QOS_RANK,
    RepriceDecision,
    TenantConfig,
)
from .fairness import (
    fairness_report,
    jains_index,
    maxmin_violation,
    weighted_drains,
)
from .state import FabricState

__all__ = [
    "AdmissionConfig",
    "AdmissionDecision",
    "TokenBucket",
    "ArbiterConfig",
    "ArbiterStats",
    "FabricArbiter",
    "QOS_RANK",
    "RepriceDecision",
    "TenantConfig",
    "fairness_report",
    "jains_index",
    "maxmin_violation",
    "weighted_drains",
    "FabricState",
]
