"""Replan admission gate — token bucket + QoS priority.

A replan is not free for the *fabric*: every tenant solve occupies the
shared planner (one jit dispatch), and every committed-load change moves
the prices its peers plan against, invalidating their demand+price-keyed
plan caches.  A tenant whose estimator is noisy (or whose traffic genuinely
bursts) can therefore thrash everyone.  The gate bounds that blast radius:

  * each tenant holds a **token bucket** (``burst`` tokens, refilled at
    ``refill_per_window`` per elapsed window); a congestion- or
    staleness-triggered replan consumes one token and is **throttled** when
    the bucket is empty;
  * **topology events bypass** the gate — a plan solved for dead geometry
    is worse than any amount of cache churn;
  * the ``gold`` QoS class bypasses the gate (latency-critical tenants);
  * with fewer than two registered tenants there is nobody to protect, so
    the gate admits everything — part of the arbiter's zero-overhead
    single-tenant contract.

The bypass/solo logic lives in :meth:`repro.fabric.FabricArbiter.admit`;
this module is the mechanism.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    burst: int = 3                  # bucket depth: back-to-back replans
    refill_per_window: float = 0.5  # sustained replans per window

    def __post_init__(self):
        if self.burst < 1:
            raise ValueError(f"burst must be >= 1, got {self.burst}")
        if self.refill_per_window < 0:
            raise ValueError("refill_per_window must be non-negative")


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    admitted: bool
    reason: str         # "topology" | "solo" | "qos" | "ok" | "throttled"
    tokens_left: float

    def to_json_obj(self) -> dict:
        return dataclasses.asdict(self)


class TokenBucket:
    """Window-clocked token bucket; refill is lazy on access."""

    def __init__(self, cfg: AdmissionConfig | None = None):
        self.cfg = cfg or AdmissionConfig()
        self._tokens = float(self.cfg.burst)
        self._last_window: Optional[int] = None

    def _refill(self, window: int) -> None:
        if self._last_window is not None and window > self._last_window:
            elapsed = window - self._last_window
            self._tokens = min(
                float(self.cfg.burst),
                self._tokens + elapsed * self.cfg.refill_per_window,
            )
        if self._last_window is None or window > self._last_window:
            self._last_window = window

    def tokens(self, window: int) -> float:
        self._refill(window)
        return self._tokens

    def try_take(self, window: int) -> bool:
        """Consume one token at ``window``; False when the bucket is dry."""
        self._refill(window)
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True
        return False
