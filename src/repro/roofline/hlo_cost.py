"""HLO-text cost model with while-loop trip-count multiplication.

``compiled.cost_analysis()`` (xla::HloCostAnalysis) counts a while-loop body
ONCE — our models scan layers (94x), sequence steps (4096x) and kv chunks
(32x), so stock numbers are off by orders of magnitude.  This module parses
the optimized post-SPMD HLO text and recomputes:

  * **flops** — dot ops (2 x result_elems x contracted_elems), multiplied by
    the product of enclosing while trip counts;
  * **bytes** — operand+result bytes of top-level (post-fusion) ops, i.e.
    fusion-boundary HBM traffic, with the same multipliers;
  * **collective bytes** — per collective kind, operand bytes x multipliers.

Trip counts come from each while's condition computation (the canonical
``compare(gte(param), constant(N)), direction=LT`` pattern); unknown
conditions conservatively count once and are reported in ``unknown_loops``.

Validated in tests against analytic FLOPs of a scanned transformer.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while", "conditional",
    "call",
}


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    raw: str

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.type_str)


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)+)\s*"
    r"([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s+\(.*->.*\{\s*$")


def _split_operands(argstr: str) -> List[str]:
    """Operand instruction names from the call-args portion of a line."""
    depth = 1
    core = ""
    for ch in argstr:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        core += ch
    return re.findall(r"%([\w.\-]+)", core)


def _attr(raw: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", raw)
    return m.group(1) if m else None


def _attr_list(raw: str, key: str) -> List[int]:
    m = re.search(key + r"=\{([0-9, ]*)\}", raw)
    if not m:
        return []
    return [int(x) for x in m.group(1).replace(" ", "").split(",") if x]


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.instr: Dict[str, Instr] = {}
        self.entry: Optional[str] = None
        cur: Optional[str] = None
        comment_re = re.compile(r"/\*.*?\*/")
        for line in text.splitlines():
            line = comment_re.sub("", line)
            mc = _COMP_RE.match(line)
            if mc and "=" not in line.split("->")[0]:
                cur = mc.group(2)
                self.computations[cur] = []
                if mc.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                continue
            mi = _INSTR_RE.match(line)
            if mi:
                name, tstr, opcode, rest = mi.groups()
                ins = Instr(name, tstr, opcode, _split_operands(rest), line)
                self.computations[cur].append(ins)
                self.instr[name] = ins
        if self.entry is None and self.computations:
            # entry is usually last
            self.entry = list(self.computations)[-1]

    # -- trip counts -----------------------------------------------------------
    def while_trip_count(self, cond_comp: str) -> Optional[int]:
        """Trip count from the while condition.

        XLA canonicalizes counted loops to ``lt(induction, constant(N))``
        with the compare frequently wrapped in a kLoop fusion, so the robust
        extraction is: the largest integer constant in the condition
        computation.  (Induction variables start at 0 in XLA-canonical
        loops; non-counted conditions return None and are reported.)"""
        instrs = self.computations.get(cond_comp, [])
        consts: List[int] = []
        has_compare = False
        for ins in instrs:
            if ins.opcode == "constant":
                m = re.search(r"constant\((-?\d+)\)", ins.raw)
                if m:
                    consts.append(int(m.group(1)))
            if ins.opcode in ("compare", "fusion"):
                has_compare = True
        if has_compare and consts:
            return max(max(consts), 0)
        return None

    # -- slice-accurate fusion byte accounting ----------------------------------
    def _fusion_bytes(self, ins: Instr) -> float:
        """HBM bytes of a top-level fusion, slice-accurate.

        XLA aliases while-loop buffers in place, so a kLoop fusion that
        dynamic-update-slices one time-step into a stacked [T, ...] buffer
        touches ~2x the slice, not 2x the buffer.  Per fusion parameter:

          * consumed only by dynamic-slice  -> charge the slice(s) read;
          * consumed only as the updated operand of dynamic-update-slice
            -> charge 0 reads (aliased in-place write);
          * otherwise -> full parameter bytes.

        The write side is the update size when the root is a DUS (possibly
        behind bitcasts), else the full result.
        """
        called = _attr(ins.raw, "calls")
        body = self.computations.get(called or "", [])
        if not body:
            return float(
                sum(self.instr[o].result_bytes for o in ins.operands
                    if o in self.instr) + ins.result_bytes
            )
        by_name = {b.name: b for b in body}
        params: List[Instr] = [b for b in body if b.opcode == "parameter"]
        # resolve bitcast chains: map name -> canonical source param (if any)
        def canon(name: str) -> Optional[str]:
            seen = 0
            while name in by_name and seen < 10:
                b = by_name[name]
                if b.opcode == "parameter":
                    return name
                if b.opcode in ("bitcast", "copy", "reshape") and b.operands:
                    name = b.operands[0]
                    seen += 1
                    continue
                return None
            return None

        # classify every use of every parameter
        reads: Dict[str, float] = {p.name: 0.0 for p in params}
        full: Dict[str, bool] = {p.name: False for p in params}
        for b in body:
            if b.opcode == "parameter":
                continue
            for oi, o in enumerate(b.operands):
                src = canon(o)
                if src is None or src not in reads:
                    continue
                if b.opcode == "dynamic-slice" and oi == 0:
                    reads[src] += b.result_bytes
                elif b.opcode == "dynamic-update-slice" and oi == 0:
                    pass  # aliased in-place destination: no read
                elif b.opcode in ("bitcast", "copy", "reshape"):
                    pass  # accounted at the chain's consumer via canon()
                else:
                    full[src] = True
        read_bytes = 0.0
        # parameter order corresponds to fusion operand order
        for i, p in enumerate(params):
            opnd = ins.operands[i] if i < len(ins.operands) else None
            pbytes = (self.instr[opnd].result_bytes
                      if opnd in self.instr else p.result_bytes)
            if full[p.name]:
                read_bytes += pbytes
            else:
                read_bytes += min(reads[p.name], pbytes)
        # write side: root DUS writes only the update region
        root = body[-1]
        seen = 0
        while root.opcode in ("bitcast", "copy", "reshape") and root.operands \
                and root.operands[0] in by_name and seen < 10:
            root = by_name[root.operands[0]]
            seen += 1
        if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
            upd = by_name.get(root.operands[1])
            write_bytes = float(upd.result_bytes if upd else ins.result_bytes)
        else:
            write_bytes = float(ins.result_bytes)
        return read_bytes + write_bytes

    def _plain_op_bytes(self, ins: Instr) -> float:
        """Top-level non-fusion op bytes (slice-aware for DS/DUS)."""
        if ins.opcode == "dynamic-slice":
            small = sum(self.instr[o].result_bytes for o in ins.operands[1:]
                        if o in self.instr)
            return float(2 * ins.result_bytes + small)
        if ins.opcode == "dynamic-update-slice" and len(ins.operands) > 1:
            upd = self.instr.get(ins.operands[1])
            ub = upd.result_bytes if upd else ins.result_bytes
            return float(2 * ub)
        opnd = sum(self.instr[o].result_bytes for o in ins.operands
                   if o in self.instr)
        return float(opnd + ins.result_bytes)

    # -- dot flops ----------------------------------------------------------------
    def _dot_flops(self, ins: Instr, comp: str) -> float:
        result_elems = 1
        for _, dims in _shape_dims(ins.type_str):
            for d in dims:
                result_elems *= d
        lhs = self.instr.get(ins.operands[0]) if ins.operands else None
        contracted = 1
        if lhs is not None:
            ldims = _shape_dims(lhs.type_str)
            if ldims:
                dims = ldims[0][1]
                for ci in _attr_list(ins.raw, "lhs_contracting_dims"):
                    if ci < len(dims):
                        contracted *= dims[ci]
        return 2.0 * result_elems * contracted

    # -- walk ------------------------------------------------------------------------
    def analyze(self) -> Dict:
        flops = 0.0
        bytes_ = 0.0
        coll = {k: 0.0 for k in _COLLECTIVES}
        unknown_loops = 0
        visited_stack = set()

        def comp_cost(comp: str, mult: float, top_level: bool):
            nonlocal flops, bytes_, coll, unknown_loops
            if comp in visited_stack:          # defensive (no recursion in HLO)
                return
            visited_stack.add(comp)
            for ins in self.computations.get(comp, []):
                op = ins.opcode
                if op == "dot":
                    flops += self._dot_flops(ins, comp) * mult
                if op == "while":
                    body = _attr(ins.raw, "body")
                    cond = _attr(ins.raw, "condition")
                    trip = self.while_trip_count(cond) if cond else None
                    if trip is None:
                        trip = 1
                        unknown_loops += 1
                    if body:
                        comp_cost(body, mult * trip, top_level)
                    if cond:
                        comp_cost(cond, mult * trip, False)
                elif op == "fusion":
                    called = _attr(ins.raw, "calls")
                    if called:
                        comp_cost(called, mult, False)  # dots inside fusions
                elif op in ("call", "conditional", "custom-call"):
                    for key in ("to_apply", "calls", "true_computation",
                                "false_computation", "branch_computations"):
                        called = _attr(ins.raw, key)
                        if called:
                            comp_cost(called, mult, False)
                # collective bytes (operand sizes)
                for kind in _COLLECTIVES:
                    if op == kind or op.startswith(kind + "-start"):
                        b = sum(
                            self.instr[o].result_bytes
                            for o in ins.operands if o in self.instr
                        ) or ins.result_bytes
                        coll[kind] += b * mult
                        break
                # HBM traffic at fusion boundaries (top-level ops only),
                # slice-accurate for scan-body DUS/DS patterns
                if top_level and op not in _SKIP_BYTES_OPS:
                    if op == "fusion":
                        bytes_ += self._fusion_bytes(ins) * mult
                    else:
                        bytes_ += self._plain_op_bytes(ins) * mult
            visited_stack.discard(comp)

        if self.entry:
            comp_cost(self.entry, 1.0, True)
        return {
            "flops": flops,
            "bytes": bytes_,
            "collectives": coll,
            "collective_bytes": sum(coll.values()),
            "unknown_loops": unknown_loops,
        }


def analyze_hlo_text(text: str) -> Dict:
    return HloModule(text).analyze()
