"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), per the brief:

    compute    = HLO_FLOPs   / (chips x peak_FLOP/s)
    memory     = HLO_bytes   / (chips x HBM_bw)
    collective = coll_bytes  / (chips x link_bw)

``compiled.cost_analysis()`` reports the per-device (post-SPMD) program, so
per-device terms divide by per-chip rates; the table reports both and the
dominant term.  Collective bytes are NOT in cost_analysis: we parse the
optimized HLO (``compiled.as_text()``), build a symbol table of every
instruction's result bytes, and sum operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops.

Hardware constants (TPU v5e class, per the brief): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every TYPE[dims] group in a (possibly tuple) type."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes from optimized HLO text."""
    # pass 1: symbol table of result sizes
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # the type annotation is the prefix of rhs up to the op name
        sizes[name] = _shape_bytes(rhs.split(")")[0] if "(" in rhs else rhs)
    # pass 2: collective ops — sum operand sizes
    out = {k: 0 for k in _COLLECTIVES}
    opnd_re = re.compile(r"%([\w.\-]+)")
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            token = f" {kind}("
            if token in line and "fusion" not in line.split("=")[-1][:20]:
                args = line.split(token, 1)[1]
                depth = 1
                arglist = []
                cur = ""
                for ch in args:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            arglist.append(cur)
                            break
                    if depth >= 1:
                        cur += ch
                names = opnd_re.findall(arglist[0] if arglist else "")
                b = sum(sizes.get(n, 0) for n in names)
                if b == 0:
                    # operands may be listed without %, fall back to result size
                    m = _DEF_RE.match(line)
                    if m:
                        b = sizes.get(m.group(1), 0)
                out[kind] += b
                break
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int]
    n_chips: int
    model_flops_total: float     # 6·N·D (or 2·N·D for inference)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.flops_per_device * self.n_chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "n_chips": self.n_chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze(compiled, n_chips: int, model_flops_total: float) -> Roofline:
    """Roofline terms from the compiled artifact.

    Primary source is the trip-count-aware HLO cost model
    (``hlo_cost.analyze_hlo_text``) — stock ``cost_analysis()`` counts scan
    bodies once and is kept only as a cross-check floor.
    """
    from .hlo_cost import analyze_hlo_text

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    stock_flops = float(cost.get("flops", 0.0))
    stock_bytes = float(cost.get("bytes accessed", 0.0))
    r = analyze_hlo_text(compiled.as_text())
    flops = max(r["flops"], stock_flops)
    byts = max(r["bytes"], stock_bytes)
    coll = {k: int(v) for k, v in r["collectives"].items()}
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        n_chips=n_chips,
        model_flops_total=model_flops_total,
    )


# --------------------------------------------------------------------------- #
# model FLOPs (analytic)
# --------------------------------------------------------------------------- #


def count_params(tree) -> int:
    import jax
    return sum(int(l.size) for l in jax.tree.leaves(tree))


def active_param_fraction(cfg) -> float:
    """MoE: fraction of expert params active per token (top_k / n_experts)."""
    if cfg.n_experts and cfg.top_k:
        # experts dominate; attn/embed always active.  Approximate by the
        # standard 6·N_active convention with N_active from routing.
        return cfg.top_k / cfg.n_experts
    return 1.0


def model_flops(cfg, n_params: int, tokens: int, kind: str) -> float:
    """6·N·D train / 2·N·D inference; MoE uses active params."""
    if cfg.n_experts and cfg.top_k:
        expert_params = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
        n_active = n_params - expert_params + expert_params * (
            cfg.top_k / cfg.n_experts
        )
    else:
        n_active = n_params
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens
