"""Per-instruction byte/FLOP breakdown of a dry-run lowering (§Perf tooling).

    PYTHONPATH=src python -m repro.roofline.breakdown --arch xlstm-125m \
        --shape train_4k [--set mlstm_chunk=64] [--top 20]

Prints the top-N byte-contributing top-level instructions (trip-count- and
slice-aware, same accounting as the roofline) with their op_name metadata,
so the dominant roofline term can be attributed to model code.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import re
from collections import defaultdict

from . import hlo_cost as hc


def breakdown(text: str):
    m = hc.HloModule(text)
    rows = []

    def comp_cost(comp, mult, top):
        for ins in m.computations.get(comp, []):
            op = ins.opcode
            if op == "while":
                body = hc._attr(ins.raw, "body")
                cond = hc._attr(ins.raw, "condition")
                trip = m.while_trip_count(cond) if cond else None
                if trip is None:
                    trip = 1
                if body:
                    comp_cost(body, mult * trip, top)
            if top and op not in hc._SKIP_BYTES_OPS:
                b = (m._fusion_bytes(ins) if op == "fusion"
                     else m._plain_op_bytes(ins)) * mult
                rows.append((b, mult, ins))

    comp_cost(m.entry, 1.0, True)
    rows.sort(key=lambda r: -r[0])
    return m, rows


def opname(ins) -> str:
    mm = re.search(r'op_name="([^"]*)"', ins.raw)
    return mm.group(1) if mm else "?"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-mode", default="nimble")
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--set-ctx", action="append", default=[])
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    def _parse(items):
        out = {}
        for it in items:
            k, v = it.split("=", 1)
            for cast in (int, float):
                try:
                    v = cast(v)
                    break
                except ValueError:
                    pass
            if v in ("True", "true"):
                v = True
            elif v in ("False", "false"):
                v = False
            out[k] = v
        return out

    texts = []
    orig = hc.analyze_hlo_text
    hc.analyze_hlo_text = lambda t: (texts.append(t), orig(t))[1]
    from repro.launch.dryrun import run_one

    rec = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                  moe_mode=args.moe_mode, cfg_overrides=_parse(args.set),
                  ctx_overrides=_parse(args.set_ctx))
    ro = rec["roofline"]
    print(f"{args.arch} x {args.shape}: dom={ro['dominant']} "
          f"comp={ro['compute_s']:.3e}s mem={ro['memory_s']:.3e}s "
          f"coll={ro['collective_s']:.3e}s")

    m, rows = breakdown(texts[0])
    total = sum(r[0] for r in rows)
    print(f"\ntop {args.top} byte contributors (of {total:.3e} bytes):")
    for b, mult, ins in rows[: args.top]:
        print(f"  {b:10.3e} ({100 * b / total:5.1f}%) x{mult:<6.0f} "
              f"{ins.opcode:22s} {ins.type_str[:42]:42s} {opname(ins)[:70]}")

    # collectives: top instructions with attribution
    crows = []

    def coll_walk(comp, mult):
        for ins in m.computations.get(comp, []):
            if ins.opcode == "while":
                body = hc._attr(ins.raw, "body")
                cond = hc._attr(ins.raw, "condition")
                trip = m.while_trip_count(cond) if cond else None
                if trip is None:
                    trip = 1
                if body:
                    coll_walk(body, mult * trip)
            for kind in hc._COLLECTIVES:
                if ins.opcode == kind or ins.opcode.startswith(kind + "-start"):
                    b = sum(m.instr[o].result_bytes for o in ins.operands
                            if o in m.instr) or ins.result_bytes
                    crows.append((b * mult, mult, kind, ins))
                    break

    coll_walk(m.entry, 1.0)
    crows.sort(key=lambda r: -r[0])
    print(f"\ntop collectives ({sum(r[0] for r in crows):.3e} bytes total):")
    for b, mult, kind, ins in crows[: args.top]:
        print(f"  {b:10.3e} x{mult:<6.0f} {kind:20s} {ins.type_str[:38]:38s} "
              f"{opname(ins)[:60]}")

    # also aggregate by op_name prefix (model-code attribution)
    agg = defaultdict(float)
    for b, mult, ins in rows:
        name = opname(ins)
        key = "/".join(name.split("/")[:4]) if name != "?" else "?"
        agg[key] += b
    print("\nby op_name prefix:")
    for k, v in sorted(agg.items(), key=lambda x: -x[1])[:15]:
        print(f"  {v:10.3e} ({100 * v / total:5.1f}%)  {k}")


if __name__ == "__main__":
    main()
