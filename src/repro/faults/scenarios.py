"""Declarative fault-scenario specs (DESIGN.md §9).

A scenario is a frozen, purely-declarative description of *what goes wrong
when* — link flap trains, rail/NIC loss, telemetry blackouts, stragglers,
tenant crashes, background elephants — with every stochastic choice
deferred to the injector's seeded RNG.  Specs carry no topology knowledge
beyond device/link indices; :class:`~repro.faults.injector.FaultInjector`
validates them against a concrete :class:`~repro.core.topology.Topology`
at compile time and expands them into scheduled
:class:`~repro.runtime.events.LinkEvent` / telemetry perturbations.

Determinism contract: a scenario plus a seed compiles to a bit-identical
:class:`~repro.faults.injector.FaultSchedule` on every call (pinned by a
hypothesis property test in ``tests/test_faults.py``), so drills are
replayable and schedule digests are stable across runs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LinkFlapSpec:
    """A flap train on one directed link: down/up cycles from ``start``.

    Each cycle holds the link down for ``down_windows`` then restored for
    ``up_windows``; the train always ends with a restore, so the fabric is
    whole after ``end_window``.  ``jitter`` (fraction of a cycle, drawn
    from the injector's seeded RNG) perturbs each cycle's start — real
    flaps are not metronomes — without ever reordering events.
    """

    src: int
    dst: int
    start: int
    cycles: int = 3
    down_windows: int = 2
    up_windows: int = 2
    jitter: float = 0.0

    def __post_init__(self):
        if self.cycles < 1:
            raise ValueError(f"cycles must be >= 1, got {self.cycles}")
        if self.down_windows < 1 or self.up_windows < 1:
            raise ValueError("down_windows and up_windows must be >= 1")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")

    @property
    def end_window(self) -> int:
        """Window of the final (un-jittered) restore event."""
        period = self.down_windows + self.up_windows
        return self.start + (self.cycles - 1) * period + self.down_windows


@dataclasses.dataclass(frozen=True)
class RailLossSpec:
    """NIC loss: every inter-group link through ``device``'s NIC goes down.

    Models a single NIC (one rail endpoint) failing — all rail links whose
    source *or* destination is ``device`` drop to ``DOWN_CAP`` at
    ``start`` and, unless ``restore`` is None (permanent loss), come back
    together at ``restore``.
    """

    device: int
    start: int
    restore: Optional[int] = None

    def __post_init__(self):
        if self.restore is not None and self.restore <= self.start:
            raise ValueError("restore must come after start")


@dataclasses.dataclass(frozen=True)
class TelemetryBlackoutSpec:
    """Telemetry loss over ``[start, start + duration)`` windows.

    ``drop_prob=1.0`` is a full blackout (the estimator sees nothing);
    ``drop_prob < 1`` is partial dropout — each pair-bytes entry is
    independently lost (NaN) with probability ``drop_prob``, masks drawn
    once per window from the injector's seeded RNG.
    """

    start: int
    duration: int
    drop_prob: float = 1.0

    def __post_init__(self):
        if self.duration < 1:
            raise ValueError(f"duration must be >= 1, got {self.duration}")
        if not 0.0 < self.drop_prob <= 1.0:
            raise ValueError(
                f"drop_prob must be in (0, 1], got {self.drop_prob}"
            )


@dataclasses.dataclass(frozen=True)
class StragglerSpec:
    """Inflated window completion over ``[start, start + duration)``.

    A slow participant stretches the measured wall time of every window in
    the range by ``inflation`` (>= 1) without changing routed bytes — the
    telemetry-plausible signature of a straggling rank.  Overlapping
    straggler specs compose by taking the worst (max) inflation.
    """

    start: int
    duration: int
    inflation: float = 2.0
    device: Optional[int] = None   # informational: which rank straggles

    def __post_init__(self):
        if self.duration < 1:
            raise ValueError(f"duration must be >= 1, got {self.duration}")
        if self.inflation < 1.0:
            raise ValueError(
                f"inflation must be >= 1.0, got {self.inflation}"
            )


@dataclasses.dataclass(frozen=True)
class TenantCrashSpec:
    """Tenant ``tenant`` stops heartbeating (committing) at ``window``.

    The drill harness stops stepping the tenant's runtime from ``window``
    on; the fabric sees its ledger stamp go stale and — with
    ``ArbiterConfig.evict_staleness`` set — decays it to zero and evicts.
    """

    tenant: str
    window: int


@dataclasses.dataclass(frozen=True)
class ElephantFlowSpec:
    """Background elephant: extra ``bytes_per_window`` on one pair.

    Injected additively into the *executed* demand over
    ``[start, start + duration)`` — cross-traffic the planner never asked
    for, per the congestion-characterization methodology (victim flows
    under sustained background elephants).  ``jitter`` multiplies each
    window's bytes by ``1 ± jitter`` noise from the injector's seeded RNG.
    """

    src: int
    dst: int
    start: int
    duration: int
    bytes_per_window: float
    jitter: float = 0.0

    def __post_init__(self):
        if self.duration < 1:
            raise ValueError(f"duration must be >= 1, got {self.duration}")
        if self.bytes_per_window <= 0:
            raise ValueError("bytes_per_window must be > 0")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """One named, seeded bundle of fault specs — the injector's input.

    Spec tuples, not lists, so scenarios are hashable and safely shared;
    ``seed`` drives every stochastic choice (jitter, dropout masks) in the
    compiled schedule.
    """

    name: str
    seed: int = 0
    flaps: Tuple[LinkFlapSpec, ...] = ()
    rail_losses: Tuple[RailLossSpec, ...] = ()
    blackouts: Tuple[TelemetryBlackoutSpec, ...] = ()
    stragglers: Tuple[StragglerSpec, ...] = ()
    crashes: Tuple[TenantCrashSpec, ...] = ()
    elephants: Tuple[ElephantFlowSpec, ...] = ()

    def __post_init__(self):
        # tolerate lists at construction; normalize to tuples for hashing
        for field in ("flaps", "rail_losses", "blackouts", "stragglers",
                      "crashes", "elephants"):
            val = getattr(self, field)
            if not isinstance(val, tuple):
                object.__setattr__(self, field, tuple(val))
