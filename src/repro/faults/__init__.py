"""Deterministic fault injection + drill harness (DESIGN.md §9).

Declarative, seeded fault scenarios — link flap trains, rail/NIC loss,
telemetry blackout/dropout, stragglers, tenant crashes, background
elephants — compiled by :class:`FaultInjector` into schedules the
existing runtime machinery consumes (``EventLog`` link events, telemetry
perturbations through ``OrchestrationRuntime.step``).  Same seed + spec
-> bit-identical schedule (``FaultSchedule.digest``); the graceful-
degradation paths these drills exercise live in the layers themselves
(estimator confidence fallback, policy flap backoff, runtime watchdog,
planner degraded mode, fabric staleness eviction).
"""

from .harness import DrillResult, arm_events, run_drill
from .injector import FaultInjector, FaultSchedule
from .scenarios import (
    ElephantFlowSpec,
    FaultScenario,
    LinkFlapSpec,
    RailLossSpec,
    StragglerSpec,
    TelemetryBlackoutSpec,
    TenantCrashSpec,
)

__all__ = [
    "DrillResult",
    "arm_events",
    "run_drill",
    "FaultInjector",
    "FaultSchedule",
    "ElephantFlowSpec",
    "FaultScenario",
    "LinkFlapSpec",
    "RailLossSpec",
    "StragglerSpec",
    "TelemetryBlackoutSpec",
    "TenantCrashSpec",
]
