"""Seed-driven fault injection — scenario specs compiled to schedules.

:class:`FaultInjector` validates a :class:`~repro.faults.scenarios.
FaultScenario` against a concrete topology and compiles it into a
:class:`FaultSchedule`: the scheduled :class:`~repro.runtime.events.
LinkEvent` list (flap trains expanded cycle by cycle, rail losses fanned
out to every link through the lost NIC) plus window-indexed telemetry
perturbations (blackout/dropout masks, straggler inflation, elephant
demand).  All randomness — flap jitter, dropout masks, elephant noise —
comes from one ``np.random.default_rng(seed)`` with a fixed draw order,
so the same (scenario, topology) pair always compiles to a bit-identical
schedule; :meth:`FaultSchedule.digest` hashes the canonical byte
serialization and is what the determinism property test pins.

The schedule is consumed by the existing machinery, not a parallel stack:
link events feed :class:`~repro.runtime.events.EventLog` (or
``FabricArbiter.broadcast``), telemetry perturbations enter through
``OrchestrationRuntime.step(observed=..., completion_scale=...)``, and
elephants are added to the executed demand matrix.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.topology import INTRA, Topology
from ..jsonio import tag
from ..runtime.events import EventLog, LinkEvent, link_down, link_restored
from .scenarios import FaultScenario


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Compiled, deterministic fault timeline for one scenario.

    ``events`` is window-sorted (stable: same-window events keep their
    generation order, so :class:`EventLog`'s schedule-order-wins rule sees
    down-before-restore exactly as the scenario intended).  The telemetry
    maps are window-indexed; windows absent from a map are unperturbed.
    """

    scenario: FaultScenario
    n_devices: int
    events: Tuple[LinkEvent, ...]
    # window -> drop probability; 1.0 = full blackout
    blackout_prob: Dict[int, float]
    # window -> [n, n] bool lost-entry mask (partial-dropout windows only)
    dropout_masks: Dict[int, np.ndarray]
    # window -> completion-time inflation factor (>= 1)
    straggler_scale: Dict[int, float]
    # window -> [n, n] additive background demand (bytes)
    elephant_bytes: Dict[int, np.ndarray]
    # tenant -> window of last heartbeat (crashed from that window on)
    crash_windows: Dict[str, int]

    # -- consumption ------------------------------------------------------------
    def event_log(self) -> EventLog:
        """Fresh :class:`EventLog` holding this schedule's link events."""
        return EventLog(self.events)

    def perturbed_demand(self, window: int, demand: np.ndarray) -> np.ndarray:
        """Executed demand for ``window``: the trace plus elephant bytes."""
        extra = self.elephant_bytes.get(window)
        if extra is None:
            return demand
        return np.asarray(demand, dtype=np.float64) + extra

    def observed_demand(
        self, window: int, demand: np.ndarray
    ) -> Optional[np.ndarray]:
        """What telemetry sees at ``window``: the demand, a NaN-masked copy
        (partial dropout), or ``None`` (full blackout)."""
        prob = self.blackout_prob.get(window)
        if prob is None:
            return demand
        if prob >= 1.0:
            return None
        obs = np.asarray(demand, dtype=np.float64).copy()
        mask = self.dropout_masks.get(window)
        if mask is not None:
            obs[mask] = np.nan
        return obs

    def completion_scale(self, window: int) -> float:
        return self.straggler_scale.get(window, 1.0)

    def crashed(self, tenant: str, window: int) -> bool:
        """True when ``tenant`` has stopped heartbeating by ``window``."""
        crash = self.crash_windows.get(tenant)
        return crash is not None and window >= crash

    @property
    def horizon(self) -> int:
        """Last window the schedule touches (0 for an empty schedule)."""
        last = 0
        for ev in self.events:
            last = max(last, ev.window)
        for m in (self.blackout_prob, self.straggler_scale,
                  self.elephant_bytes):
            if m:
                last = max(last, max(m))
        for w in self.crash_windows.values():
            last = max(last, w)
        return last

    # -- identity ---------------------------------------------------------------
    def digest(self) -> str:
        """SHA-256 over the canonical byte serialization of the schedule.

        Two schedules are bit-identical iff their digests match — the
        determinism contract's observable (same seed + spec -> same
        digest), covering event order, every mask bit, and every float.
        """
        h = hashlib.sha256()
        h.update(str(self.n_devices).encode())
        for ev in self.events:
            h.update(
                f"E{ev.window}:{ev.src}:{ev.dst}:{ev.scale!r};".encode()
            )
        for w in sorted(self.blackout_prob):
            h.update(f"B{w}:{self.blackout_prob[w]!r};".encode())
        for w in sorted(self.dropout_masks):
            h.update(f"M{w};".encode())
            h.update(np.ascontiguousarray(self.dropout_masks[w]).tobytes())
        for w in sorted(self.straggler_scale):
            h.update(f"S{w}:{self.straggler_scale[w]!r};".encode())
        for w in sorted(self.elephant_bytes):
            h.update(f"D{w};".encode())
            h.update(np.ascontiguousarray(self.elephant_bytes[w]).tobytes())
        for t in sorted(self.crash_windows):
            h.update(f"C{t}:{self.crash_windows[t]};".encode())
        return h.hexdigest()

    def to_json_obj(self) -> dict:
        return tag(
            "fault_schedule",
            {
                "scenario": self.scenario.name,
                "seed": int(self.scenario.seed),
                "digest": self.digest(),
                "horizon": int(self.horizon),
                "events": [ev.describe() for ev in self.events],
                "blackout_windows": sorted(self.blackout_prob),
                "straggler_windows": sorted(self.straggler_scale),
                "elephant_windows": sorted(self.elephant_bytes),
                "crashes": {
                    t: int(w) for t, w in sorted(self.crash_windows.items())
                },
            },
        )


class FaultInjector:
    """Compile :class:`FaultScenario` specs against one topology."""

    def __init__(self, topo: Topology):
        self.topo = topo

    # -- validation helpers -----------------------------------------------------
    def _check_device(self, dev: int, what: str) -> None:
        if not 0 <= dev < self.topo.n_devices:
            raise ValueError(
                f"{what}: device {dev} out of range "
                f"[0, {self.topo.n_devices})"
            )

    def _check_link(self, src: int, dst: int, what: str) -> None:
        self._check_device(src, what)
        self._check_device(dst, what)
        if not self.topo.has_link(src, dst):
            raise ValueError(f"{what}: no link {src}->{dst} in the topology")

    def _nic_links(self, device: int) -> Tuple[Tuple[int, int], ...]:
        """Directed inter-group (rail) links through ``device``'s NIC."""
        out = []
        for l in self.topo.links:
            if l.kind != INTRA and device in (l.src, l.dst):
                out.append((l.src, l.dst))
        return tuple(out)

    # -- compilation ------------------------------------------------------------
    def compile(self, scenario: FaultScenario) -> FaultSchedule:
        """Expand ``scenario`` into a deterministic :class:`FaultSchedule`.

        Draw order is fixed — flap jitter in spec order, dropout masks in
        window order per blackout spec, elephant noise in window order per
        elephant spec — so equal (seed, specs, topology) triples always
        produce bit-identical schedules.
        """
        rng = np.random.default_rng(scenario.seed)
        n = self.topo.n_devices
        events: list[LinkEvent] = []

        for spec in scenario.flaps:
            self._check_link(spec.src, spec.dst, "flap spec")
            period = spec.down_windows + spec.up_windows
            prev_restore = spec.start
            for cycle in range(spec.cycles):
                down_w = spec.start + cycle * period
                if spec.jitter > 0.0:
                    off = rng.uniform(-spec.jitter, spec.jitter) * period
                    down_w += int(round(off))
                # never reorder: a cycle starts at or after the previous
                # restore, and never before the spec's start window
                down_w = max(down_w, prev_restore, spec.start)
                restore_w = down_w + spec.down_windows
                events.append(link_down(down_w, spec.src, spec.dst))
                events.append(link_restored(restore_w, spec.src, spec.dst))
                prev_restore = restore_w

        for spec in scenario.rail_losses:
            self._check_device(spec.device, "rail-loss spec")
            links = self._nic_links(spec.device)
            if not links:
                raise ValueError(
                    f"rail-loss spec: device {spec.device} has no "
                    "inter-group links"
                )
            for src, dst in links:
                events.append(link_down(spec.start, src, dst))
            if spec.restore is not None:
                for src, dst in links:
                    events.append(link_restored(spec.restore, src, dst))

        # stable sort: same-window events keep generation order, matching
        # EventLog's schedule-order-wins override rule
        events.sort(key=lambda ev: ev.window)

        blackout_prob: Dict[int, float] = {}
        dropout_masks: Dict[int, np.ndarray] = {}
        for spec in scenario.blackouts:
            for w in range(spec.start, spec.start + spec.duration):
                # overlapping blackouts compose by worst loss
                blackout_prob[w] = max(
                    blackout_prob.get(w, 0.0), spec.drop_prob
                )
                if spec.drop_prob < 1.0:
                    mask = rng.random((n, n)) < spec.drop_prob
                    prev = dropout_masks.get(w)
                    dropout_masks[w] = mask if prev is None else prev | mask
        # full-blackout windows need no mask: everything is lost
        for w, prob in blackout_prob.items():
            if prob >= 1.0:
                dropout_masks.pop(w, None)

        straggler_scale: Dict[int, float] = {}
        for spec in scenario.stragglers:
            if spec.device is not None:
                self._check_device(spec.device, "straggler spec")
            for w in range(spec.start, spec.start + spec.duration):
                straggler_scale[w] = max(
                    straggler_scale.get(w, 1.0), spec.inflation
                )

        elephant_bytes: Dict[int, np.ndarray] = {}
        for spec in scenario.elephants:
            self._check_link(spec.src, spec.dst, "elephant spec")
            for w in range(spec.start, spec.start + spec.duration):
                b = spec.bytes_per_window
                if spec.jitter > 0.0:
                    b *= 1.0 + rng.uniform(-spec.jitter, spec.jitter)
                mat = elephant_bytes.setdefault(w, np.zeros((n, n)))
                mat[spec.src, spec.dst] += b

        crash_windows: Dict[str, int] = {}
        for spec in scenario.crashes:
            if not spec.tenant:
                raise ValueError("tenant-crash spec needs a tenant name")
            prev = crash_windows.get(spec.tenant)
            crash_windows[spec.tenant] = (
                spec.window if prev is None else min(prev, spec.window)
            )

        return FaultSchedule(
            scenario=scenario,
            n_devices=n,
            events=tuple(events),
            blackout_prob=blackout_prob,
            dropout_masks=dropout_masks,
            straggler_scale=straggler_scale,
            elephant_bytes=elephant_bytes,
            crash_windows=crash_windows,
        )
