"""Drill harness — drive a runtime through a compiled fault schedule.

:func:`run_drill` replays a traffic trace through one runtime (or
:class:`~repro.api.Session`) while the :class:`~repro.faults.injector.
FaultSchedule` perturbs every window: link events are armed into the
runtime's event log up front, elephants are added to the executed demand,
blackouts/dropouts filter what telemetry observes, and stragglers inflate
the measured completion.  The result wraps the per-window reports with
the recovery/availability accounting the fault drills gate on
(``benchmarks/bench_faults.py``).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..jsonio import tag
from ..runtime.controller import WindowReport
from .injector import FaultSchedule


def _unwrap_runtime(runtime_or_session):
    """Accept an OrchestrationRuntime or a Session wrapping one."""
    inner = getattr(runtime_or_session, "runtime", None)
    return inner if inner is not None else runtime_or_session


def arm_events(runtime_or_session, schedule: FaultSchedule) -> int:
    """Schedule the fault timeline's link events into the runtime's log."""
    rt = _unwrap_runtime(runtime_or_session)
    for ev in schedule.events:
        rt.events.schedule(ev)
    return len(schedule.events)


@dataclasses.dataclass
class DrillResult:
    """Per-window reports of one drill plus fault-drill accounting."""

    reports: List[WindowReport]
    schedule: FaultSchedule

    @property
    def total_completion_s(self) -> float:
        return float(sum(r.completion_s for r in self.reports))

    def completions(self) -> np.ndarray:
        return np.array([r.completion_s for r in self.reports])

    def healthy_median_s(self, until: int) -> float:
        """Median completion over windows ``[0, until)`` — the pre-fault
        reference the recovery/availability metrics compare against."""
        pre = [r.completion_s for r in self.reports if r.window < until]
        return float(np.median(pre)) if pre else 0.0

    def availability(self, ref_completion_s: float,
                     factor: float = 5.0) -> float:
        """Fraction of windows with a *live* plan: completion within
        ``factor`` x the healthy reference (a plan funneling traffic onto
        a dead link blows far past this; a merely degraded fabric does
        not)."""
        if not self.reports or ref_completion_s <= 0:
            return 1.0
        ok = sum(
            1 for r in self.reports
            if r.completion_s <= factor * ref_completion_s
        )
        return ok / len(self.reports)

    def recovery_window(self, after: int, threshold_s: float
                        ) -> Optional[int]:
        """First window >= ``after`` whose completion is back under
        ``threshold_s`` (None if the drill never recovers)."""
        return next(
            (
                r.window
                for r in self.reports
                if r.window >= after and r.completion_s <= threshold_s
            ),
            None,
        )

    def replans_by_reason(self) -> Dict[str, int]:
        """Issued-replan count per reason (plus suppressed ``backoff`` and
        ``gated`` windows, which issue nothing but are drill signals)."""
        counts: collections.Counter = collections.Counter()
        for r in self.reports:
            if r.replan_issued or r.replan_reason in ("backoff", "gated"):
                counts[r.replan_reason] += 1
        return dict(counts)

    @property
    def replan_count(self) -> int:
        return sum(1 for r in self.reports if r.replan_issued)

    @property
    def backoff_windows(self) -> List[int]:
        """Windows where the flap backoff suppressed a topology replan."""
        return [
            r.window for r in self.reports if r.replan_reason == "backoff"
        ]

    def to_json_obj(self) -> dict:
        return tag(
            "fault_drill",
            {
                "scenario": self.schedule.scenario.name,
                "digest": self.schedule.digest(),
                "windows": len(self.reports),
                "total_completion_s": self.total_completion_s,
                "replans": self.replan_count,
                "replans_by_reason": self.replans_by_reason(),
                "backoff_windows": self.backoff_windows,
            },
        )


def run_drill(
    runtime_or_session,
    trace: np.ndarray,               # [W, n, n]
    schedule: FaultSchedule,
    tenant: Optional[str] = None,
) -> DrillResult:
    """Replay ``trace`` through the runtime under ``schedule``'s faults.

    ``tenant`` (when given) honors the schedule's crash specs: stepping
    stops cold at the tenant's crash window — no teardown, no final
    commit — exactly the no-heartbeat failure the fabric's staleness
    eviction exists for.  The caller owns event arming when it wants
    broadcast semantics instead; by default the link events are armed
    into the runtime's own log here.
    """
    rt = _unwrap_runtime(runtime_or_session)
    arm_events(rt, schedule)
    reports: List[WindowReport] = []
    for w in range(len(trace)):
        if tenant is not None and schedule.crashed(tenant, w):
            break
        demand = schedule.perturbed_demand(w, trace[w])
        observed = schedule.observed_demand(w, demand)
        reports.append(
            rt.step(
                demand,
                observed=observed,
                completion_scale=schedule.completion_scale(w),
            )
        )
    return DrillResult(reports=reports, schedule=schedule)
