"""Batched serving engine: prefill + greedy/temperature decode loop.

``make_serve_step`` builds the single-token decode function the dry-run
lowers for the decode input shapes (one new token against a seq_len-deep
cache).  ``ServeEngine`` drives it for real batched requests (examples/
and the end-to-end serving smoke test).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape
from repro.models.registry import Model


def make_serve_step(model: Model):
    def serve_step(params, cache, token, pos):
        """token [B] int32, pos scalar int32 -> (logits [B, V], cache')."""
        return model.decode_step(params, cache, token, pos)
    return serve_step


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: object
    max_len: int = 256

    def __post_init__(self):
        self._step = jax.jit(make_serve_step(self.model))

    def generate(
        self,
        prompts: np.ndarray,          # [B, P] int32 prompt tokens
        n_new: int = 32,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> np.ndarray:
        B, P = prompts.shape
        shape = InputShape("serve", self.max_len, B, "decode")
        cache = self.model.init_cache(B, shape)
        rng = jax.random.PRNGKey(seed)
        tok = jnp.asarray(prompts[:, 0])
        out: List[np.ndarray] = []
        # prefill by stepping the prompt (cache-correct for all families)
        for i in range(P):
            tok_i = jnp.asarray(prompts[:, i])
            logits, cache = self._step(self.params, cache, tok_i,
                                       jnp.int32(i))
        # autoregressive decode
        for j in range(n_new):
            if temperature > 0:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(
                    sub, logits.astype(jnp.float32) / temperature, axis=-1
                )
            else:
                tok = jnp.argmax(logits, axis=-1)
            out.append(np.asarray(tok))
            logits, cache = self._step(self.params, cache, tok.astype(jnp.int32),
                                       jnp.int32(P + j))
        return np.stack(out, axis=1)
