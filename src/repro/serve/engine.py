"""Batched serving engine: prefill + greedy/temperature decode loop.

``make_serve_step`` builds the single-token decode function the dry-run
lowers for the decode input shapes (one new token against a seq_len-deep
cache).  ``make_prefill_scan`` rolls the per-token prompt prefill into one
``lax.scan`` — a single jitted dispatch instead of P host round-trips,
bit-identical to stepping the prompt token by token (pinned by
``tests/test_serve_engine.py``).  ``ServeEngine`` drives both for real
batched requests (examples/ and the end-to-end serving smoke test).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape
from repro.models.registry import Model


def make_serve_step(model: Model):
    def serve_step(params, cache, token, pos):
        """token [B] int32, pos scalar int32 -> (logits [B, V], cache')."""
        return model.decode_step(params, cache, token, pos)
    return serve_step


def make_prefill_scan(model: Model):
    """Whole-prompt prefill as one scan over (token column, position).

    The scan body is exactly one ``decode_step`` — the same computation
    the per-token loop ran — so the final cache and last-position logits
    are bit-identical to P sequential steps, in one dispatch.
    """

    def prefill(params, cache, prompts):
        """prompts [B, P] int32 -> (last logits [B, V], cache')."""
        P = prompts.shape[1]

        def body(cache, tok_pos):
            tok, pos = tok_pos
            logits, cache = model.decode_step(params, cache, tok, pos)
            return cache, logits

        cache, logits_seq = jax.lax.scan(
            body, cache, (prompts.T, jnp.arange(P, dtype=jnp.int32))
        )
        return logits_seq[-1], cache

    return prefill


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: object
    max_len: int = 256

    def __post_init__(self):
        self._step = jax.jit(make_serve_step(self.model))
        self._prefill = jax.jit(make_prefill_scan(self.model))

    def generate(
        self,
        prompts: np.ndarray,          # [B, P] int32 prompt tokens
        n_new: int = 32,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> np.ndarray:
        B, P = prompts.shape
        if P < 1:
            raise ValueError("prompts must carry at least one token")
        shape = InputShape("serve", self.max_len, B, "decode")
        cache = self.model.init_cache(B, shape)
        rng = jax.random.PRNGKey(seed)
        out: List[np.ndarray] = []
        # prefill the whole prompt in one jitted scan (cache-correct for
        # all families; bit-identical to stepping token by token)
        logits, cache = self._prefill(
            self.params, cache, jnp.asarray(prompts, dtype=jnp.int32)
        )
        # autoregressive decode
        for j in range(n_new):
            if temperature > 0:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(
                    sub, logits.astype(jnp.float32) / temperature, axis=-1
                )
            else:
                tok = jnp.argmax(logits, axis=-1)
            out.append(np.asarray(tok))
            logits, cache = self._step(self.params, cache, tok.astype(jnp.int32),
                                       jnp.int32(P + j))
        return np.stack(out, axis=1)
