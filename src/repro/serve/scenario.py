"""Declarative serving scenarios — tenant mixes, traffic programs, SLOs
(DESIGN.md §10).

A :class:`ScenarioSpec` is a frozen, JSON-round-trippable description of a
*continuous* serving deployment: which fabric, which tenants (each with a
per-tenant :class:`TrafficProgram` — diurnal swell, phase-shifted drifting
skew, MoE popularity flips), a deterministic tenant-churn schedule
(:class:`ChurnSpec`), an embedded :class:`~repro.faults.FaultScenario`
drill, and an :class:`SloSpec` of gates the run must hold.  Scenarios are
*data*: they ship as config (``ScenarioSpec.to_json`` /
``ScenarioSpec.from_json`` round-trip bit-exactly, unknown keys raise with
the offending key named) and a named built-in library covers the paper's
production-shaped regimes:

  * ``steady``          — two balanced tenants; adaptive must *match*
    static (the no-regression scenario);
  * ``diurnal``         — phase-shifted diurnal skew swell (daytime
    hotspot concentration, nighttime balance) on two tenants;
  * ``churn_storm``     — a long-lived tenant under a storm of short-lived
    scavenger tenants joining and leaving;
  * ``flap_under_load`` — drifting skew while a rail link flaps;
  * ``elephant_victim`` — a victim tenant absorbing background elephant
    flows (the congestion-characterization victim-flow scenario).

Determinism contract: every stochastic choice (traffic jitter, popularity
flips, churn jitter) is drawn from RNGs seeded by ``(spec seed, window)``
or compiled in one fixed draw order, so a scenario replays bit-identically
— the same contract :mod:`repro.faults` pins for fault schedules.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..api.spec import TopologySpec
from ..faults.scenarios import (
    ElephantFlowSpec,
    FaultScenario,
    LinkFlapSpec,
    RailLossSpec,
    StragglerSpec,
    TelemetryBlackoutSpec,
    TenantCrashSpec,
)
from ..jsonio import json_dumps, json_loads, tag

MB = float(1 << 20)

#: schema tag of a serialized scenario
SCENARIO_SCHEMA = "nimble.serve_scenario/v1"

#: traffic-program shapes understood by :meth:`TrafficProgram.demand`
TRAFFIC_KINDS = ("steady", "diurnal", "drift", "flips")


# -- traffic programs -------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrafficProgram:
    """One tenant's open-ended traffic as a *function of the window index*.

    Stateless by construction: :meth:`demand` derives window ``w``'s
    ``[n, n]`` byte matrix from ``(seed, w)`` alone — no generator state —
    so a tenant joining at window 40 sees exactly the traffic it would
    have seen had it been up since window 0, and replays are bit-exact.

    Kinds:

      * ``steady``  — balanced all-pairs with multiplicative jitter;
      * ``diurnal`` — skew toward ``hot`` swells and relaxes with period
        ``period``: at the peak ``hot_frac`` of each source's bytes target
        the hotspot and the magnitude is ``swell``x; at the trough traffic
        is balanced at base magnitude (daytime concentration, nighttime
        balance).  ``phase`` shifts the cycle per tenant;
      * ``drift``   — a receive hotspot that migrates between node groups
        every ``dwell`` windows with a ``ramp``-window crossfade (the
        runtime-adaptation worst case); ``phase`` offsets the schedule so
        co-tenants peak on different groups;
      * ``flips``   — MoE popularity flips: ``n_hot`` "popular expert"
        destinations are re-drawn each ``dwell``-window epoch from the
        seeded RNG and flip *abruptly* (no ramp), the data-mixture
        phase-lock regime.
    """

    kind: str
    bytes_per_src: float = 256 * MB
    hot_frac: float = 0.7
    hot: int = 0             # diurnal: the fixed hotspot destination
    period: int = 12         # diurnal: full swell cycle, windows
    swell: float = 2.0       # diurnal: peak magnitude multiplier
    dwell: int = 8           # drift/flips: windows per hotspot epoch
    ramp: int = 2            # drift: crossfade windows at an epoch change
    n_hot: int = 2           # flips: popular destinations per epoch
    phase: int = 0           # window offset (phase-shifted co-tenants)
    jitter: float = 0.02
    seed: int = 0

    def __post_init__(self):
        if self.kind not in TRAFFIC_KINDS:
            raise ValueError(
                f"unknown traffic kind {self.kind!r}; one of {TRAFFIC_KINDS}"
            )
        if self.bytes_per_src <= 0:
            raise ValueError("bytes_per_src must be > 0")
        if not 0.0 < self.hot_frac <= 1.0:
            raise ValueError(f"hot_frac must be in (0, 1], got {self.hot_frac}")
        if self.period < 2 or self.dwell < 1:
            raise ValueError("period must be >= 2 and dwell >= 1")
        if self.swell < 1.0:
            raise ValueError(f"swell must be >= 1.0, got {self.swell}")
        if self.ramp < 0 or self.n_hot < 1:
            raise ValueError("ramp must be >= 0 and n_hot >= 1")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")

    # -- window -> demand --------------------------------------------------------
    def _skewed(self, n: int, hots: Tuple[int, ...], frac: float,
                scale: float) -> np.ndarray:
        """``frac`` of every source's bytes split across ``hots``."""
        bps = self.bytes_per_src * scale
        D = np.zeros((n, n))
        for s in range(n):
            hs = [h for h in hots if h != s]
            cold = [d for d in range(n) if d != s and d not in hs]
            if not hs or frac <= 0.0:
                for d in cold:
                    D[s, d] = bps / len(cold)
                continue
            for h in hs:
                D[s, h] = bps * frac / len(hs)
            for d in cold:
                D[s, d] = bps * (1.0 - frac) / len(cold)
        return D

    def _drift_hot(self, n: int, epoch: int) -> int:
        """Deterministic migrating hotspot: alternates node halves, then
        walks within the half — every migration crosses inter-group rails."""
        half = max(n // 2, 1)
        return (epoch % 2) * half + (epoch // 2) % half

    def demand(self, window: int, n: int) -> np.ndarray:
        """The ``[n, n]`` demand matrix this program emits at ``window``."""
        w = window + self.phase
        if self.kind == "steady":
            D = self._skewed(n, (), 0.0, 1.0)
        elif self.kind == "diurnal":
            s = 0.5 * (1.0 - np.cos(2.0 * np.pi * w / self.period))
            D = self._skewed(
                n, (self.hot % n,), self.hot_frac * s,
                1.0 + (self.swell - 1.0) * s,
            )
        elif self.kind == "drift":
            epoch, off = divmod(w, self.dwell)
            cur = self._skewed(
                n, (self._drift_hot(n, epoch),), self.hot_frac, 1.0
            )
            if epoch > 0 and off < self.ramp:
                mix = (off + 1) / (self.ramp + 1)
                prev = self._skewed(
                    n, (self._drift_hot(n, epoch - 1),), self.hot_frac, 1.0
                )
                cur = mix * cur + (1.0 - mix) * prev
            D = cur
        else:  # flips
            epoch = w // self.dwell
            rng = np.random.default_rng((self.seed, 7919, epoch))
            hots = tuple(
                int(h) for h in rng.choice(n, size=min(self.n_hot, n),
                                           replace=False)
            )
            D = self._skewed(n, hots, self.hot_frac, 1.0)
        if self.jitter > 0.0:
            rng = np.random.default_rng((self.seed, window))
            noise = 1.0 + self.jitter * rng.standard_normal((n, n))
            D = D * np.clip(noise, 0.25, 4.0)
        np.fill_diagonal(D, 0.0)
        return D


# -- tenants and churn ------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's identity, entitlement, traffic, and lifetime.

    ``join_window`` / ``leave_window`` are *scenario* windows: the control
    plane spawns the tenant's session at ``join_window`` and retires it
    (clean close: ledger withdrawn, bus unsubscribed) at ``leave_window``;
    ``None`` runs to the end of the scenario.
    """

    name: str
    traffic: TrafficProgram
    qos: str = "standard"
    weight: float = 1.0
    join_window: int = 0
    leave_window: Optional[int] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if self.join_window < 0:
            raise ValueError(f"join_window must be >= 0, got {self.join_window}")
        if self.leave_window is not None and self.leave_window <= self.join_window:
            raise ValueError(
                f"tenant {self.name!r}: leave_window {self.leave_window} "
                f"must come after join_window {self.join_window}"
            )


@dataclasses.dataclass(frozen=True)
class ChurnSpec:
    """Deterministic schedule of short-lived tenants joining and leaving.

    ``compile_churn`` expands this into concrete :class:`TenantSpec`\\ s in
    one fixed draw order from ``np.random.default_rng(seed)`` — the same
    (spec, horizon) pair always yields the bit-identical schedule (pinned
    by a hypothesis property in ``tests/test_serve_scenarios.py``).
    """

    template: TrafficProgram
    n_tenants: int = 4
    lifetime: int = 6        # windows each churned tenant lives
    spacing: int = 3         # windows between consecutive joins
    start: int = 2
    jitter: int = 1          # +- windows on each join/lifetime draw
    qos: str = "scavenger"
    weight: float = 1.0
    name_prefix: str = "churn"
    seed: int = 0

    def __post_init__(self):
        if self.n_tenants < 1:
            raise ValueError(f"n_tenants must be >= 1, got {self.n_tenants}")
        if self.lifetime < 1 or self.spacing < 1:
            raise ValueError("lifetime and spacing must be >= 1")
        if self.start < 0 or self.jitter < 0:
            raise ValueError("start and jitter must be >= 0")


def compile_churn(spec: ChurnSpec, windows: int) -> Tuple[TenantSpec, ...]:
    """Expand a churn spec over a ``windows``-long horizon.

    Fixed draw order — two draws per tenant slot, always taken, even for
    slots that fall past the horizon — so the schedule is deterministic in
    (spec, windows) and a longer horizon only *extends* the prefix.
    """
    rng = np.random.default_rng(spec.seed)
    out: List[TenantSpec] = []
    for i in range(spec.n_tenants):
        j_off = int(rng.integers(-spec.jitter, spec.jitter + 1))
        l_off = int(rng.integers(-spec.jitter, spec.jitter + 1))
        join = max(spec.start + i * spec.spacing + j_off, 0)
        life = max(spec.lifetime + l_off, 1)
        if join >= windows - 1:
            continue  # would never step before teardown
        out.append(
            TenantSpec(
                name=f"{spec.name_prefix}-{i:02d}",
                traffic=spec.template,
                qos=spec.qos,
                weight=spec.weight,
                join_window=join,
                leave_window=join + life,
            )
        )
    return tuple(out)


# -- SLOs -------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SloSpec:
    """The gates a scenario run must hold (DESIGN.md §10.3).

    Latency gates are *relative* by default (robust across fabric scales):
    the cluster p99 window latency must stay within
    ``p99_latency_factor`` x the median, with an optional absolute ceiling
    ``p99_latency_s``.  The drain gates compare against the **unpriced
    static baseline** arm on the same scenario: ``combined_win_floor`` is
    the floor on ``static total completion / adaptive total completion``
    (1.0 = must not lose; 0.99 = parity) and ``min_drain_ratio`` the
    per-tenant floor on the same ratio.  ``jain_floor`` gates weighted
    fairness across tenants, ``max_recovery_windows`` the windows allowed
    between the drill's final link event and cluster latency returning to
    1.5x the healthy median, and ``availability_floor`` the fraction of
    windows served within ``availability_factor`` x the healthy median.
    """

    p99_latency_factor: float = 3.0
    p99_latency_s: Optional[float] = None
    combined_win_floor: float = 1.0
    min_drain_ratio: float = 0.9
    jain_floor: float = 0.8
    max_recovery_windows: Optional[int] = None
    availability_floor: float = 0.9
    availability_factor: float = 5.0

    def __post_init__(self):
        if self.p99_latency_factor < 1.0:
            raise ValueError("p99_latency_factor must be >= 1.0")
        if self.p99_latency_s is not None and self.p99_latency_s <= 0:
            raise ValueError("p99_latency_s must be > 0 or None")
        if self.combined_win_floor <= 0 or self.min_drain_ratio <= 0:
            raise ValueError("drain floors must be > 0")
        if not 0.0 <= self.jain_floor <= 1.0:
            raise ValueError("jain_floor must be in [0, 1]")
        if self.max_recovery_windows is not None and self.max_recovery_windows < 0:
            raise ValueError("max_recovery_windows must be >= 0 or None")
        if not 0.0 <= self.availability_floor <= 1.0:
            raise ValueError("availability_floor must be in [0, 1]")
        if self.availability_factor < 1.0:
            raise ValueError("availability_factor must be >= 1.0")


# -- the scenario -----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One named, seeded, fully-declarative serving scenario."""

    name: str
    topology: TopologySpec
    windows: int
    tenants: Tuple[TenantSpec, ...]
    churn: Optional[ChurnSpec] = None
    faults: Optional[FaultScenario] = None
    slo: SloSpec = SloSpec()
    seed: int = 0

    def __post_init__(self):
        if not isinstance(self.tenants, tuple):
            object.__setattr__(self, "tenants", tuple(self.tenants))
        if self.windows < 1:
            raise ValueError(f"windows must be >= 1, got {self.windows}")
        if not self.tenants:
            raise ValueError("a scenario needs at least one tenant")
        names = [t.name for t in self.roster()]
        dupes = {n for n in names if names.count(n) > 1}
        if dupes:
            raise ValueError(
                f"duplicate tenant name {sorted(dupes)[0]!r} in scenario "
                f"{self.name!r}"
            )

    def roster(self) -> Tuple[TenantSpec, ...]:
        """Base tenants plus the compiled churn schedule (fixed order)."""
        extra = (
            compile_churn(self.churn, self.windows) if self.churn else ()
        )
        return self.tenants + extra

    def without_churn(self) -> "ScenarioSpec":
        """The never-churned control: base tenants only, same everything
        else — the reference arm for the survivor-drain gate."""
        return dataclasses.replace(self, churn=None)

    # -- JSON round trip ---------------------------------------------------------
    def to_json_obj(self) -> dict:
        if self.topology.caps is not None or self.topology.link_scale:
            raise ValueError(
                "scenario JSON carries only plain topology geometry "
                "(n_devices / group_size / n_pods); custom caps or "
                "link_scale belong in code-built specs"
            )
        obj = {
            "name": self.name,
            "topology": {
                "n_devices": self.topology.n_devices,
                "group_size": self.topology.group_size,
                "n_pods": self.topology.n_pods,
            },
            "windows": self.windows,
            "tenants": [_tenant_to_obj(t) for t in self.tenants],
            "churn": _churn_to_obj(self.churn) if self.churn else None,
            "faults": _faults_to_obj(self.faults) if self.faults else None,
            "slo": dataclasses.asdict(self.slo),
            "seed": self.seed,
        }
        return tag("serve_scenario", obj)

    def to_json(self) -> bytes:
        return json_dumps(self.to_json_obj(), indent=True)

    @staticmethod
    def from_json_obj(obj: dict) -> "ScenarioSpec":
        if not isinstance(obj, dict):
            raise ValueError(f"scenario must be a dict, got {type(obj).__name__}")
        obj = dict(obj)
        schema = obj.pop("schema", SCENARIO_SCHEMA)
        if schema != SCENARIO_SCHEMA:
            raise ValueError(
                f"scenario schema {schema!r} != {SCENARIO_SCHEMA!r}"
            )
        _check_keys(
            obj,
            ("name", "topology", "windows", "tenants", "churn", "faults",
             "slo", "seed"),
            "scenario",
        )
        topo_obj = dict(obj.get("topology") or {})
        _check_keys(
            topo_obj, ("n_devices", "group_size", "n_pods"),
            "scenario.topology",
        )
        churn = obj.get("churn")
        faults = obj.get("faults")
        return ScenarioSpec(
            name=obj["name"],
            topology=TopologySpec(**topo_obj),
            windows=obj["windows"],
            tenants=tuple(
                _tenant_from_obj(t) for t in obj.get("tenants", [])
            ),
            churn=_churn_from_obj(churn) if churn is not None else None,
            faults=_faults_from_obj(faults) if faults is not None else None,
            slo=_build(SloSpec, obj.get("slo") or {}, "scenario.slo"),
            seed=obj.get("seed", 0),
        )

    @staticmethod
    def from_json(data) -> "ScenarioSpec":
        if isinstance(data, str):
            data = data.encode()
        return ScenarioSpec.from_json_obj(json_loads(data))


# -- (de)serialization helpers ----------------------------------------------------

def _check_keys(obj: dict, allowed, what: str) -> None:
    """Reject unknown keys, naming the first offender — a typo'd scenario
    file must fail loudly, not silently drop a gate."""
    for k in obj:
        if k not in allowed:
            raise ValueError(f"{what}: unknown key {k!r}")


def _build(cls, obj: dict, what: str):
    """Strictly construct a flat frozen dataclass from a JSON dict."""
    if not isinstance(obj, dict):
        raise ValueError(f"{what}: expected a dict, got {type(obj).__name__}")
    _check_keys(obj, tuple(f.name for f in dataclasses.fields(cls)), what)
    return cls(**obj)


def _tenant_to_obj(t: TenantSpec) -> dict:
    obj = dataclasses.asdict(t)
    obj["traffic"] = dataclasses.asdict(t.traffic)
    return obj


def _tenant_from_obj(obj: dict) -> TenantSpec:
    if not isinstance(obj, dict):
        raise ValueError(f"tenant: expected a dict, got {type(obj).__name__}")
    obj = dict(obj)
    _check_keys(
        obj,
        tuple(f.name for f in dataclasses.fields(TenantSpec)),
        f"tenant {obj.get('name', '?')!r}",
    )
    traffic = _build(
        TrafficProgram, obj.pop("traffic", {}),
        f"tenant {obj.get('name', '?')!r}.traffic",
    )
    return TenantSpec(traffic=traffic, **obj)


def _churn_to_obj(c: ChurnSpec) -> dict:
    obj = dataclasses.asdict(c)
    obj["template"] = dataclasses.asdict(c.template)
    return obj


def _churn_from_obj(obj: dict) -> ChurnSpec:
    if not isinstance(obj, dict):
        raise ValueError(f"churn: expected a dict, got {type(obj).__name__}")
    obj = dict(obj)
    _check_keys(
        obj, tuple(f.name for f in dataclasses.fields(ChurnSpec)), "churn"
    )
    template = _build(
        TrafficProgram, obj.pop("template", {}), "churn.template"
    )
    return ChurnSpec(template=template, **obj)


#: fault-scenario list fields -> their leaf spec classes
_FAULT_FIELDS = {
    "flaps": LinkFlapSpec,
    "rail_losses": RailLossSpec,
    "blackouts": TelemetryBlackoutSpec,
    "stragglers": StragglerSpec,
    "crashes": TenantCrashSpec,
    "elephants": ElephantFlowSpec,
}


def _faults_to_obj(f: FaultScenario) -> dict:
    obj: dict = {"name": f.name, "seed": f.seed}
    for field, _ in _FAULT_FIELDS.items():
        specs = getattr(f, field)
        if specs:
            obj[field] = [dataclasses.asdict(s) for s in specs]
    return obj


def _faults_from_obj(obj: dict) -> FaultScenario:
    if not isinstance(obj, dict):
        raise ValueError(f"faults: expected a dict, got {type(obj).__name__}")
    obj = dict(obj)
    _check_keys(obj, ("name", "seed") + tuple(_FAULT_FIELDS), "faults")
    kwargs: dict = {
        "name": obj.get("name", "faults"),
        "seed": obj.get("seed", 0),
    }
    for field, cls in _FAULT_FIELDS.items():
        specs = obj.get(field)
        if specs:
            kwargs[field] = tuple(
                _build(cls, s, f"faults.{field}[{i}]")
                for i, s in enumerate(specs)
            )
    return FaultScenario(**kwargs)


# -- built-in library -------------------------------------------------------------

_TOPO8 = TopologySpec(8, group_size=4)


def _steady() -> ScenarioSpec:
    """Two balanced tenants, no drills: adaptive must match static."""
    return ScenarioSpec(
        name="steady",
        topology=_TOPO8,
        windows=24,
        tenants=(
            TenantSpec("web", TrafficProgram("steady", seed=1)),
            TenantSpec("batch", TrafficProgram("steady", seed=2),
                       qos="scavenger"),
        ),
        slo=SloSpec(
            p99_latency_factor=1.5,
            combined_win_floor=0.99,
            min_drain_ratio=0.95,
            jain_floor=0.9,
            availability_floor=0.95,
        ),
    )


def _diurnal() -> ScenarioSpec:
    """Phase-shifted diurnal skew swell on two tenants: each tenant's
    hotspot concentrates and relaxes on an 18-window day, half a day out
    of phase with its peer — the aggregate shape never stops moving."""
    return ScenarioSpec(
        name="diurnal",
        topology=_TOPO8,
        windows=36,
        tenants=(
            TenantSpec(
                "east",
                TrafficProgram("diurnal", hot=0, period=18, swell=2.0,
                               hot_frac=0.7, seed=3),
            ),
            TenantSpec(
                "west",
                TrafficProgram("diurnal", hot=4, period=18, swell=2.0,
                               hot_frac=0.7, phase=9, seed=4),
            ),
        ),
        slo=SloSpec(
            p99_latency_factor=3.0,
            combined_win_floor=1.0,
            min_drain_ratio=0.9,
            jain_floor=0.8,
        ),
    )


def _churn_storm() -> ScenarioSpec:
    """One long-lived drifting tenant under a storm of short-lived
    scavenger tenants; the survivor's drain must shrug the churn off."""
    return ScenarioSpec(
        name="churn_storm",
        topology=_TOPO8,
        windows=32,
        tenants=(
            TenantSpec("survivor", TrafficProgram("drift", dwell=8, seed=5)),
        ),
        churn=ChurnSpec(
            template=TrafficProgram("steady", bytes_per_src=64 * MB, seed=6),
            n_tenants=5,
            lifetime=6,
            spacing=4,
            start=4,
            jitter=1,
            seed=11,
        ),
        slo=SloSpec(
            p99_latency_factor=3.0,
            combined_win_floor=1.0,
            min_drain_ratio=0.85,
            jain_floor=0.5,      # scavenger churners are *entitled* to less
        ),
    )


def _flap_under_load() -> ScenarioSpec:
    """Drifting skew while a rail link flaps down/up — the execution-time
    case for replanning: static keeps routing into the dead link."""
    return ScenarioSpec(
        name="flap_under_load",
        topology=_TOPO8,
        windows=32,
        tenants=(
            TenantSpec("app", TrafficProgram("drift", dwell=8, seed=7)),
            TenantSpec("side", TrafficProgram("steady",
                                              bytes_per_src=128 * MB,
                                              seed=8)),
        ),
        faults=FaultScenario(
            name="flap_under_load",
            flaps=(
                LinkFlapSpec(src=0, dst=4, start=10, cycles=2,
                             down_windows=2, up_windows=3),
            ),
        ),
        slo=SloSpec(
            p99_latency_factor=6.0,   # flap windows are *supposed* to spike
            combined_win_floor=1.0,
            min_drain_ratio=0.9,
            jain_floor=0.7,
            max_recovery_windows=2,
            availability_floor=0.8,
        ),
    )


def _elephant_victim() -> ScenarioSpec:
    """A victim tenant absorbing sustained background elephant flows on a
    rail pair (arxiv 2604.11432's victim-flow scenario): adaptive re-solves
    spread the elephant across alternates, static funnels it through the
    pre-elephant split and the victim's p99 spikes."""
    return ScenarioSpec(
        name="elephant_victim",
        topology=_TOPO8,
        windows=30,
        tenants=(
            TenantSpec("victim", TrafficProgram("steady", seed=9)),
            TenantSpec("peer", TrafficProgram("steady",
                                              bytes_per_src=128 * MB,
                                              seed=10)),
        ),
        faults=FaultScenario(
            name="elephant_victim",
            seed=13,
            elephants=(
                ElephantFlowSpec(src=1, dst=5, start=8, duration=16,
                                 bytes_per_window=1024.0 * MB, jitter=0.1),
            ),
        ),
        slo=SloSpec(
            p99_latency_factor=6.0,
            combined_win_floor=1.0,
            # priced tenants cede some *solo* drain (longer alternate
            # paths) to win the combined stack — calibrated: worst tenant
            # 0.83x solo for a 1.36x combined win
            min_drain_ratio=0.8,
            jain_floor=0.7,
        ),
    )


def _minimal() -> ScenarioSpec:
    """Smallest end-to-end scenario: two tenants, six windows — the
    ``repro.api.selfcheck`` check-6 fixture, registry-hosted so it stays
    round-trippable and launchable like every other built-in."""
    return ScenarioSpec(
        name="minimal",
        topology=_TOPO8,
        windows=6,
        tenants=(
            TenantSpec("a", TrafficProgram("steady", seed=1)),
            TenantSpec("b", TrafficProgram("steady", seed=2)),
        ),
        slo=SloSpec(p99_latency_factor=2.0, jain_floor=0.8,
                    availability_floor=0.9),
    )


#: name -> builder for the built-in scenario library
BUILTIN_SCENARIOS = {
    "steady": _steady,
    "diurnal": _diurnal,
    "churn_storm": _churn_storm,
    "flap_under_load": _flap_under_load,
    "elephant_victim": _elephant_victim,
    "minimal": _minimal,
}


def scenario_names() -> List[str]:
    return sorted(BUILTIN_SCENARIOS)


def get_scenario(name: str) -> ScenarioSpec:
    """Resolve a built-in scenario by name (fresh spec every call)."""
    try:
        return BUILTIN_SCENARIOS[name]()
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; one of {scenario_names()}"
        ) from None


def load_scenario(name_or_path: str) -> ScenarioSpec:
    """Registry name or a path to a ``nimble.serve_scenario/v1`` JSON file."""
    if name_or_path in BUILTIN_SCENARIOS:
        return BUILTIN_SCENARIOS[name_or_path]()
    import os

    if os.path.exists(name_or_path):
        with open(name_or_path, "rb") as f:
            return ScenarioSpec.from_json(f.read())
    raise ValueError(
        f"{name_or_path!r} is neither a built-in scenario "
        f"({scenario_names()}) nor a scenario JSON file"
    )
