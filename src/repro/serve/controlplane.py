"""Continuous-traffic control plane over the NIMBLE stack (DESIGN.md §10).

:class:`ControlPlane` turns a declarative :class:`~repro.serve.scenario.
ScenarioSpec` into a *running service*: it owns one shared fabric, spawns
and retires tenant sessions on the scenario's churn schedule, advances
every live tenant window-by-window through ``Session.step`` while
streaming the embedded fault schedule in via the ``step(observed=,
completion_scale=)`` drill hooks (DESIGN.md §9), and keeps **online** SLO
accounting as it goes — ring-buffer latency percentiles, per-tenant drain
ledgers, availability against the healthy-median baseline.  The outcome is
a tagged ``nimble.serve/v1`` :class:`ServeReport`.

Two arms, one loop: ``mode="adaptive"`` runs each tenant as an arbitrated
:class:`~repro.api.Session` on a shared congestion-pricing
:class:`~repro.fabric.FabricArbiter` (calibrated price recency on);
``mode="static"`` runs each tenant as a one-shot plan solved at join and
never revisited — the unpriced baseline every drain SLO is measured
against.  :func:`evaluate_slo` applies a scenario's :class:`~repro.serve.
scenario.SloSpec` gates to an (adaptive, static) report pair and is what
``benchmarks/run.py --smoke`` gates as ``serve_slo``.

Cluster latency is the **stacked** per-window drain — every live tenant's
executed per-resource load summed, drained at the *current* (possibly
degraded) capacities — the same contention metric the fairness bench
gates, not the per-tenant solo simulation (which feeds the per-tenant
ledgers instead).  Fault-window event timing is translated per tenant: a
scenario-window event reaches a churned tenant shifted into its *local*
window clock, so a tenant that joined at window 12 sees a window-20 flap
exactly 8 windows into its own life.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..api.session import Session
from ..api.spec import PRICE_DECAY_DEFAULT, SessionSpec
from ..core.cost import ResourceModel
from ..core.fabsim import simulate
from ..core.mcf import apply_plan_fractions
from ..core.planner import PlannerConfig
from ..fabric import ArbiterConfig, FabricArbiter
from ..faults.injector import FaultInjector, FaultSchedule
from ..jsonio import schema_kind, tag
from ..runtime.controller import demand_dict, solve_plans_batch
from ..runtime.events import LinkEvent, merge_overrides
from .scenario import ScenarioSpec, SloSpec, TenantSpec

#: control-plane arms
SERVE_MODES = ("adaptive", "static")

#: recovery threshold: cluster latency back within this factor of the
#: healthy median counts as recovered (matches the fault-drill harness)
RECOVERY_FACTOR = 1.5


class RingPercentiles:
    """Bounded online latency window: percentiles over the last N samples.

    The control plane never holds the full history hostage to the horizon
    — a week-long scenario keeps O(capacity) floats per ring, and the SLO
    percentiles are over the trailing window, which is what a serving p99
    means anyway.
    """

    def __init__(self, capacity: int = 512):
        if capacity < 1:
            raise ValueError(f"ring capacity must be >= 1, got {capacity}")
        self._ring: collections.deque = collections.deque(maxlen=capacity)

    def add(self, value: float) -> None:
        self._ring.append(float(value))

    def __len__(self) -> int:
        return len(self._ring)

    def percentile(self, p: float) -> float:
        if not self._ring:
            return 0.0
        return float(np.percentile(np.asarray(self._ring), p))

    def median(self) -> float:
        return self.percentile(50.0)

    def values(self) -> List[float]:
        """The retained trailing samples, oldest first."""
        return list(self._ring)


@dataclasses.dataclass
class TenantLedger:
    """Per-tenant online drain accounting (one per spawned session)."""

    name: str
    qos: str
    weight: float
    joined: int
    left: Optional[int] = None
    crashed: bool = False
    windows: int = 0
    payload_bytes: float = 0.0
    completion_s: float = 0.0
    replans: int = 0
    ring: RingPercentiles = dataclasses.field(
        default_factory=lambda: RingPercentiles()
    )

    def record(self, completion_s: float, payload_bytes: float,
               replan_issued: bool) -> None:
        self.windows += 1
        self.completion_s += completion_s
        self.payload_bytes += payload_bytes
        self.replans += int(replan_issued)
        self.ring.add(completion_s)

    def throughput_gbs(self) -> float:
        if self.completion_s <= 0:
            return 0.0
        return self.payload_bytes / self.completion_s / 1e9

    def to_json_obj(self) -> dict:
        return {
            "qos": self.qos,
            "weight": self.weight,
            "joined": self.joined,
            "left": self.left,
            "crashed": self.crashed,
            "windows": self.windows,
            "payload_bytes": self.payload_bytes,
            "completion_s": self.completion_s,
            "mean_completion_s": (
                self.completion_s / self.windows if self.windows else 0.0
            ),
            "p99_completion_s": self.ring.percentile(99.0),
            "replans": self.replans,
            "throughput_gbs": self.throughput_gbs(),
        }


class _StaticTenant:
    """Baseline arm: one plan solved at join, followed forever.

    Mirrors ``runtime.run_static`` — the solve happens once on the
    join-window demand and join-time (possibly already degraded) fabric;
    every later window executes under those frozen split ratios on
    whatever the fabric has become.  No telemetry, no pricing, no replan.
    """

    def __init__(self, topo, demand0: np.ndarray,
                 pcfg: Optional[PlannerConfig] = None):
        self._pcfg = pcfg or PlannerConfig(n_iters=32)
        self._plan = solve_plans_batch(
            topo, demand0[None], None, self._pcfg
        )[0]
        self._chunk_bytes = float(1 << 20)

    def step(self, demand: np.ndarray, topo, completion_scale: float = 1.0):
        """(completion_s, payload_bytes, resource_bytes) for one window."""
        dem = demand_dict(np.asarray(demand, dtype=np.float64))
        exec_plan = apply_plan_fractions(self._plan, dem, topo=topo)
        sim = simulate(exec_plan, self._chunk_bytes)
        return (
            float(sim.completion_time) * completion_scale,
            float(sim.total_payload),
            exec_plan.resource_bytes,
        )

    def close(self) -> None:
        pass


@dataclasses.dataclass
class ServeReport:
    """Outcome of one control-plane run (``nimble.serve/v1``)."""

    scenario: str
    mode: str
    windows: int
    n_devices: int
    seed: int
    tenants: Dict[str, TenantLedger]
    window_latency_s: List[float]       # per-window stacked cluster drain
    healthy_median_s: float
    fault_start: Optional[int]
    last_event_window: Optional[int]
    recovery_windows: Optional[int]
    availability: float
    jain_index: float
    fault_digest: Optional[str] = None
    fairness: Optional[dict] = None     # fabric fairness (adaptive arm)
    metrics: Optional[dict] = None      # nimble.metrics/v1 (recorder runs)

    @property
    def total_completion_s(self) -> float:
        """Cluster service time: sum of the stacked per-window drains."""
        return float(sum(self.window_latency_s))

    def median_latency_s(self) -> float:
        if not self.window_latency_s:
            return 0.0
        return float(np.median(np.asarray(self.window_latency_s)))

    def p99_latency_s(self) -> float:
        if not self.window_latency_s:
            return 0.0
        return float(np.percentile(np.asarray(self.window_latency_s), 99.0))

    def tenant_completion(self, name: str) -> float:
        return self.tenants[name].completion_s

    def to_json_obj(self) -> dict:
        med = self.median_latency_s()
        p99 = self.p99_latency_s()
        payload = {
            "scenario": self.scenario,
            "mode": self.mode,
            "windows": self.windows,
            "n_devices": self.n_devices,
            "seed": self.seed,
            "tenants": {
                t: led.to_json_obj() for t, led in sorted(self.tenants.items())
            },
            "cluster": {
                "total_completion_s": self.total_completion_s,
                "median_latency_s": med,
                "p99_latency_s": p99,
                "p99_over_median": (p99 / med) if med > 0 else 1.0,
                "healthy_median_s": self.healthy_median_s,
                "availability": self.availability,
                "jain_index": self.jain_index,
                "fault_start": self.fault_start,
                "last_event_window": self.last_event_window,
                "recovery_windows": self.recovery_windows,
            },
        }
        if self.fault_digest is not None:
            payload["fault_digest"] = self.fault_digest
        if self.fairness is not None:
            payload["fairness"] = self.fairness
        if self.metrics is not None:
            payload["metrics"] = self.metrics
        return tag("serve", payload)


class ControlPlane:
    """Run one scenario end-to-end: spawn → serve → drill → retire."""

    def __init__(self, spec: ScenarioSpec, mode: str = "adaptive",
                 recorder=None):
        if mode not in SERVE_MODES:
            raise ValueError(f"unknown mode {mode!r}; one of {SERVE_MODES}")
        self.spec = spec
        self.mode = mode
        # flight recorder (repro.obs.FlightRecorder, duck-typed): threaded
        # down to every spawned Session so the whole scenario records under
        # one correlation id; None / disabled keeps this path byte-free
        self._obs = (
            recorder
            if recorder is not None and getattr(recorder, "enabled", False)
            else None
        )
        self.topo_base = spec.topology.build()
        self.schedule: Optional[FaultSchedule] = (
            FaultInjector(self.topo_base).compile(spec.faults)
            if spec.faults is not None
            else None
        )
        self.roster: Tuple[TenantSpec, ...] = spec.roster()
        # background elephant flows are injected into exactly one tenant's
        # executed demand — the first base tenant (the scenario's victim) —
        # so the extra bytes hit the fabric once, not once per tenant
        self._elephant_target = spec.tenants[0].name

    # -- the loop ----------------------------------------------------------------
    def run(self) -> ServeReport:
        spec, schedule = self.spec, self.schedule
        n = self.topo_base.n_devices
        adaptive = self.mode == "adaptive"

        arbiter: Optional[FabricArbiter] = None
        if adaptive:
            arbiter = FabricArbiter(
                self.topo_base,
                cfg=ArbiterConfig(price_decay=PRICE_DECAY_DEFAULT),
            )
        topo_now = self.topo_base
        overrides: Dict[Tuple[int, int], float] = {}
        static_rm = ResourceModel(topo_now)

        events_by_window: Dict[int, List[LinkEvent]] = {}
        if schedule is not None:
            for ev in schedule.events:
                events_by_window.setdefault(ev.window, []).append(ev)

        live: Dict[str, object] = {}
        joined_at: Dict[str, int] = {}
        ledgers: Dict[str, TenantLedger] = {}
        window_latency: List[float] = []
        cluster_ring = RingPercentiles()

        def spawn(t: TenantSpec, w: int) -> None:
            demand0 = t.traffic.demand(w, n)
            if self._obs is not None:
                self._obs.tracer.instant(
                    "spawn", "serve", "cluster",
                    {"tenant": t.name, "window": w, "mode": self.mode},
                )
            if adaptive:
                sess = Session(SessionSpec(
                    topology=self.topo_base,
                    adaptivity="arbitrated",
                    tenant=t.name,
                    qos=t.qos,
                    weight=t.weight,
                    fabric=arbiter,
                    initial_demand=demand0,
                ), recorder=self._obs)
                # a tenant joining a degraded fabric must degrade *now*:
                # replay the cumulative overrides into its local window 0
                for (src, dst), scale in sorted(overrides.items()):
                    if scale != 1.0:
                        sess.runtime.events.schedule(
                            LinkEvent(0, src, dst, scale)
                        )
                live[t.name] = sess
            else:
                live[t.name] = _StaticTenant(topo_now, demand0)
            joined_at[t.name] = w
            ledgers[t.name] = TenantLedger(
                name=t.name, qos=t.qos, weight=t.weight, joined=w
            )

        def retire(name: str, w: int, crashed: bool = False) -> None:
            live.pop(name).close()
            led = ledgers[name]
            led.left = w
            led.crashed = crashed
            if self._obs is not None:
                self._obs.tracer.instant(
                    "retire", "serve", "cluster",
                    {"tenant": name, "window": w, "crashed": crashed},
                )

        for w in range(spec.windows):
            if self._obs is not None:
                tr = self._obs.tracer
                tr.advance_to(w * 1000)
                w_span = tr.begin(
                    "scenario-window", "serve", "cluster",
                    {"window": w, "scenario": spec.name, "mode": self.mode},
                )
            else:
                w_span = None
            # retire: scheduled departures, then crash-silenced tenants
            for t in self.roster:
                if t.leave_window == w and t.name in live:
                    retire(t.name, w)
                elif (
                    t.name in live
                    and schedule is not None
                    and schedule.crashed(t.name, w)
                ):
                    retire(t.name, w, crashed=True)
            # spawn this window's joiners (skip tenants already crashed)
            for t in self.roster:
                if t.join_window == w and (
                    schedule is None or not schedule.crashed(t.name, w)
                ):
                    spawn(t, w)
            # fault events due at this scenario window
            due = events_by_window.get(w)
            if due:
                if self._obs is not None:
                    for ev in due:
                        self._obs.tracer.instant(
                            "fault", "serve", "cluster",
                            {"event": ev.describe(), "kind": ev.kind,
                             "window": w},
                        )
                batch = dict(merge_overrides(due))
                overrides.update(batch)
                topo_now = self.topo_base.with_link_scale(overrides)
                static_rm = ResourceModel(topo_now)
                if arbiter is not None:
                    # ledger capacities follow immediately (the broadcast
                    # rule); runtimes get the events shifted into their own
                    # window clocks instead of the shared bus, which only
                    # speaks absolute windows
                    arbiter.state.apply_link_overrides(batch)
                    for name, sess in live.items():
                        for ev in due:
                            sess.runtime.events.schedule(
                                dataclasses.replace(
                                    ev, window=w - joined_at[name]
                                )
                            )

            # serve: advance every live tenant, stacking executed loads
            scale = schedule.completion_scale(w) if schedule else 1.0
            stacked = np.zeros(static_rm.capacity.shape, dtype=np.float64)
            stepped = False
            for t in self.roster:
                handle = live.get(t.name)
                if handle is None:
                    continue
                D = t.traffic.demand(w, n)
                if schedule is not None and t.name == self._elephant_target:
                    D = schedule.perturbed_demand(w, D)
                if adaptive:
                    obs = schedule.observed_demand(w, D) if schedule else D
                    rep = handle.step(
                        D, observed=obs, completion_scale=scale
                    )
                    comp, payload = rep.completion_s, rep.payload_bytes
                    replanned = rep.replan_issued
                    loads = arbiter.state.committed_load(t.name)
                    if loads is not None:
                        stacked += loads
                else:
                    comp, payload, loads = handle.step(
                        D, topo_now, completion_scale=scale
                    )
                    replanned = False
                    stacked += loads
                ledgers[t.name].record(comp, payload, replanned)
                stepped = True
            if stepped:
                if adaptive:
                    lat = arbiter.state.drain_time_s(stacked) * scale
                else:
                    lat = float(np.max(stacked / static_rm.capacity)) * scale
                window_latency.append(lat)
                cluster_ring.add(lat)
            else:
                lat = 0.0
                window_latency.append(0.0)
            if w_span is not None:
                obs = self._obs
                obs.tracer.instant(
                    "drain", "serve", "cluster",
                    {"window": w, "latency_s": round(lat, 6),
                     "tenants": len(live)},
                )
                obs.tracer.end(w_span, {"latency_s": round(lat, 6)})
                obs.metrics.histogram(
                    "nimble_serve_window_latency_s",
                    {"scenario": spec.name, "mode": self.mode},
                ).observe(lat)
                obs.metrics.gauge(
                    "nimble_serve_live_tenants",
                    {"scenario": spec.name, "mode": self.mode},
                ).set(len(live))

        # fairness snapshot BEFORE teardown — unregister withdraws loads;
        # same for the metrics registry, which pulls from live runtimes
        fairness = arbiter.fairness_report() if arbiter is not None else None
        metrics = self._collect_metrics(live, arbiter)
        for name in list(live):
            retire(name, spec.windows)

        return self._finalize(window_latency, ledgers, fairness, metrics)

    def _collect_metrics(self, live: Dict[str, object],
                         arbiter: Optional[FabricArbiter]) -> Optional[dict]:
        """Pull every live layer into the recorder's registry and snapshot
        it (``nimble.metrics/v1``) — ``None`` without a recorder, keeping
        ``nimble.serve/v1`` byte-identical to the pre-obs schema."""
        if self._obs is None:
            return None
        from ..obs import collect_arbiter, collect_runtime

        reg = self._obs.metrics
        if self.mode == "adaptive":
            for name, sess in live.items():
                if getattr(sess, "runtime", None) is not None:
                    collect_runtime(reg, sess.runtime, tenant=name)
        if arbiter is not None:
            collect_arbiter(reg, arbiter)
        return reg.snapshot()

    # -- accounting --------------------------------------------------------------
    def _finalize(
        self,
        window_latency: List[float],
        ledgers: Dict[str, TenantLedger],
        fairness: Optional[dict],
        metrics: Optional[dict] = None,
    ) -> ServeReport:
        spec, schedule = self.spec, self.schedule
        lats = np.asarray(window_latency, dtype=np.float64)
        served = lats[lats > 0]

        fault_start: Optional[int] = None
        last_event: Optional[int] = None
        if schedule is not None:
            touched = (
                [ev.window for ev in schedule.events]
                + list(schedule.blackout_prob)
                + list(schedule.straggler_scale)
                + list(schedule.elephant_bytes)
                + list(schedule.crash_windows.values())
            )
            if touched:
                fault_start = min(touched)
            if schedule.events:
                last_event = max(ev.window for ev in schedule.events)

        if fault_start is not None and fault_start > 0:
            healthy = lats[:fault_start]
            healthy = healthy[healthy > 0]
        else:
            healthy = served
        healthy_median = float(np.median(healthy)) if len(healthy) else 0.0

        availability = 1.0
        if len(served) and healthy_median > 0:
            limit = spec.slo.availability_factor * healthy_median
            availability = float((served <= limit).mean())

        recovery: Optional[int] = None
        if last_event is not None and healthy_median > 0:
            for w in range(last_event, len(lats)):
                if 0 < lats[w] <= RECOVERY_FACTOR * healthy_median:
                    recovery = w - last_event
                    break

        # weighted service fairness: throughput per unit weight — a
        # weight-2 tenant is entitled to twice the bytes/s before the
        # index reads it as favored
        from ..fabric.fairness import jains_index

        shares = [
            led.throughput_gbs() / led.weight
            for led in ledgers.values()
            if led.windows > 0
        ]
        jain = jains_index(shares)

        return ServeReport(
            scenario=spec.name,
            mode=self.mode,
            windows=spec.windows,
            n_devices=self.topo_base.n_devices,
            seed=spec.seed,
            tenants=ledgers,
            window_latency_s=window_latency,
            healthy_median_s=healthy_median,
            fault_start=fault_start,
            last_event_window=last_event,
            recovery_windows=recovery,
            availability=availability,
            jain_index=jain,
            fault_digest=(
                schedule.digest() if schedule is not None else None
            ),
            fairness=fairness,
            metrics=metrics,
        )


# -- entry points -----------------------------------------------------------------

def run_scenario(spec: ScenarioSpec, mode: str = "adaptive",
                 recorder=None) -> ServeReport:
    """One arm of one scenario, end to end (optionally flight-recorded)."""
    return ControlPlane(spec, mode=mode, recorder=recorder).run()


def evaluate_scenario(spec: ScenarioSpec, recorder=None) -> dict:
    """Both arms plus the SLO verdict — the serve_slo gate's unit of work.

    A recorder, when given, records the **adaptive** arm only: the static
    arm is the unpriced baseline and must stay untouched by observability.
    """
    adaptive = run_scenario(spec, "adaptive", recorder=recorder)
    static = run_scenario(spec, "static")
    return {
        "scenario": spec.name,
        "adaptive": adaptive,
        "static": static,
        "slo": evaluate_slo(adaptive, spec.slo, baseline=static),
    }


# -- SLO gating -------------------------------------------------------------------

def evaluate_slo(
    report: ServeReport,
    slo: SloSpec,
    baseline: Optional[ServeReport] = None,
) -> dict:
    """Apply an :class:`SloSpec`'s gates to a run (vs its static baseline).

    Every gate reports ``{ok, value, limit}``; ``pass`` is their
    conjunction.  Baseline-relative gates (combined and per-tenant drain)
    are skipped when no baseline is given — a single-arm run can only be
    judged on its own latency, availability, fairness, and recovery.
    """
    gates: Dict[str, dict] = {}

    # tail latency is judged over *served* windows — those inside the
    # availability envelope (within availability_factor x the healthy
    # median).  A hard link-down window has effectively unbounded stacked
    # drain; that is an outage, charged to the availability and recovery
    # gates, not a latency sample (a request you never served has no p99).
    lats = np.asarray(report.window_latency_s, dtype=np.float64)
    lats = lats[lats > 0]
    if report.healthy_median_s > 0:
        served = lats[
            lats <= slo.availability_factor * report.healthy_median_s
        ]
        if not len(served):
            served = lats
    else:
        served = lats
    med = float(np.median(served)) if len(served) else 0.0
    p99 = float(np.percentile(served, 99.0)) if len(served) else 0.0
    factor = (p99 / med) if med > 0 else 1.0
    gates["p99_latency"] = {
        "ok": factor <= slo.p99_latency_factor,
        "value": factor,
        "limit": slo.p99_latency_factor,
    }
    if slo.p99_latency_s is not None:
        gates["p99_latency_abs"] = {
            "ok": p99 <= slo.p99_latency_s,
            "value": p99,
            "limit": slo.p99_latency_s,
        }

    gates["availability"] = {
        "ok": report.availability >= slo.availability_floor,
        "value": report.availability,
        "limit": slo.availability_floor,
    }
    gates["jain"] = {
        "ok": report.jain_index >= slo.jain_floor,
        "value": report.jain_index,
        "limit": slo.jain_floor,
    }

    if slo.max_recovery_windows is not None:
        rec = report.recovery_windows
        gates["recovery"] = {
            "ok": rec is not None and rec <= slo.max_recovery_windows,
            "value": rec,
            "limit": slo.max_recovery_windows,
        }

    if baseline is not None:
        total = report.total_completion_s
        win = (baseline.total_completion_s / total) if total > 0 else 0.0
        gates["combined_drain"] = {
            "ok": win >= slo.combined_win_floor,
            "value": win,
            "limit": slo.combined_win_floor,
        }
        ratios = []
        for name, led in report.tenants.items():
            ref = baseline.tenants.get(name)
            if ref is None or led.completion_s <= 0:
                continue
            ratios.append(ref.completion_s / led.completion_s)
        worst = min(ratios) if ratios else 1.0
        gates["tenant_drain"] = {
            "ok": worst >= slo.min_drain_ratio,
            "value": worst,
            "limit": slo.min_drain_ratio,
        }

    return {"pass": all(g["ok"] for g in gates.values()), "gates": gates}


# -- record validation (selfcheck / smoke gating) ---------------------------------

def validate_serve_record(rec: dict) -> None:
    """Raise ``ValueError`` naming the first violated ``nimble.serve/v1``
    invariant (the shape the smoke gate and check 6 trust)."""
    if schema_kind(rec) != "serve":
        raise ValueError(
            f"expected a nimble.serve record, got {rec.get('schema')!r}"
        )
    for key in ("scenario", "mode", "windows", "tenants", "cluster"):
        if key not in rec:
            raise ValueError(f"serve record missing {key!r}")
    if rec["mode"] not in SERVE_MODES:
        raise ValueError(f"serve record mode {rec['mode']!r} invalid")
    if rec["windows"] < 1:
        raise ValueError("serve record windows must be >= 1")
    if not rec["tenants"]:
        raise ValueError("serve record has no tenants")
    cl = rec["cluster"]
    for key in ("total_completion_s", "median_latency_s", "p99_latency_s",
                "availability", "jain_index"):
        if key not in cl:
            raise ValueError(f"serve record cluster missing {key!r}")
    if not 0.0 <= cl["availability"] <= 1.0:
        raise ValueError(
            f"availability {cl['availability']} outside [0, 1]"
        )
    if not 0.0 <= cl["jain_index"] <= 1.0 + 1e-9:
        raise ValueError(f"jain_index {cl['jain_index']} outside [0, 1]")
    if cl["total_completion_s"] < 0:
        raise ValueError("total_completion_s must be >= 0")
    for name, t in rec["tenants"].items():
        for key in ("completion_s", "payload_bytes", "windows"):
            if t.get(key, -1) < 0:
                raise ValueError(f"tenant {name!r}: {key} must be >= 0")
