"""Serving control plane: scenario registry + continuous-traffic harness
(DESIGN.md §10).

``repro.serve.scenario`` declares *what* to serve (tenant mixes, traffic
programs, churn, embedded fault drills, SLO gates — all JSON-round-trip
data); ``repro.serve.controlplane`` *runs* it (spawn → serve → drill →
retire over shared-fabric ``Session``\\ s, online SLO accounting,
``nimble.serve/v1`` reports); ``repro.serve.engine`` is the model-level
token-serving engine behind ``launch/serve.py``'s generation mode.

The engine is imported lazily — scenario/control-plane users (benches,
selfcheck) don't pay for the model registry.
"""

from .controlplane import (
    ControlPlane,
    RingPercentiles,
    ServeReport,
    TenantLedger,
    evaluate_scenario,
    evaluate_slo,
    run_scenario,
    validate_serve_record,
)
from .scenario import (
    BUILTIN_SCENARIOS,
    ChurnSpec,
    ScenarioSpec,
    SloSpec,
    TenantSpec,
    TrafficProgram,
    compile_churn,
    get_scenario,
    load_scenario,
    scenario_names,
)

__all__ = [
    "BUILTIN_SCENARIOS",
    "ChurnSpec",
    "ControlPlane",
    "RingPercentiles",
    "ScenarioSpec",
    "ServeEngine",
    "ServeReport",
    "SloSpec",
    "TenantLedger",
    "TenantSpec",
    "TrafficProgram",
    "compile_churn",
    "evaluate_scenario",
    "evaluate_slo",
    "get_scenario",
    "load_scenario",
    "run_scenario",
    "scenario_names",
    "validate_serve_record",
]


def __getattr__(name):
    if name == "ServeEngine":
        from .engine import ServeEngine
        return ServeEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
