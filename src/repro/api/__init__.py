"""``repro.api`` — the endpoint-driven front door (DESIGN.md §5).

One declarative :class:`SessionSpec` describes the fabric, the tenant,
and the adaptivity level (``static | adaptive | arbitrated``); one
:class:`Session` owns construction, binding order, teardown, and hands out
ready-wired endpoints (``all_to_all``, ``moe_dispatcher``, ``plan``,
``step``/``run_trace``, ``report``).  Session-built stacks are
bit-identical to the hand-wired constructors they replace — which keep
working unchanged.

    from repro.api import Session, SessionSpec, TopologySpec

    spec = SessionSpec(topology=TopologySpec(8, group_size=4),
                       adaptivity="adaptive")
    with Session(spec) as sess:
        comm = sess.all_to_all("x", max_chunks=32, chunk_bytes=2**20)
        result = sess.run_trace(trace)
        record = sess.report()

``python -m repro.api.selfcheck`` verifies the facade's guarantees in the
current environment.
"""

from .session import PLAN_MODES, Session
from .spec import (
    ADAPTIVITY_LEVELS,
    FABRIC_STALENESS_DEFAULT,
    PRICE_DECAY_DEFAULT,
    SessionSpec,
    TopologySpec,
)

__all__ = [
    "ADAPTIVITY_LEVELS",
    "FABRIC_STALENESS_DEFAULT",
    "PRICE_DECAY_DEFAULT",
    "PLAN_MODES",
    "Session",
    "SessionSpec",
    "TopologySpec",
    "validate_fairness_record",
]


def __getattr__(name: str):
    # lazy: importing .selfcheck from here would shadow
    # ``python -m repro.api.selfcheck`` (runpy double-import warning)
    if name == "validate_fairness_record":
        from .selfcheck import validate_fairness_record

        return validate_fairness_record
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
