"""Declarative session specification — everything a NIMBLE stack needs.

The paper's integration claim is that NIMBLE is *endpoint-driven* and
plugs into existing communication libraries "without requiring application
changes".  After the planner (DESIGN.md §2), runtime (§3), and fabric
arbiter (§4) landed, the wiring to get there was anything but declarative:
every caller hand-built ``Topology`` + ``CostModel`` + ``PlannerConfig`` +
``OrchestrationRuntime`` + ``FabricArbiter`` and called
``attach_telemetry`` / ``register_runtime`` in exactly the right order.
:class:`SessionSpec` replaces that plumbing with one frozen value object:
*what* fabric, *which* tenant, *how much* adaptivity — and
:class:`~repro.api.session.Session` turns it into a wired stack.

Adaptivity levels (strictly increasing capability):

  * ``"static"``     — planner only.  ``plan()`` / ``run_trace()`` solve
    one-shot; endpoints carry no telemetry.  Construction-equivalent to
    PR 1's hand wiring.
  * ``"adaptive"``   — adds an :class:`~repro.runtime.OrchestrationRuntime`
    (monitor → estimate → replan → swap); endpoints auto-attach telemetry.
  * ``"arbitrated"`` — additionally joins a shared
    :class:`~repro.fabric.FabricArbiter` as tenant ``tenant`` (weight /
    QoS / admission from this spec): solves are congestion-priced, replans
    gated, link events and price hints arrive over the shared bus.
    Price-recency protection is ON by default at this level
    (``price_decay`` / ``fabric_staleness``, calibrated on the
    mutual-drift scenarios in ``benchmarks/bench_fairness.py``): exported
    prices fade as peers' telemetry stamps go stale, pending plans are
    re-priced at the swap boundary, and a "prices moved" hint
    force-replans a demand-stable tenant.  Pass ``None`` for either knob
    to opt back out — byte-identical to the raw-ledger arbiter.

Every ``None`` component-config field falls through to the exact library
default the hand-wired constructors use, which is what makes the facade's
bit-exactness guarantee (``tests/test_session.py``) possible at all; the
two recency knobs are the one deliberate exception, and ``None`` there is
the opt-*out*.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Tuple, Union

from ..core.cost import CostModel
from ..core.planner import PlannerConfig
from ..core.topology import LinkCaps, Topology
from ..fabric import AdmissionConfig, ArbiterConfig, QOS_RANK, TenantConfig
from ..runtime import EstimatorConfig, PolicyConfig, RuntimeConfig

#: valid ``SessionSpec.adaptivity`` values, weakest first
ADAPTIVITY_LEVELS = ("static", "adaptive", "arbitrated")

#: calibrated price-recency defaults for **arbitrated** sessions (ISSUE 5,
#: DESIGN.md §4.3), chosen on the mutual-drift scenarios in
#: ``benchmarks/bench_fairness.py``: a 4-window half-life fades a peer
#: that stopped refreshing telemetry to ~3% of its committed load within
#: two dwell periods of the drift traces without perturbing fresh or
#: host-committed (unstamped) loads, and a 2-window soft deadline
#: re-prices a demand-stable tenant two windows after a "prices moved"
#: hint — late enough that one in-flight replan absorbs the shift, early
#: enough that stale avoidance never outlives a drift phase.  Both are
#: per-session knobs; ``None`` opts back out to the raw PR-3/PR-4 ledger
#: behavior (byte-identical, pinned by ``tests/test_price_recency.py``).
PRICE_DECAY_DEFAULT: float = 4.0      # half-life, windows
FABRIC_STALENESS_DEFAULT: int = 2     # windows from hint to forced replan


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Declarative fabric geometry — a :class:`Topology` as a value.

    Mirrors the ``Topology`` constructor one-for-one so specs can live in
    configs / JSON-ish call sites without importing the core; ``build()``
    is the only construction path and therefore the single place the
    session layer turns description into geometry.
    """

    n_devices: int
    group_size: int = 4
    n_pods: int = 1
    caps: Optional[LinkCaps] = None
    # (src, dst) -> capacity scale; a mapping or an iterable of pairs
    link_scale: Union[
        Mapping[Tuple[int, int], float],
        Tuple[Tuple[Tuple[int, int], float], ...],
        None,
    ] = None

    def build(self) -> Topology:
        return Topology(
            self.n_devices,
            self.group_size,
            self.n_pods,
            self.caps,
            dict(self.link_scale) if self.link_scale else None,
        )


@dataclasses.dataclass(frozen=True)
class SessionSpec:
    """One declarative description of a full NIMBLE stack.

    ``topology`` accepts either a :class:`TopologySpec` or an existing
    :class:`Topology` (callers that already hold one, e.g. benchmarks
    sweeping a fixed fabric).  ``cost`` accepts a :class:`CostModel`, a
    mapping of field overrides (``{"relay_cap": 9e10}``), or ``None`` for
    library defaults.  ``fabric`` lets an arbitrated session *join* an
    existing :class:`~repro.fabric.FabricArbiter` (multi-session
    deployments share one ledger); ``None`` makes the session construct
    and own its own.
    """

    topology: Union[TopologySpec, Topology]
    cost: Union[CostModel, Mapping, None] = None
    adaptivity: str = "static"
    # -- tenant identity (arbitrated sessions) ---------------------------------
    tenant: str = "default"
    qos: str = "standard"
    weight: float = 1.0
    admission: Optional[AdmissionConfig] = None
    # -- component overrides (None = the hand-wired constructor default) -------
    planner: Optional[PlannerConfig] = None
    runtime: Optional[RuntimeConfig] = None
    policy: Optional[PolicyConfig] = None
    estimator: Optional[EstimatorConfig] = None
    arbiter: Optional[ArbiterConfig] = None
    fabric: Optional[object] = None          # shared FabricArbiter to join
    initial_demand: Optional[object] = None  # [n, n] warm demand matrix
    # -- price recency (arbitrated sessions; ignored otherwise) ----------------
    # half-life (windows) for recency decay of peers' stamped committed
    # load in exported prices, and the soft deadline (windows) between a
    # "prices moved" hint and a forced re-pricing replan.  The calibrated
    # defaults are ON for arbitrated sessions; THESE spec-level knobs are
    # the opt-out — pass None here for raw-ledger / hint-only behavior.
    # An explicit non-None ``arbiter=ArbiterConfig(price_decay=...)`` or
    # ``policy=PolicyConfig(fabric_staleness=...)`` wins over these, but a
    # component-config None means "inherit" (it is indistinguishable from
    # the constructor default), not "disable"; a joined ``fabric`` keeps
    # its owner's arbiter config.
    price_decay: Optional[float] = PRICE_DECAY_DEFAULT
    fabric_staleness: Optional[int] = FABRIC_STALENESS_DEFAULT

    def __post_init__(self):
        if self.adaptivity not in ADAPTIVITY_LEVELS:
            raise ValueError(
                f"unknown adaptivity {self.adaptivity!r}; "
                f"one of {ADAPTIVITY_LEVELS}"
            )
        if self.qos not in QOS_RANK:
            raise ValueError(
                f"unknown qos class {self.qos!r}; one of {sorted(QOS_RANK)}"
            )
        if self.weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {self.weight}")
        if self.runtime is not None and self.planner is not None:
            raise ValueError(
                "give the planner config via runtime=RuntimeConfig("
                "planner=...) when a runtime config is supplied — two "
                "sources of planner truth would desynchronize plan() and "
                "the replan loop"
            )
        adaptive = self.adaptivity in ("adaptive", "arbitrated")
        if not adaptive:
            for field in ("runtime", "policy", "estimator", "initial_demand"):
                if getattr(self, field) is not None:
                    raise ValueError(
                        f"{field!r} requires adaptivity 'adaptive' or "
                        f"'arbitrated', not {self.adaptivity!r}"
                    )
        if self.adaptivity != "arbitrated":
            if self.fabric is not None or self.arbiter is not None:
                raise ValueError(
                    "'fabric'/'arbiter' require adaptivity 'arbitrated'"
                )
        if self.fabric is not None and self.arbiter is not None:
            raise ValueError(
                "'arbiter' configures a session-owned arbiter; a joined "
                "'fabric' already has its own config"
            )
        if self.price_decay is not None and self.price_decay <= 0:
            raise ValueError(
                f"price_decay half-life must be > 0 windows or None, got "
                f"{self.price_decay}"
            )
        if self.fabric_staleness is not None and self.fabric_staleness < 1:
            raise ValueError(
                f"fabric_staleness must be >= 1 window or None, got "
                f"{self.fabric_staleness}"
            )

    # -- builders ----------------------------------------------------------------
    def build_topology(self) -> Topology:
        if isinstance(self.topology, Topology):
            return self.topology
        return self.topology.build()

    def build_cost_model(self) -> Optional[CostModel]:
        """``None`` means "library defaults" and is passed through as-is,
        so Session-built components share the exact code paths (and value
        caches) of hand-wired ones."""
        if self.cost is None or isinstance(self.cost, CostModel):
            return self.cost
        return dataclasses.replace(CostModel(), **dict(self.cost))

    def runtime_config(self) -> Optional[RuntimeConfig]:
        """Runtime config with a bare ``planner`` override folded in."""
        if self.runtime is not None:
            return self.runtime
        if self.planner is not None:
            return RuntimeConfig(planner=self.planner)
        return None

    def tenant_config(self) -> TenantConfig:
        return TenantConfig(
            weight=self.weight,
            qos=self.qos,
            admission=self.admission or AdmissionConfig(),
        )

    def policy_config(self) -> Optional[PolicyConfig]:
        """Replan policy with the calibrated ``fabric_staleness`` folded in.

        Arbitrated sessions get the spec-level soft deadline unless the
        explicit ``policy`` already pins a non-``None`` one (a ``None``
        there is the constructor default and means "inherit" — disabling
        goes through ``SessionSpec.fabric_staleness=None``, the one knob
        that can express the opt-out).  Non-arbitrated sessions pass
        ``policy`` through untouched — without an arbiter there are no
        hints for the deadline to watch, and the hand-wired constructor
        defaults must stay bit-identical.
        """
        if self.adaptivity != "arbitrated" or self.fabric_staleness is None:
            return self.policy
        policy = self.policy or PolicyConfig()
        if policy.fabric_staleness is not None:
            return policy
        return dataclasses.replace(
            policy, fabric_staleness=self.fabric_staleness
        )

    def arbiter_config(self) -> ArbiterConfig:
        """Arbiter config with the calibrated ``price_decay`` folded in.

        Used only when the session constructs and owns its fabric; a
        joined ``fabric`` already runs under its owner's config.  An
        explicit non-``None`` ``arbiter=ArbiterConfig(price_decay=...)``
        wins over the spec-level knob; ``ArbiterConfig(price_decay=None)``
        is the constructor default and means "inherit" — disabling decay
        goes through ``SessionSpec.price_decay=None``.
        """
        cfg = self.arbiter or ArbiterConfig()
        if self.price_decay is not None and cfg.price_decay is None:
            cfg = dataclasses.replace(cfg, price_decay=self.price_decay)
        return cfg
