"""Declarative session specification — everything a NIMBLE stack needs.

The paper's integration claim is that NIMBLE is *endpoint-driven* and
plugs into existing communication libraries "without requiring application
changes".  After the planner (DESIGN.md §2), runtime (§3), and fabric
arbiter (§4) landed, the wiring to get there was anything but declarative:
every caller hand-built ``Topology`` + ``CostModel`` + ``PlannerConfig`` +
``OrchestrationRuntime`` + ``FabricArbiter`` and called
``attach_telemetry`` / ``register_runtime`` in exactly the right order.
:class:`SessionSpec` replaces that plumbing with one frozen value object:
*what* fabric, *which* tenant, *how much* adaptivity — and
:class:`~repro.api.session.Session` turns it into a wired stack.

Adaptivity levels (strictly increasing capability):

  * ``"static"``     — planner only.  ``plan()`` / ``run_trace()`` solve
    one-shot; endpoints carry no telemetry.  Construction-equivalent to
    PR 1's hand wiring.
  * ``"adaptive"``   — adds an :class:`~repro.runtime.OrchestrationRuntime`
    (monitor → estimate → replan → swap); endpoints auto-attach telemetry.
  * ``"arbitrated"`` — additionally joins a shared
    :class:`~repro.fabric.FabricArbiter` as tenant ``tenant`` (weight /
    QoS / admission from this spec): solves are congestion-priced, replans
    gated, link events and price hints arrive over the shared bus.

Every ``None`` field falls through to the exact library default the
hand-wired constructors use, which is what makes the facade's bit-exactness
guarantee (``tests/test_session.py``) possible at all.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Tuple, Union

from ..core.cost import CostModel
from ..core.planner import PlannerConfig
from ..core.topology import LinkCaps, Topology
from ..fabric import AdmissionConfig, ArbiterConfig, QOS_RANK, TenantConfig
from ..runtime import EstimatorConfig, PolicyConfig, RuntimeConfig

#: valid ``SessionSpec.adaptivity`` values, weakest first
ADAPTIVITY_LEVELS = ("static", "adaptive", "arbitrated")


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Declarative fabric geometry — a :class:`Topology` as a value.

    Mirrors the ``Topology`` constructor one-for-one so specs can live in
    configs / JSON-ish call sites without importing the core; ``build()``
    is the only construction path and therefore the single place the
    session layer turns description into geometry.
    """

    n_devices: int
    group_size: int = 4
    n_pods: int = 1
    caps: Optional[LinkCaps] = None
    # (src, dst) -> capacity scale; a mapping or an iterable of pairs
    link_scale: Union[
        Mapping[Tuple[int, int], float],
        Tuple[Tuple[Tuple[int, int], float], ...],
        None,
    ] = None

    def build(self) -> Topology:
        return Topology(
            self.n_devices,
            self.group_size,
            self.n_pods,
            self.caps,
            dict(self.link_scale) if self.link_scale else None,
        )


@dataclasses.dataclass(frozen=True)
class SessionSpec:
    """One declarative description of a full NIMBLE stack.

    ``topology`` accepts either a :class:`TopologySpec` or an existing
    :class:`Topology` (callers that already hold one, e.g. benchmarks
    sweeping a fixed fabric).  ``cost`` accepts a :class:`CostModel`, a
    mapping of field overrides (``{"relay_cap": 9e10}``), or ``None`` for
    library defaults.  ``fabric`` lets an arbitrated session *join* an
    existing :class:`~repro.fabric.FabricArbiter` (multi-session
    deployments share one ledger); ``None`` makes the session construct
    and own its own.
    """

    topology: Union[TopologySpec, Topology]
    cost: Union[CostModel, Mapping, None] = None
    adaptivity: str = "static"
    # -- tenant identity (arbitrated sessions) ---------------------------------
    tenant: str = "default"
    qos: str = "standard"
    weight: float = 1.0
    admission: Optional[AdmissionConfig] = None
    # -- component overrides (None = the hand-wired constructor default) -------
    planner: Optional[PlannerConfig] = None
    runtime: Optional[RuntimeConfig] = None
    policy: Optional[PolicyConfig] = None
    estimator: Optional[EstimatorConfig] = None
    arbiter: Optional[ArbiterConfig] = None
    fabric: Optional[object] = None          # shared FabricArbiter to join
    initial_demand: Optional[object] = None  # [n, n] warm demand matrix

    def __post_init__(self):
        if self.adaptivity not in ADAPTIVITY_LEVELS:
            raise ValueError(
                f"unknown adaptivity {self.adaptivity!r}; "
                f"one of {ADAPTIVITY_LEVELS}"
            )
        if self.qos not in QOS_RANK:
            raise ValueError(
                f"unknown qos class {self.qos!r}; one of {sorted(QOS_RANK)}"
            )
        if self.weight <= 0:
            raise ValueError(f"tenant weight must be > 0, got {self.weight}")
        if self.runtime is not None and self.planner is not None:
            raise ValueError(
                "give the planner config via runtime=RuntimeConfig("
                "planner=...) when a runtime config is supplied — two "
                "sources of planner truth would desynchronize plan() and "
                "the replan loop"
            )
        adaptive = self.adaptivity in ("adaptive", "arbitrated")
        if not adaptive:
            for field in ("runtime", "policy", "estimator", "initial_demand"):
                if getattr(self, field) is not None:
                    raise ValueError(
                        f"{field!r} requires adaptivity 'adaptive' or "
                        f"'arbitrated', not {self.adaptivity!r}"
                    )
        if self.adaptivity != "arbitrated":
            if self.fabric is not None or self.arbiter is not None:
                raise ValueError(
                    "'fabric'/'arbiter' require adaptivity 'arbitrated'"
                )
        if self.fabric is not None and self.arbiter is not None:
            raise ValueError(
                "'arbiter' configures a session-owned arbiter; a joined "
                "'fabric' already has its own config"
            )

    # -- builders ----------------------------------------------------------------
    def build_topology(self) -> Topology:
        if isinstance(self.topology, Topology):
            return self.topology
        return self.topology.build()

    def build_cost_model(self) -> Optional[CostModel]:
        """``None`` means "library defaults" and is passed through as-is,
        so Session-built components share the exact code paths (and value
        caches) of hand-wired ones."""
        if self.cost is None or isinstance(self.cost, CostModel):
            return self.cost
        return dataclasses.replace(CostModel(), **dict(self.cost))

    def runtime_config(self) -> Optional[RuntimeConfig]:
        """Runtime config with a bare ``planner`` override folded in."""
        if self.runtime is not None:
            return self.runtime
        if self.planner is not None:
            return RuntimeConfig(planner=self.planner)
        return None

    def tenant_config(self) -> TenantConfig:
        return TenantConfig(
            weight=self.weight,
            qos=self.qos,
            admission=self.admission or AdmissionConfig(),
        )
