"""``nimble.Session`` — the endpoint-driven front door (DESIGN.md §5).

One facade owns lifecycle and composition for the whole stack: it builds
the fabric from a :class:`~repro.api.spec.SessionSpec`, caches the
incidence tables, instantiates the orchestration runtime (adaptive+),
joins — or constructs — the shared fabric arbiter (arbitrated), and hands
out *ready-wired* endpoints:

  * :meth:`all_to_all` / :meth:`moe_dispatcher` — dataplane endpoints with
    telemetry already attached to the session's runtime;
  * :meth:`plan` — host-level solve, congestion-priced when arbitrated;
  * :meth:`step` / :meth:`run_trace` / :meth:`run_oracle` — the runtime
    loop (``run_trace`` on a static session is the one-shot baseline);
  * :meth:`report` — one tagged ``nimble.session/v1`` record embedding the
    existing ``nimble.<kind>/vN`` sub-schemas (runtime stats, telemetry
    aggregate, fabric fairness).

State machine: ``active`` (constructed; __enter__ requires it) → ``closed``
(:meth:`close` or context-manager exit: arbiter tenant unregistered —
ledger load withdrawn, bus unsubscribed — endpoint caches dropped; every
further call raises).  Closing is idempotent.

The facade adds *no* planning semantics: a Session-built stack produces
**byte-identical** plans and window reports to the hand-wired stack it
replaces (``tests/test_session.py`` pins static, adaptive, and arbitrated
configurations).  Direct construction of ``NimbleAllToAll`` /
``OrchestrationRuntime`` / ``FabricArbiter`` keeps working unchanged; the
facade is the recommended path, not the only one.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

import numpy as np

from ..core.dataplane import NimbleAllToAll
from ..core.mcf import Plan, solve_direct, solve_mwu, solve_static_striping
from ..core.moe_comm import MoECommConfig, MoEDispatcher
from ..core.planner import PlannerConfig
from ..core.schedule import build_planner_tables
from ..fabric import FabricArbiter, TenantConfig
from ..jsonio import tag
from ..runtime import (
    OrchestrationRuntime,
    RuntimeConfig,
    TraceResult,
    demand_dict,
    run_oracle,
    run_static,
)
from .spec import SessionSpec

#: host-plan modes understood by :meth:`Session.plan`
PLAN_MODES = ("nimble", "direct", "stripe")


class Session:
    """Wired NIMBLE stack behind one declarative spec.

    ``Session(spec)`` — or ``Session(topology=..., adaptivity=...)`` as a
    convenience for inline specs — performs all construction and binding
    in the canonical order (fabric → tables → runtime → arbiter join, the
    order ``register_runtime`` needs to keep ledger, gate, and bus in
    sync).  Use as a context manager so the tenant's ledger share is
    released on exit.
    """

    def __init__(self, spec: Optional[SessionSpec] = None, *,
                 recorder=None, **spec_kwargs):
        if spec is None:
            spec = SessionSpec(**spec_kwargs)
        elif spec_kwargs:
            raise TypeError("pass either a SessionSpec or its fields, not both")
        self.spec = spec
        # flight recorder (repro.obs, DESIGN.md §11): stored *before* the
        # runtime is built so the construction-time initial solve is traced
        # under this session's correlation id.  None (the default) leaves
        # every layer on its exact unrecorded code path.
        self._recorder = (
            recorder
            if recorder is not None and getattr(recorder, "enabled", False)
            else None
        )
        self.topo = spec.build_topology()
        self.cost_model = spec.build_cost_model()
        # incidence tables are fingerprint-cached (DESIGN.md §2.2); building
        # them here warms the cache every endpoint and solve will hit
        self.tables = build_planner_tables(self.topo, self.cost_model)
        self.runtime: Optional[OrchestrationRuntime] = None
        self.arbiter: Optional[FabricArbiter] = None
        self._owns_fabric = False
        self._registered = False
        self._endpoints: dict = {}
        self._last_trace: Optional[TraceResult] = None

        if spec.adaptivity in ("adaptive", "arbitrated"):
            self.runtime = OrchestrationRuntime.from_session(self)
        if spec.adaptivity == "arbitrated":
            if spec.fabric is not None:
                self.arbiter = spec.fabric
            else:
                self.arbiter = FabricArbiter.from_session(self)
                self._owns_fabric = True
            self.arbiter.register_runtime(
                spec.tenant, self.runtime, spec.tenant_config()
            )
            self._registered = True
        if self._recorder is not None and self.arbiter is not None:
            # shared fabrics: every joining session attaches the same
            # recorder — idempotent, last attach wins
            self.arbiter.attach_recorder(self._recorder)
        self._state = "active"

    @property
    def recorder(self):
        """The attached :class:`repro.obs.FlightRecorder` (None when the
        session runs unrecorded)."""
        return self._recorder

    # -- lifecycle ---------------------------------------------------------------
    @property
    def state(self) -> str:
        return self._state

    @property
    def fabric(self) -> Optional[FabricArbiter]:
        """The shared arbiter (None unless arbitrated).  Hand this to a
        second session's ``SessionSpec(fabric=...)`` to co-tenant it."""
        return self.arbiter

    @property
    def tenant(self) -> str:
        return self.spec.tenant

    def _require_active(self) -> None:
        if self._state != "active":
            raise RuntimeError(
                f"session {self.spec.tenant!r} is {self._state}; "
                "construct a new Session"
            )

    def close(self) -> None:
        """Tear the session down: release the ledger share, unsubscribe
        from the bus, drop endpoint caches.  Idempotent."""
        if self._state == "closed":
            return
        if self._registered and self.arbiter is not None:
            # unregister withdraws committed load, unbinds the runtime,
            # and unsubscribes the bus callback — the reverse of the
            # register_runtime composition
            self.arbiter.unregister(self.spec.tenant)
        self._registered = False
        self._endpoints.clear()
        self._state = "closed"

    def __enter__(self) -> "Session":
        self._require_active()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- co-tenancy --------------------------------------------------------------
    def join_static_tenant(
        self,
        name: str,
        load,
        cfg: Optional[TenantConfig] = None,
    ) -> str:
        """Register a non-runtime tenant and commit its load to the ledger.

        ``load`` is a ``[R]`` resource-bytes vector or any object with a
        ``resource_bytes`` attribute (a solved :class:`Plan`) — the shape
        background/legacy jobs the arbiter cannot replan take in the
        benchmarks.  Arbitrated sessions only.
        """
        self._require_active()
        if self.arbiter is None:
            raise RuntimeError(
                "join_static_tenant requires adaptivity='arbitrated'"
            )
        loads = getattr(load, "resource_bytes", load)
        self.arbiter.register(name, cfg)
        try:
            self.arbiter.commit(name, np.asarray(loads, dtype=np.float64))
        except Exception:
            # atomic join: a rejected commit (wrong shape, negative load)
            # must not leave a registered zero-load ghost that activates
            # the gate/price machinery and blocks a corrected retry
            self.arbiter.unregister(name)
            raise
        return name

    # -- endpoints ---------------------------------------------------------------
    def all_to_all(
        self,
        axis_name: str,
        *,
        max_chunks: int,
        chunk_bytes: float,
        alt_frac: float = 0.5,
        mode: str = "nimble",
        planner_cfg: Optional[PlannerConfig] = None,
    ) -> NimbleAllToAll:
        """Ready-wired dataplane endpoint (telemetry attached when the
        session runs a runtime).  Instances are cached per argument set, so
        per-layer callers share one schedule + incidence build."""
        self._require_active()
        key = (
            "a2a", axis_name, int(max_chunks), float(chunk_bytes),
            float(alt_frac), mode, planner_cfg,
        )
        if key not in self._endpoints:
            self._endpoints[key] = NimbleAllToAll.from_session(
                self,
                axis_name,
                max_chunks=max_chunks,
                chunk_bytes=chunk_bytes,
                alt_frac=alt_frac,
                mode=mode,
                planner_cfg=planner_cfg,
            )
        return self._endpoints[key]

    def moe_dispatcher(
        self,
        axis_name: str,
        cfg: MoECommConfig,
        planner_cfg: Optional[PlannerConfig] = None,
    ) -> MoEDispatcher:
        """Ready-wired expert-parallel dispatcher (runtime-fed when the
        session is adaptive)."""
        self._require_active()
        key = ("moe", axis_name, tuple(
            str(v) for v in dataclasses.asdict(cfg).values()
        ), planner_cfg)
        if key not in self._endpoints:
            self._endpoints[key] = MoEDispatcher.from_session(
                self, axis_name, cfg, planner_cfg=planner_cfg
            )
        return self._endpoints[key]

    # -- host-level planning -----------------------------------------------------
    def plan(self, demand, mode: str = "nimble", *,
             commit: Optional[bool] = None) -> Plan:
        """Solve one demand (``{(s, d): bytes}`` or an ``[n, n]`` array).

        ``mode`` selects the paper's §II-B policies: ``"nimble"`` (MWU,
        congestion-priced with the fabric's exported prices when the
        session is arbitrated), ``"direct"`` (NCCL/PXN-like least-hop), or
        ``"stripe"`` (UCX-like even striping).  ``commit`` controls
        whether the solved load is committed to the shared ledger under
        this session's tenant; the default commits exactly the arbitrated
        nimble solves (what co-planning needs), never the baselines.
        """
        self._require_active()
        dem = (
            dict(demand)
            if isinstance(demand, Mapping)
            else demand_dict(np.asarray(demand, dtype=np.float64))
        )
        if mode == "nimble":
            prices = (
                self.arbiter.prices_for(self.spec.tenant)
                if self.arbiter is not None
                else None
            )
            # thread the spec's planner knobs into the host solver so
            # plan() and the runtime's replan solves share one planner
            # truth; None keeps solve_mwu's exact defaults (which equal
            # PlannerConfig's: lam=0.25, ε=1 MiB)
            rcfg = self.spec.runtime_config()
            pcfg = rcfg.planner if rcfg is not None else None
            if pcfg is None:
                plan = solve_mwu(self.topo, dem, self.cost_model,
                                 ext_loads=prices)
            else:
                plan = solve_mwu(self.topo, dem, self.cost_model,
                                 lam=pcfg.lam, eps=pcfg.chunk_bytes,
                                 ext_loads=prices)
        elif mode == "direct":
            plan = solve_direct(self.topo, dem, self.cost_model)
        elif mode == "stripe":
            plan = solve_static_striping(self.topo, dem, self.cost_model)
        else:
            raise ValueError(f"unknown plan mode {mode!r}; one of {PLAN_MODES}")
        if commit is None:
            commit = self.arbiter is not None and mode == "nimble"
        if commit:
            if self.arbiter is None:
                raise RuntimeError("commit=True requires an arbitrated session")
            # host commits are unstamped (timeless: no window clock to
            # decay against) but fingerprint-tagged, so a session planning
            # on a different fabric geometry than the ledger's fails by
            # name instead of by shape
            self.arbiter.commit(
                self.spec.tenant, plan.resource_bytes,
                fingerprint=self.topo.fingerprint,
            )
        return plan

    # -- runtime loop ------------------------------------------------------------
    def _require_runtime(self) -> OrchestrationRuntime:
        self._require_active()
        if self.runtime is None:
            raise RuntimeError(
                "this call needs adaptivity 'adaptive' or 'arbitrated' "
                f"(session is {self.spec.adaptivity!r})"
            )
        return self.runtime

    def step(self, demand, **kw):
        """Advance the runtime loop one window (see
        ``OrchestrationRuntime.step``).  Keyword arguments — the fault
        drills' ``observed=`` / ``completion_scale=`` — pass through."""
        return self._require_runtime().step(demand, **kw)

    def run_trace(self, trace, events=None) -> TraceResult:
        """Replay a ``[W, n, n]`` traffic trace.

        Adaptive/arbitrated sessions drive the full runtime loop; a
        *static* session replays the one-shot baseline (plan on the first
        window, never replan) — the same ``TraceResult`` shape either way,
        so policy comparisons are a two-spec diff.
        """
        self._require_active()
        if self.runtime is None:
            rcfg = self.spec.runtime_config() or RuntimeConfig()
            return run_static(
                self.topo,
                trace,
                self.cost_model,
                rcfg.planner,
                chunk_bytes=rcfg.chunk_bytes,
                events=events,
            )
        result = self.runtime.run_trace(trace, events=events)
        self._last_trace = result
        return result

    def run_oracle(self, trace) -> TraceResult:
        """Clairvoyant per-window re-solve over the session's fabric — the
        adaptation upper bound for :meth:`run_trace` comparisons."""
        self._require_active()
        rcfg = self.spec.runtime_config() or RuntimeConfig()
        return run_oracle(
            self.topo, trace, self.cost_model, rcfg.planner,
            chunk_bytes=rcfg.chunk_bytes,
        )

    def prefill(self, demands) -> int:
        """Batch-solve and cache anticipated demand phases (see
        ``OrchestrationRuntime.prefill_cache``)."""
        return self._require_runtime().prefill_cache(demands)

    # -- reporting ---------------------------------------------------------------
    def report(self) -> dict:
        """One tagged ``nimble.session/v1`` record for the whole stack.

        Embeds the existing sub-schemas unchanged — ``nimble.
        runtime_stats/v1``, ``nimble.telemetry_aggregate/v1``,
        ``nimble.runtime_trace/v1`` (last ``run_trace``), ``nimble.
        fabric_fairness/v1`` and ``nimble.fabric_arbiter_stats/v1`` — so
        existing consumers (``experiments/make_report.py``, the benches)
        dispatch on the kinds they already know — plus a
        ``nimble.metrics/v1`` snapshot (DESIGN.md §11) collected from the
        live stack, whether or not a recorder is attached.
        """
        self._require_active()
        payload: dict = {
            "tenant": self.spec.tenant,
            "adaptivity": self.spec.adaptivity,
            "state": self._state,
            "topology": self.topo.describe(),
        }
        if self.runtime is not None:
            payload["runtime_stats"] = self.runtime.stats.to_json_obj()
            payload["cache"] = self.runtime.cache_info()
            payload["telemetry"] = self.runtime.telemetry.aggregate()
        if self._last_trace is not None:
            payload["trace"] = self._last_trace.to_json_obj()
        if self.arbiter is not None:
            payload["fairness"] = self.arbiter.fairness_report()
            payload["arbiter_stats"] = self.arbiter.stats.to_json_obj()
        payload["metrics"] = self._metrics_snapshot()
        return tag("session", payload)

    def _metrics_snapshot(self) -> dict:
        """``nimble.metrics/v1`` snapshot of the scattered stack health
        signals (replans, reprices, evictions, gated windows, telemetry
        rejections, estimator confidence) under the §11 naming scheme.

        Collected from a fresh registry each call — pull-based, so the
        per-window hot path never pays for it.  With a recorder attached
        its registry is used instead, folding in anything the layers
        pushed live (per-window latency histograms).
        """
        from ..obs import MetricsRegistry, collect_session

        reg = (
            self._recorder.metrics
            if self._recorder is not None
            else MetricsRegistry()
        )
        collect_session(reg, self)
        return reg.snapshot()
