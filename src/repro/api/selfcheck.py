"""Session facade selfcheck — ``python -m repro.api.selfcheck``.

Fast, CPU-only verification that the endpoint API's guarantees hold in
this environment:

  1. **static**     — ``Session.plan`` is bit-identical to hand-wired
     ``solve_mwu`` / ``solve_direct`` / ``solve_static_striping``;
  2. **adaptive**   — ``Session.run_trace`` reproduces a hand-wired
     ``OrchestrationRuntime`` window stream exactly;
  3. **arbitrated** — one two-tenant window runs through the facade and
     the exported fairness record validates against the
     ``nimble.fabric_fairness/v1`` schema;
  4. **pressure**   — a demand-stable arbitrated tenant picks up a peer's
     committed-load shift via the prices-moved hint (``reason="fabric"``);
  5. **decay**      — price recency (ISSUE 5): stamped peer loads fade
     monotonically as the fabric clock runs past them, unstamped (host)
     commits never decay, and ``price_decay=None`` exports the raw ledger
     byte-identically;
  6. **serve**      — the serving control plane (ISSUE 7, DESIGN.md §10)
     runs the registry's ``minimal`` two-tenant scenario end-to-end
     through both arms, the scenario survives a JSON round-trip
     bit-exactly, and the exported report validates against the
     ``nimble.serve/v1`` schema;
  7. **obs**        — the flight recorder (ISSUE 8, DESIGN.md §11): the
     ``minimal`` scenario rerun with tracing attached exports a valid
     ``nimble.trace/v1`` Chrome trace spanning all four layers under one
     correlation id, every swap has a provenance record, and the serve
     report embeds a ``nimble.metrics/v1`` snapshot;
  8. **lint**       — the static invariant checker (ISSUE 9, DESIGN.md
     §12): the full ``repro.analysis`` rule registry reports zero live
     findings over ``src/repro`` with the shipped (empty) baseline, the
     ``nimble.lint/v1`` report strict-parses, and ``schemas.lock.json``
     is fresh (regenerating it from source is a no-op).

``benchmarks/run.py --smoke`` reuses check 3 as its ``session_api`` gate
and check 8 as its ``static_gate``.
"""

from __future__ import annotations

import sys

import numpy as np

MB = float(1 << 20)

#: required fields of a ``nimble.fabric_fairness/v1`` record
FAIRNESS_SCHEMA = "nimble.fabric_fairness/v1"
_FAIRNESS_FIELDS = {
    "tenants": list,
    "drain_s": dict,
    "weights": dict,
    "weighted_drain_s": dict,
    "jain_index": float,
    "maxmin_violation": float,
    "combined_drain_s": float,
}


def validate_fairness_record(rec: dict) -> None:
    """Raise ``ValueError`` unless ``rec`` is a well-formed
    ``nimble.fabric_fairness/v1`` record (schema tag, field types/ranges,
    cross-field tenant consistency)."""
    if not isinstance(rec, dict):
        raise ValueError(f"fairness record is {type(rec).__name__}, not dict")
    if rec.get("schema") != FAIRNESS_SCHEMA:
        raise ValueError(
            f"schema {rec.get('schema')!r} != {FAIRNESS_SCHEMA!r}"
        )
    for field, typ in _FAIRNESS_FIELDS.items():
        if field not in rec:
            raise ValueError(f"missing field {field!r}")
        if not isinstance(rec[field], typ):
            raise ValueError(
                f"field {field!r} is {type(rec[field]).__name__}, "
                f"expected {typ.__name__}"
            )
    tenants = set(rec["tenants"])
    for field in ("drain_s", "weights", "weighted_drain_s"):
        if set(rec[field]) != tenants:
            raise ValueError(
                f"{field!r} keys {sorted(rec[field])} != tenants "
                f"{sorted(tenants)}"
            )
        for t, v in rec[field].items():
            if not isinstance(v, float) or v < 0:
                raise ValueError(f"{field}[{t!r}] = {v!r} not a float >= 0")
    if not 0.0 <= rec["jain_index"] <= 1.0:
        raise ValueError(f"jain_index {rec['jain_index']} outside [0, 1]")
    if not 0.0 <= rec["maxmin_violation"] <= 1.0:
        raise ValueError(
            f"maxmin_violation {rec['maxmin_violation']} outside [0, 1]"
        )
    if rec["combined_drain_s"] < 0:
        raise ValueError("combined_drain_s < 0")


def _skew_demand(n: int = 8, hot: int = 0, hot_frac: float = 0.7,
                 bytes_per_src: float = 64 * MB) -> dict:
    return {
        (s, d): bytes_per_src * (
            hot_frac if d == hot else (1.0 - hot_frac) / (n - 2)
        )
        for s in range(n)
        for d in range(n)
        if s != d
    }


def check_static() -> str:
    """Session.plan vs hand-wired solvers — bit-identical, all modes."""
    from ..core.mcf import solve_direct, solve_mwu, solve_static_striping
    from ..core.topology import Topology
    from . import Session, SessionSpec, TopologySpec

    D = _skew_demand()
    topo = Topology(8, group_size=4)
    refs = {
        "nimble": solve_mwu(topo, D),
        "direct": solve_direct(topo, D),
        "stripe": solve_static_striping(topo, D),
    }
    with Session(SessionSpec(topology=TopologySpec(8, group_size=4))) as sess:
        for mode, ref in refs.items():
            plan = sess.plan(D, mode=mode)
            if not (
                np.array_equal(plan.resource_bytes, ref.resource_bytes)
                and np.array_equal(plan.link_bytes, ref.link_bytes)
            ):
                raise AssertionError(f"static {mode} plan diverged")
    return "static: 3 modes bit-identical to hand-wired solvers"


def check_adaptive(windows: int = 10) -> str:
    """Session.run_trace vs hand-wired OrchestrationRuntime — identical."""
    from ..core.topology import Topology
    from ..runtime import OrchestrationRuntime, drifting_skew_trace
    from . import Session, SessionSpec

    topo = Topology(8, group_size=4)
    trace = drifting_skew_trace(8, windows, dwell=4)
    ref = OrchestrationRuntime(topo).run_trace(trace)
    with Session(SessionSpec(topology=topo, adaptivity="adaptive")) as sess:
        got = sess.run_trace(trace)
    for a, b in zip(ref.reports, got.reports):
        if a != b:
            raise AssertionError(f"adaptive window {a.window} diverged")
    return f"adaptive: {windows} windows report-identical to hand-wired"


def check_arbitrated() -> dict:
    """One arbitrated two-tenant window through the facade; returns the
    validated fairness record (the ``--smoke`` session_api gate)."""
    from ..core.mcf import solve_direct
    from ..core.topology import Topology
    from ..runtime import drifting_skew_trace
    from . import Session, SessionSpec

    topo = Topology(8, group_size=4)
    bg = solve_direct(
        topo, {(0, 4): 128 * MB, (4, 0): 128 * MB, (1, 5): 128 * MB}
    )
    with Session(SessionSpec(
        topology=topo, adaptivity="arbitrated", tenant="smoke",
    )) as sess:
        sess.join_static_tenant("bg", bg)
        trace = drifting_skew_trace(8, 1, dwell=1)
        sess.step(trace[0])
        rec = sess.report()
    fairness = rec.get("fairness")
    validate_fairness_record(fairness)
    if rec.get("schema") != "nimble.session/v1":
        raise AssertionError(f"session schema {rec.get('schema')!r}")
    return fairness


def check_fabric_pressure(windows: int = 8) -> str:
    """A demand-stable arbitrated tenant replans (reason="fabric") after a
    peer's commit moves the shared prices."""
    from ..core.mcf import solve_direct
    from ..core.topology import Topology
    from ..runtime import PolicyConfig, balanced_trace
    from . import Session, SessionSpec

    topo = Topology(8, group_size=4)
    trace = balanced_trace(8, windows)
    with Session(SessionSpec(
        topology=topo, adaptivity="arbitrated", tenant="stable",
        policy=PolicyConfig(fabric_staleness=2),
    )) as sess:
        reasons = []
        for w in range(windows):
            if w == 3:
                # a peer elephants onto the fabric mid-trace
                sess.join_static_tenant(
                    "peer",
                    solve_direct(topo, {(0, 4): 512 * MB, (4, 0): 512 * MB}),
                )
            reasons.append(sess.step(trace[w]).replan_reason)
    if "fabric" not in reasons:
        raise AssertionError(
            f"no fabric-pressure replan in {reasons} — prices-moved hint "
            "did not reach the policy"
        )
    return f"pressure: fabric replan at w{reasons.index('fabric')} of {windows}"


def check_price_decay() -> str:
    """Decayed ledger prices: monotone fade for stamped commits, identity
    for unstamped commits and for ``price_decay=None``."""
    from ..core.mcf import solve_direct
    from ..core.topology import Topology
    from ..fabric import ArbiterConfig, FabricArbiter

    topo = Topology(8, group_size=4)
    bg = solve_direct(
        topo, {(0, 4): 256 * MB, (4, 0): 256 * MB}
    ).resource_bytes

    arb = FabricArbiter(topo, cfg=ArbiterConfig(price_decay=2.0))
    raw = FabricArbiter(topo)  # price_decay=None: the raw-ledger control
    for a in (arb, raw):
        a.register("fresh")
        a.register("stale")
        a.register("host")
    for a in (arb, raw):
        a.commit("stale", bg, window=0)     # stamped, then never refreshed
        a.commit("host", bg)                # unstamped: timeless
    prices = []
    for w in range(0, 8, 2):
        for a in (arb, raw):
            a.commit("fresh", bg, window=w)  # advances the fabric clock
        decayed = arb.state.external_load("fresh", half_life=2.0)
        stale_part = decayed - bg  # host's undecayed share subtracted
        prices.append(stale_part)
        if not np.allclose(
            raw.state.external_load("fresh"), 2.0 * bg
        ):
            raise AssertionError("price_decay=None no longer raw ledger")
        if arb.state.decay_factor("host", 2.0) != 1.0:
            raise AssertionError("unstamped commit decayed")
    for older, newer in zip(prices, prices[1:]):
        if not (newer <= older + 1e-12).all() or not (newer < older).any():
            raise AssertionError(
                "decayed prices not monotone decreasing in staleness"
            )
    half = arb.state.decay_factor("stale", 2.0)
    expect = 0.5 ** (arb.state.clock / 2.0)
    if abs(half - expect) > 1e-12:
        raise AssertionError(f"decay factor {half} != 0.5^(stale/hl) {expect}")
    return (
        f"decay: stamped peer faded to {half:.3f}x over "
        f"{arb.state.clock} windows (hl=2); unstamped + decay=None exact"
    )


def check_serve() -> str:
    """Minimal two-tenant scenario end-to-end through the control plane:
    registry round-trip is bit-exact, both arms run, the adaptive report
    is a valid ``nimble.serve/v1`` record with every roster tenant served
    for the full horizon."""
    from ..serve import (
        ScenarioSpec,
        get_scenario,
        run_scenario,
        validate_serve_record,
    )

    spec = get_scenario("minimal")
    back = ScenarioSpec.from_json_obj(spec.to_json_obj())
    if back != spec:
        raise AssertionError("minimal scenario JSON round-trip diverged")
    adaptive = run_scenario(spec, "adaptive")
    static = run_scenario(spec, "static")
    rec = adaptive.to_json_obj()
    validate_serve_record(rec)
    names = {t.name for t in spec.roster()}
    if set(adaptive.tenants) != names or set(static.tenants) != names:
        raise AssertionError(
            f"control plane served {sorted(adaptive.tenants)}, "
            f"roster {sorted(names)}"
        )
    for name, led in adaptive.tenants.items():
        if led.windows != spec.windows:
            raise AssertionError(
                f"tenant {name!r} served {led.windows}/{spec.windows} windows"
            )
    return (
        f"serve: minimal scenario round-trips; both arms ran "
        f"{spec.windows} windows x {len(names)} tenants, report schema "
        f"{rec['schema']} valid"
    )


def check_obs() -> str:
    """Flight-recorded minimal scenario: valid four-layer Chrome trace
    under one correlation id, provenance for every swap, metrics embedded
    in the serve record (ISSUE 8, DESIGN.md §11)."""
    from ..jsonio import schema_kind, schema_version
    from ..obs import FlightRecorder, validate_trace
    from ..serve import get_scenario, run_scenario

    spec = get_scenario("minimal")
    recorder = FlightRecorder()
    report = run_scenario(spec, "adaptive", recorder=recorder)

    trace = recorder.export_trace()
    info = validate_trace(trace)  # schema, ts order, nesting, one corr id
    missing = {"serve", "runtime", "fabric", "planner"} - set(info["cats"])
    if missing:
        raise AssertionError(f"trace has no spans from layers {sorted(missing)}")
    if info["correlation_id"] != recorder.correlation_id:
        raise AssertionError("trace lost its correlation id")

    # the sessions are already retired — provenance is the audit trail
    swapped = recorder.provenance.swapped()
    if not swapped:
        raise AssertionError("no swap acquired a provenance record")
    for p in swapped:
        if p.swapped_window is None or not p.trigger or p.signature is None:
            raise AssertionError(
                f"swapped plan v{p.version} has an incomplete provenance "
                f"record: {p.to_json_obj()}"
            )

    rec = report.to_json_obj()
    metrics = rec.get("metrics")
    if metrics is None or schema_kind(metrics) != "metrics":
        raise AssertionError("serve record did not embed a metrics snapshot")
    if schema_version(metrics) != 1:
        raise AssertionError(f"metrics schema version {metrics.get('schema')}")
    if not metrics["metrics"]:
        raise AssertionError("metrics snapshot is empty")
    return (
        f"obs: trace {info['events']} events across 4 layers "
        f"(corr={info['correlation_id']}); {len(recorder.provenance)} plans "
        f"issued, {len(swapped)} swaps all provenanced; "
        f"{len(metrics['metrics'])} metrics embedded"
    )


def check_lint() -> str:
    """Static invariant checker over src/repro: zero live findings with
    the shipped baseline, a strict-parsing ``nimble.lint/v1`` report, and
    a fresh ``schemas.lock.json`` (ISSUE 9, DESIGN.md §12)."""
    import os

    from ..analysis import (
        analyze_paths,
        default_baseline_path,
        default_lock_path,
        load_baseline,
        lock_is_fresh,
    )
    from ..analysis.engine import build_contexts
    from ..jsonio import parse_schema_id

    src_repro = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rel_to = os.path.dirname(src_repro)
    report = analyze_paths(
        [src_repro],
        baseline=load_baseline(default_baseline_path()),
        rel_to=rel_to,
    )
    if not report.clean:
        head = "; ".join(str(f) for f in report.findings[:3])
        raise AssertionError(
            f"{len(report.findings)} live finding(s) over src/repro "
            f"(first: {head}) — run `python -m repro.analysis`"
        )
    obj = report.to_json_obj()
    if parse_schema_id(obj["schema"]) != ("lint", 1):
        raise AssertionError(f"lint report schema {obj['schema']!r}")
    contexts = build_contexts([src_repro], rel_to=rel_to)
    if not lock_is_fresh(default_lock_path(), contexts):
        raise AssertionError(
            "schemas.lock.json is stale — "
            "`python -m repro.analysis --write-lock` and commit"
        )
    return (
        f"lint: {report.files} files clean "
        f"({len(report.suppressed)} suppressed, "
        f"{len(report.baselined)} baselined); schema lock fresh"
    )


def smoke_session_check() -> dict:
    """The ``benchmarks/run.py --smoke`` gate: arbitrated two-tenant window
    through the facade + schema validation.  Returns a summary record."""
    fairness = check_arbitrated()
    return {
        "summary": (
            f"arbitrated 2-tenant window OK; fairness schema "
            f"{FAIRNESS_SCHEMA} valid, jain={fairness['jain_index']:.3f}"
        ),
        "jain_index": fairness["jain_index"],
        "tenants": fairness["tenants"],
    }


def main(argv=None) -> int:
    checks = [
        check_static,
        check_adaptive,
        check_arbitrated,
        check_fabric_pressure,
        check_price_decay,
        check_serve,
        check_obs,
        check_lint,
    ]
    failed = 0
    for check in checks:
        try:
            out = check()
            msg = out if isinstance(out, str) else (
                f"arbitrated: fairness schema valid, "
                f"jain={out['jain_index']:.3f}"
            )
            print(f"[selfcheck] OK   {msg}")
        except Exception as e:  # noqa: BLE001 — selfcheck reports, not raises
            failed += 1
            print(f"[selfcheck] FAIL {check.__name__}: {e}")
    print(
        f"[selfcheck] {len(checks) - failed}/{len(checks)} checks passed"
    )
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
