"""NIMBLE core: execution-time multi-path communication balancing.

Public API:
  Topology / LinkCaps        — interconnect model (topology.py)
  CostModel / ResourceModel  — capacity-normalized cost F(L) (cost.py)
  solve_mwu / solve_direct / solve_static_striping — Algorithm 1 + baselines
  simulate / simulate_nccl_rounds — fabric simulator (fabsim.py)
  PathIncidence / incidence_for — cached sparse planner core (incidence.py)
  PlannerConfig / plan_flows / plan_flows_batch — jittable runtime planner
  NimbleAllToAll             — scheduled shard_map dataplane (dataplane.py)
  MoEDispatcher              — expert-parallel dispatch/combine (moe_comm.py)
"""

from .cost import CostModel, ResourceModel
from .dataplane import NimbleAllToAll, baseline_all_to_all, ref_all_to_allv
from .fabsim import SimResult, simulate, simulate_nccl_rounds
from .incidence import PathIncidence, incidence_for, topology_fingerprint
from .mcf import (
    Plan,
    congestion_lower_bound,
    solve_degraded,
    solve_direct,
    solve_mwu,
    solve_static_striping,
)
from .moe_comm import MoECommConfig, MoEDispatcher
from .paths import Path, all_pairs_paths, enumerate_paths
from .planner import (
    PlannerConfig,
    plan_chunks_batch_jit,
    plan_chunks_jit,
    plan_flows,
    plan_flows_batch,
    quantize_chunks,
)
from .schedule import build_planner_tables, build_schedule
from .topology import LinkCaps, Topology

__all__ = [
    "Topology", "LinkCaps", "CostModel", "ResourceModel", "Plan",
    "solve_mwu", "solve_direct", "solve_static_striping", "solve_degraded",
    "congestion_lower_bound", "simulate", "simulate_nccl_rounds", "SimResult",
    "PlannerConfig", "plan_flows", "plan_flows_batch", "quantize_chunks",
    "plan_chunks_jit", "plan_chunks_batch_jit",
    "PathIncidence", "incidence_for", "topology_fingerprint",
    "build_schedule", "build_planner_tables",
    "NimbleAllToAll", "baseline_all_to_all", "ref_all_to_allv",
    "MoECommConfig", "MoEDispatcher",
    "Path", "enumerate_paths", "all_pairs_paths",
]
