"""Offset-parameterized path schedule — the TPU-native dataplane layout.

Under XLA SPMD every device executes the same program, so NIMBLE's candidate
paths are expressed as *offset decompositions* that are symmetric across
devices (DESIGN.md §2).  With devices numbered ``dev = group*G + pos`` along
the NIMBLE axis:

  hop alphabet (each hop is ONE uniform ``lax.ppermute``):
    rot(a)   : (g, p) -> (g, (p+a) % G)          intra-group rotation
    shift(m) : (g, p) -> ((g+m) % NG, p)         rail-matched group shift

  destination *relations*  rel = (m, dq), m in [0,NG), dq in [0,G), != (0,0):
    dest(s=(g,p)) = ((g+m) % NG, (p+dq) % G)

  candidate paths (paper §IV-B, normalized to 3 stages):
    intra (m=0):  k=0 direct        [rot dq,  -,        -      ]
                  k>=1 via a        [rot a,   rot dq-a, -      ]   a != dq
    inter (m>0):  k in [0,G)        [rot r,   shift m,  rot dq-r]
                  with r = (dq + k) % G; k=0 is the destination-rail (PXN)
                  path, the static-baseline default.

Every (relation, path, chunk-slot) gets a static slot in a flat state array;
a communication round is one ppermute of the slot subset whose current hop
matches that permutation.  Which *slots are filled* is decided at runtime by
the planner (flow amounts), which is how "execution-time planning" coexists
with a static SPMD program.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .cost import CostModel
from .incidence import MAX_CHARGE, PathIncidence, incidence_for, topology_fingerprint
from .topology import Topology

# hop kinds
ROT = 0
SHIFT = 1

Hop = Tuple[int, int]  # (kind, amount); None entries are identity


@dataclasses.dataclass(frozen=True)
class Relation:
    rel_id: int
    m: int   # group offset
    dq: int  # position (rail) offset


def enumerate_relations(n_groups: int, G: int) -> List[Relation]:
    rels = []
    rid = 0
    for m in range(n_groups):
        for dq in range(G):
            if m == 0 and dq == 0:
                continue
            rels.append(Relation(rid, m, dq))
            rid += 1
    return rels


def path_hops(rel: Relation, k: int, G: int) -> List[Optional[Hop]]:
    """Normalized 3-stage hop list for candidate ``k`` of ``rel``."""
    m, dq = rel.m, rel.dq
    if m == 0:
        if k == 0:
            return [(ROT, dq), None, None]
        alts = [a for a in range(1, G) if a != dq]
        a = alts[k - 1]
        return [(ROT, a), (ROT, (dq - a) % G), None]
    r = (dq + k) % G
    h0 = (ROT, r) if r else None
    h2 = (ROT, (dq - r) % G) if (dq - r) % G else None
    return [h0, (SHIFT, m), h2]


def n_candidates(rel: Relation, G: int) -> int:
    return (G - 1) if rel.m == 0 else G


def path_nodes(rel: Relation, k: int, src: int, G: int, n_groups: int) -> List[int]:
    """Concrete device sequence for source ``src`` on path (rel, k)."""
    g, p = divmod(src, G)
    nodes = [src]
    for hop in path_hops(rel, k, G):
        if hop is None:
            continue
        kind, amt = hop
        if kind == ROT:
            p = (p + amt) % G
        else:
            g = (g + amt) % n_groups
        nodes.append(g * G + p)
    return nodes


# The dense planner tables are now a view of the shared planner core
# (incidence.py): one sparse path→resource incidence per (Topology,
# CostModel), cached under the topology fingerprint.  ``PlannerTables`` is
# kept as the historical name — it IS the incidence structure.
PlannerTables = PathIncidence


def build_planner_tables(topo: Topology, cm: CostModel | None = None) -> PlannerTables:
    """Cached planner tables for ``topo`` (see ``incidence.incidence_for``)."""
    return incidence_for(topo, cm)


# ---------------------------------------------------------------------------
# slot / round layout for the dataplane
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CommSchedule:
    """Static slot layout + ppermute rounds for ``nimble_all_to_allv``.

    ``C`` chunk slots are provisioned per destination on the direct path
    (k=0) — enough for the whole demand as fallback — and
    ``ceil(C * alt_frac)`` on each alternate, trading wire padding for
    rerouting headroom (tunable; see EXPERIMENTS.md §Perf).
    """

    topo: Topology
    C: int                      # max chunks per destination
    alt_frac: float
    rels: List[Relation]
    K: int
    S: np.ndarray               # [n_rel, K] slot capacity (0 = invalid path)
    slot_rel: np.ndarray        # [n_slots]
    slot_k: np.ndarray          # [n_slots]
    slot_pos: np.ndarray        # [n_slots] position within (rel, k)
    rounds: List[List[Tuple[Hop, np.ndarray]]]  # 3 rounds of (hop, slot ids)

    @property
    def n_slots(self) -> int:
        return len(self.slot_rel)

    def perm_pairs(self, hop: Hop) -> List[Tuple[int, int]]:
        """Device permutation for a hop, as (src, dst) pairs for ppermute."""
        kind, amt = hop
        G, NG = self.topo.group_size, self.topo.n_groups
        pairs = []
        for dev in range(self.topo.n_devices):
            g, p = divmod(dev, G)
            if kind == ROT:
                dst = g * G + (p + amt) % G
            else:
                dst = ((g + amt) % NG) * G + p
            pairs.append((dev, dst))
        return pairs


_SCHED_CACHE: "collections.OrderedDict[tuple, CommSchedule]" = (
    collections.OrderedDict()
)
#: LRU bound — link events mint fresh fingerprints (see incidence._CACHE_CAP)
_SCHED_CACHE_CAP = 64


def build_schedule(
    topo: Topology, C: int, alt_frac: float = 0.5
) -> CommSchedule:
    """Build (or fetch the cached) slot layout for ``(topo, C, alt_frac)``.

    Cached under the topology fingerprint: every MoE layer / tenant with the
    same geometry shares one schedule, so repeated dataplane construction
    stops re-enumerating slots and rounds.  Treat the result as immutable.
    """
    key = (topology_fingerprint(topo), int(C), float(alt_frac))
    hit = _SCHED_CACHE.get(key)
    if hit is not None:
        _SCHED_CACHE.move_to_end(key)
        return hit
    sched = _build_schedule(topo, C, alt_frac)
    _SCHED_CACHE[key] = sched
    while len(_SCHED_CACHE) > _SCHED_CACHE_CAP:
        _SCHED_CACHE.popitem(last=False)
    return sched


def _build_schedule(
    topo: Topology, C: int, alt_frac: float = 0.5
) -> CommSchedule:
    G, NG = topo.group_size, topo.n_groups
    rels = enumerate_relations(NG, G)
    K = max(n_candidates(r, G) for r in rels)

    S = np.zeros((len(rels), K), dtype=np.int64)
    alt_slots = int(np.ceil(C * alt_frac))
    for rel in rels:
        for k in range(n_candidates(rel, G)):
            S[rel.rel_id, k] = C if k == 0 else alt_slots

    slot_rel, slot_k, slot_pos = [], [], []
    for rel in rels:
        for k in range(K):
            for j in range(int(S[rel.rel_id, k])):
                slot_rel.append(rel.rel_id)
                slot_k.append(k)
                slot_pos.append(j)
    slot_rel = np.array(slot_rel, dtype=np.int64)
    slot_k = np.array(slot_k, dtype=np.int64)
    slot_pos = np.array(slot_pos, dtype=np.int64)

    # group slots by their hop at each of the 3 normalized stages
    rounds: List[List[Tuple[Hop, np.ndarray]]] = []
    for t in range(3):
        by_hop: Dict[Hop, List[int]] = {}
        for sid in range(len(slot_rel)):
            rel = rels[slot_rel[sid]]
            hop = path_hops(rel, int(slot_k[sid]), G)[t]
            if hop is not None:
                by_hop.setdefault(hop, []).append(sid)
        rounds.append(
            [(hop, np.array(ids, dtype=np.int64)) for hop, ids in sorted(by_hop.items())]
        )
    return CommSchedule(
        topo=topo,
        C=C,
        alt_frac=alt_frac,
        rels=rels,
        K=K,
        S=S,
        slot_rel=slot_rel,
        slot_k=slot_k,
        slot_pos=slot_pos,
        rounds=rounds,
    )
