"""Link-time fabric simulator.

Executes a routing :class:`~repro.core.mcf.Plan` on the calibrated resource
graph and reports completion time / effective bandwidth, modeling the
paper's chunked bottleneck-rate pipeline (§IV-C):

  * each resource (link / relay-throughput / injection) drains its assigned
    effective bytes at capacity;
  * a multi-hop path additionally pays a pipeline **fill** latency of
    ``(n_hops - 1) * chunk / bottleneck_cap`` before reaching steady state
    (the P2P staging buffers must fill once);
  * the exchange completes when the slowest resource drains — the max-load
    objective Z of the IP is exactly the simulated completion time, which is
    why Algorithm 1 minimizes the right thing.

This is the evaluation vehicle for the paper's bandwidth claims on a CPU-only
container: Fig. 6/7/8 ratios are reproduced analytically from plans, while
bit-exact data movement is separately validated by the real shard_map
dataplane on forced host devices.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping

import numpy as np

from ..jsonio import json_dumps, tag
from .incidence import incidence_for
from .mcf import PairKey, Plan, RoutedFlow


@dataclasses.dataclass
class SimResult:
    completion_time: float          # seconds
    total_payload: float            # bytes
    effective_bandwidth: float      # payload / time
    per_resource_time: np.ndarray
    per_resource_util: np.ndarray   # fraction of completion time busy
    bottleneck_resource: int        # < n_links => a link; then relay; then inject

    def bandwidth_gbs(self) -> float:
        return self.effective_bandwidth / 1e9

    def bottleneck_kind(self, plan: Plan) -> str:
        rid = self.bottleneck_resource
        E, n = plan.rm.n_links, plan.topo.n_devices
        if rid < E:
            l = plan.topo.links[rid]
            return f"link[{l.src}->{l.dst}]"
        if rid < E + n:
            return f"relay[{rid - E}]"
        return f"inject[{rid - E - n}]"

    # -- serialization (shared schema, repro.jsonio) --------------------------
    def to_json_obj(self) -> dict:
        """Tagged dict (``nimble.simresult/v1``) for cross-file consumers."""
        return tag(
            "simresult",
            {
                "completion_time_s": float(self.completion_time),
                "total_payload_bytes": float(self.total_payload),
                "effective_bandwidth_gbs": self.bandwidth_gbs(),
                "bottleneck_resource": int(self.bottleneck_resource),
                "per_resource_time_s": [
                    float(x) for x in self.per_resource_time
                ],
                "per_resource_util": [
                    float(x) for x in self.per_resource_util
                ],
            },
        )

    def to_json(self, *, indent: bool = False) -> bytes:
        return json_dumps(self.to_json_obj(), indent=indent)


def _pipeline_fill_reference(plan: Plan, chunk_bytes: float) -> np.ndarray:
    """Reference per-flow fill loop (kept for the equivalence test)."""
    rm = plan.rm
    fill = np.zeros(rm.n_resources)
    for key, flows in plan.consolidated().items():
        for f in flows:
            if f.path.n_relays > 0 and f.bytes > 0:
                caps = rm.topo.capacity[list(f.path.links)]
                extra = (f.path.n_hops - 1) * min(chunk_bytes, f.bytes) / caps.min()
                for l in f.path.links:
                    fill[l] = max(fill[l], extra)
    return fill


#: below this many relayed flows the scalar loop beats a (possibly cold)
#: O(n²K) incidence-table fetch — e.g. one-shot simulations of host plans
#: on fingerprints outside the table cache
_VECTORIZE_MIN_FLOWS = 8


def _pipeline_fill(plan: Plan, chunk_bytes: float) -> np.ndarray:
    """Vectorized pipeline-fill: per-path bottleneck caps come precomputed
    from the shared incidence tables (``path_link_min_cap`` / ``path_links``)
    instead of being re-derived per flow; values are bit-identical to
    :func:`_pipeline_fill_reference`.  Plans with few relayed flows take
    the scalar loop — not worth a table build."""
    rm = plan.rm
    n_res = rm.n_resources
    relayed: List[RoutedFlow] = [
        f
        for flows in plan.consolidated().values()
        for f in flows
        if f.path.n_relays > 0 and f.bytes > 0
    ]
    # extra slot collects the -1 padding scatter so real rows stay exact
    buf = np.zeros(n_res + 1)
    slow: List[RoutedFlow] = []
    if len(relayed) < _VECTORIZE_MIN_FLOWS:
        slow = relayed
    else:
        inc = incidence_for(plan.topo, rm.cm)
        pid_of = inc.path_index
        pids: List[int] = []
        byts: List[float] = []
        for f in relayed:
            pid = pid_of.get(f.path)
            if pid is None:   # path unknown to the tables (none expected)
                slow.append(f)
            else:
                pids.append(pid)
                byts.append(f.bytes)
        if pids:
            pid_a = np.asarray(pids, dtype=np.int64)
            b = np.asarray(byts, dtype=np.float64)
            extra = (
                (inc.path_n_hops[pid_a] - 1)
                * np.minimum(chunk_bytes, b)
                / inc.path_link_min_cap[pid_a]
            )
            links = inc.path_links[pid_a]             # [F, MAX_HOPS]
            np.maximum.at(
                buf,
                np.where(links >= 0, links, n_res).ravel(),
                np.repeat(extra, links.shape[1]),
            )
    for f in slow:
        caps = rm.topo.capacity[list(f.path.links)]
        extra = (f.path.n_hops - 1) * min(chunk_bytes, f.bytes) / caps.min()
        for l in f.path.links:
            buf[l] = max(buf[l], extra)
    return buf[:n_res]


def simulate(plan: Plan, chunk_bytes: float = 1 << 20) -> SimResult:
    rm = plan.rm
    drain = plan.resource_bytes / rm.capacity
    # pipeline fill: charged once per multi-hop path on its bottleneck resource
    fill = _pipeline_fill(plan, chunk_bytes)
    per_res = drain + fill
    t = float(per_res.max()) if len(per_res) else 0.0
    total = float(sum(sum(x.bytes for x in v) for v in plan.flows.values()))
    bw = total / t if t > 0 else 0.0
    util = per_res / t if t > 0 else np.zeros_like(per_res)
    return SimResult(
        completion_time=t,
        total_payload=total,
        effective_bandwidth=bw,
        per_resource_time=per_res,
        per_resource_util=util,
        bottleneck_resource=int(np.argmax(per_res)) if len(per_res) else -1,
    )


def pair_bandwidth(plan: Plan, pair: PairKey, chunk_bytes: float = 1 << 20) -> float:
    """Effective bandwidth seen by a single (s, d) pair under the plan."""
    flows = plan.consolidated().get(pair, [])
    if not flows:
        return 0.0
    rm = plan.rm
    t = 0.0
    for f in flows:
        rids = [rid for rid, _ in rm.charges(f.path, 1.0)]
        drain = max(plan.resource_bytes[r] / rm.capacity[r] for r in rids)
        caps = rm.topo.capacity[list(f.path.links)]
        fillt = (f.path.n_hops - 1) * min(chunk_bytes, f.bytes) / caps.min()
        t = max(t, drain + fillt)
    total = sum(f.bytes for f in flows)
    return total / t if t > 0 else 0.0


def compare(
    plans: Mapping[str, Plan], chunk_bytes: float = 1 << 20
) -> Dict[str, SimResult]:
    return {name: simulate(p, chunk_bytes) for name, p in plans.items()}


def simulate_nccl_rounds(
    topo, demands: Mapping[PairKey, float], cost_model=None
) -> float:
    """Round-serialized NCCL-like All-to-Allv completion time (seconds).

    NCCL executes grouped p2p as n-1 rounds (rank r talks to r+k in round
    k) over a fixed channel set; a round's duration is its slowest transfer
    on the statically chosen (PXN) path, and rounds serialize on the shared
    channels.  This kernel-level behaviour — not just static routing — is
    what the paper's Fig. 7 baseline pays under skew, and it is why measured
    NCCL losses (up to 5.2x) exceed the pure link-funneling bound (~4x).
    """
    from .mcf import solve_direct

    n = topo.n_devices
    total = 0.0
    for k in range(1, n):
        round_d = {}
        for s in range(n):
            dpair = (s, (s + k) % n)
            if dpair in demands and demands[dpair] > 0:
                round_d[dpair] = demands[dpair]
        if not round_d:
            continue
        plan = solve_direct(topo, round_d, cost_model)
        total += simulate(plan).completion_time
    return total
