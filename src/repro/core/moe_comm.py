"""Expert-parallel dispatch / combine over the NIMBLE dataplane (paper §V-D).

The paper's headline workload: MoE token routing is a skewed All-to-Allv
(dispatch) followed by expert FFN compute and the transposed All-to-Allv
(combine).  This module implements the full endpoint-driven pipeline:

  1. tokens are assigned to experts (top-k gating, done by the model);
  2. assignments are packed into per-destination-device chunk buffers
     ("Kernel Scatter", Pallas ``token_scatter`` on TPU, jnp fallback here);
  3. the live demand matrix is planned + executed by
     :class:`~repro.core.dataplane.NimbleAllToAll` — tokens ride a bf16/f32
     payload, the per-token expert id rides a tiny f32 sideband on the SAME
     plan (so routing stays consistent);
  4. expert FFN runs on received tokens (``grouped_ffn`` kernel / ref);
  5. outputs return in-place through the transposed plan and are
     scatter-combined into the original token order with gate weights.

Ordering/determinism: chunk -> slot maps are derived from the replicated plan
on both sides (paper's per-destination reassembly queues).  Capacity: the
static per-destination buffer implements a capacity factor; overflow tokens
are dropped with a counter (the paper's no-drop deployments correspond to a
large enough factor, see configs).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .cost import CostModel
from .dataplane import NimbleAllToAll
from .planner import PlannerConfig
from .topology import Topology


@dataclasses.dataclass
class MoECommConfig:
    n_devices: int                 # EP group size (model-axis)
    n_experts: int
    d_model: int
    chunk_tokens: int = 16         # ε in tokens — planner chunk granularity
    capacity_factor: float = 2.0   # per-destination buffer vs uniform share
    group_size: int = 4            # chips per "node" on the NIMBLE axis
    alt_frac: float = 0.5
    mode: str = "nimble"           # nimble | direct | stripe
    payload_dtype: jnp.dtype = jnp.float32

    @property
    def experts_per_device(self) -> int:
        assert self.n_experts % self.n_devices == 0
        return self.n_experts // self.n_devices


class MoEDispatcher:
    """Stateless (per-shape) dispatch/combine helper.  Use inside shard_map.

    ``runtime`` optionally routes dispatch planning through an
    :class:`~repro.runtime.controller.OrchestrationRuntime`: host-driven
    batched plans feed its telemetry/estimator (via the dataplane's
    telemetry sink and ``runtime.observe_dispatch``), so drifting expert
    popularity shows up in the runtime's replan loop.  The jitted
    per-invocation dispatch path is unchanged — the runtime observes from
    the host side only.
    """

    def __init__(self, axis_name: str, cfg: MoECommConfig,
                 planner_cfg: Optional[PlannerConfig] = None,
                 runtime=None,
                 cost_model: Optional[CostModel] = None,
                 topo: Optional[Topology] = None):
        self.axis = axis_name
        self.cfg = cfg
        self._comms = {}
        self._planner_cfg = planner_cfg
        self.runtime = runtime
        # non-default fabric description for the underlying dataplane
        # endpoints (Session-supplied; None keeps the historical behavior
        # of deriving a default Topology from the comm geometry)
        self._cost_model = cost_model
        self._topo = topo

    @classmethod
    def from_session(cls, session, axis_name: str, cfg: MoECommConfig,
                     planner_cfg: Optional[PlannerConfig] = None
                     ) -> "MoEDispatcher":
        """Session-wired dispatcher (DESIGN.md §5).

        The session (duck-typed — this module never imports ``repro.api``)
        supplies the fabric topology, cost model, planner defaults, and —
        when it runs one — the orchestration runtime, so expert-parallel
        dispatch demand feeds the runtime's telemetry/estimator without
        any per-application ``attach_telemetry`` wiring.  The comm
        geometry in ``cfg`` must match the session's fabric.
        """
        topo = session.topo
        if (cfg.n_devices, cfg.group_size) != (topo.n_devices,
                                               topo.group_size):
            raise ValueError(
                f"MoE comm geometry ({cfg.n_devices}, {cfg.group_size}) != "
                f"session fabric ({topo.n_devices}, {topo.group_size})"
            )
        return cls(
            axis_name,
            cfg,
            planner_cfg=(
                planner_cfg if planner_cfg is not None else session.spec.planner
            ),
            runtime=getattr(session, "runtime", None),
            cost_model=session.cost_model,
            topo=topo,
        )

    # -- static geometry -------------------------------------------------------
    def capacity_tokens(self, n_assign: int) -> int:
        cfg = self.cfg
        per_dest = int(np.ceil(n_assign / cfg.n_devices * cfg.capacity_factor))
        ct = cfg.chunk_tokens
        return int(np.ceil(per_dest / ct)) * ct

    def _comm(self, n_chunks: int, elems: int) -> NimbleAllToAll:
        key = (n_chunks, elems)
        if key not in self._comms:
            chunk_bytes = float(
                self.cfg.chunk_tokens * self.cfg.d_model
                * jnp.dtype(self.cfg.payload_dtype).itemsize
            )
            comm = NimbleAllToAll(
                self.axis,
                self.cfg.n_devices,
                self.cfg.group_size,
                max_chunks=n_chunks,
                chunk_bytes=chunk_bytes,
                alt_frac=self.cfg.alt_frac,
                planner_cfg=self._planner_cfg,
                cost_model=self._cost_model,
                mode=self.cfg.mode,
                topo=self._topo,
            )
            if self.runtime is not None:
                comm.attach_telemetry(self.runtime.telemetry)
            self._comms[key] = comm
        return self._comms[key]

    def plan_batched(
        self, demand_chunks: jnp.ndarray, n_assign: int
    ) -> jnp.ndarray:
        """Plan B dispatch rounds in one jit call: [B, n, n] -> [B, n, n, K].

        Multi-tenant / pipelined entry point: the demand matrices of
        several MoE layers (or microbatches, or co-located tenants) are
        planned together by the vmapped MWU over the shared cached
        incidence tables, instead of B sequential planner dispatches.
        ``n_assign`` is the per-round assignment count (T*k), as in
        :meth:`dispatch`, and fixes the chunk capacity C.
        """
        cfg = self.cfg
        cap_tok = self.capacity_tokens(n_assign)
        C = cap_tok // cfg.chunk_tokens
        comm = self._comm(C, cfg.chunk_tokens * cfg.d_model)
        if self.runtime is not None and not isinstance(
            demand_chunks, jax.core.Tracer
        ):
            # feed the dispatch demand into the runtime's estimator so MoE
            # expert-popularity drift participates in its replan decisions;
            # one update per batch entry, matching the per-window records
            # the telemetry sink takes in plan_batch
            D = np.asarray(demand_chunks, dtype=np.float64) * float(
                comm.cfg.chunk_bytes
            )
            for b in range(D.shape[0]):
                self.runtime.estimator.update(D[b])
        return comm.plan_batch(demand_chunks)

    # -- dispatch ----------------------------------------------------------------
    def dispatch(
        self,
        tokens: jnp.ndarray,     # [T, d] local tokens
        expert_idx: jnp.ndarray,  # [T, k] int32 global expert ids
        token_valid: Optional[jnp.ndarray] = None,  # [T] bool ownership mask
    ):
        """Route token copies to expert-owning devices.

        Returns (recv_tokens [n, C, ct, d], recv_expert [n, C, ct] local ids
        with -1 padding, state) where ``state`` carries everything combine
        needs (plan, slot maps, dropped-token mask).
        """
        cfg = self.cfg
        n, ct, d = cfg.n_devices, cfg.chunk_tokens, cfg.d_model
        T, k = expert_idx.shape
        A = T * k
        cap_tok = self.capacity_tokens(A)
        C = cap_tok // ct
        comm = self._comm(C, ct * d)

        dest = (expert_idx // cfg.experts_per_device).reshape(A)  # [A]
        if token_valid is not None:
            # unowned tokens (replicated-token mode, DESIGN.md §8): route to
            # a sentinel so they never enter any send buffer.
            avalid = jnp.repeat(token_valid, k)
            dest = jnp.where(avalid, dest, n)                      # sentinel
        # stable pack: position of each assignment within its destination
        order = jnp.argsort(dest, stable=True)                    # [A]
        dest_sorted = dest[order]
        counts = jnp.bincount(dest, length=n)                     # tokens/dest
        offsets = jnp.cumsum(counts) - counts
        slot_sorted = jnp.arange(A) - offsets[jnp.minimum(dest_sorted, n - 1)]
        kept_sorted = (slot_sorted < cap_tok) & (dest_sorted < n)  # cap + owned
        # scatter assignment a=order[r] -> (dest, slot)
        slot = jnp.zeros((A,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
        kept = jnp.zeros((A,), bool).at[order].set(kept_sorted)

        tok_flat = jnp.repeat(tokens, k, axis=0)                  # [A, d]
        x = jnp.zeros((n, C * ct, d), cfg.payload_dtype)
        x = x.at[dest, jnp.minimum(slot, cap_tok - 1)].add(
            jnp.where(kept[:, None], tok_flat.astype(cfg.payload_dtype), 0)
        )
        e_side = jnp.full((n, C * ct, 1), -1.0, jnp.float32)
        e_side = e_side.at[dest, jnp.minimum(slot, cap_tok - 1), 0].set(
            jnp.where(kept, expert_idx.reshape(A).astype(jnp.float32), -1.0)
        )

        send_chunks = jnp.ceil(
            jnp.minimum(counts, cap_tok) / ct
        ).astype(jnp.int32)                                       # [n]
        plan = comm.plan_from_counts(send_chunks)                 # [n, n, K]

        y = comm.execute(x.reshape(n, C, ct * d), plan)
        e_comm = self._comm(C, ct)  # sideband shares schedule shape
        ey = e_comm.execute(e_side.reshape(n, C, ct), plan)

        me = jax.lax.axis_index(self.axis)
        recv_tokens = y.reshape(n, C, ct, d)
        recv_tokens = recv_tokens.at[me].set(x.reshape(n, C, ct, d)[me])
        e_recv = ey.reshape(n, C, ct)
        e_recv = e_recv.at[me].set(e_side.reshape(n, C, ct)[me])
        # decode sideband: pad slots stay -1 (zeros arriving decode to 0 but
        # only within planned chunk counts; out-of-plan slots were zero-filled
        # -> mark them invalid via the per-source chunk counts)
        recv_chunk_counts = plan[:, me].sum(-1)                   # [n]
        recv_chunk_counts = recv_chunk_counts.at[me].set(send_chunks[me])
        cidx = jnp.arange(C)[None, :]
        chunk_valid = cidx < recv_chunk_counts[:, None]           # [n, C]
        expert_global = jnp.where(
            chunk_valid[..., None], jnp.round(e_recv).astype(jnp.int32), -1
        )
        expert_local = jnp.where(
            expert_global >= 0,
            expert_global - me * cfg.experts_per_device,
            -1,
        )
        # guard: mis-routed ids (shouldn't happen) masked out
        expert_local = jnp.where(
            (expert_local >= 0) & (expert_local < cfg.experts_per_device),
            expert_local,
            -1,
        )
        state = dict(
            plan=plan,
            dest=dest,
            slot=slot,
            kept=kept,
            send_chunks=send_chunks,
            C=C,
            dropped=(~kept).sum(),
        )
        return recv_tokens, expert_local, state

    # -- combine -----------------------------------------------------------------
    def combine(
        self,
        expert_out: jnp.ndarray,   # [n, C, ct, d] outputs in recv layout
        state,
        gate_w: jnp.ndarray,       # [T, k] float gate weights
    ) -> jnp.ndarray:
        """Return expert outputs to token owners and gate-combine: [T, d]."""
        cfg = self.cfg
        n, ct, d = cfg.n_devices, cfg.chunk_tokens, cfg.d_model
        T, k = gate_w.shape
        C = state["C"]
        comm = self._comm(C, ct * d)

        # transpose plan: what I received per source is what I send back
        plan_T = jnp.swapaxes(state["plan"], 0, 1)
        y = comm.execute(
            expert_out.reshape(n, C, ct * d).astype(cfg.payload_dtype), plan_T
        )
        me = jax.lax.axis_index(self.axis)
        y = y.reshape(n, C, ct, d)
        y = y.at[me].set(expert_out[me].astype(cfg.payload_dtype))
        # gather each assignment's processed token from (dest, slot)
        flat = y.reshape(n, C * ct, d)
        a_out = flat[state["dest"], jnp.minimum(state["slot"], C * ct - 1)]
        a_out = jnp.where(state["kept"][:, None], a_out, 0)
        w = gate_w.reshape(T * k, 1).astype(a_out.dtype)
        out = (a_out * w).reshape(T, k, d).sum(axis=1)
        return out
