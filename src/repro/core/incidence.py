"""Sparse path→resource incidence — the shared planner core (DESIGN.md §2).

Both Algorithm-1 implementations (the faithful host solver ``mcf.solve_mwu``
and the jitted vectorized MWU ``planner.plan_flows``) price candidate paths
against the same resource vector ``[links (E), relay (n), inject (n)]``.
This module precomputes that path→resource mapping ONCE per
``(Topology, CostModel)`` as a :class:`PathIncidence`:

  * **CSR form** (``indptr`` / ``indices`` / ``multipliers``): exact sparse
    incidence over the E + 2n real resources, for host-side numpy sweeps and
    analysis tooling;
  * **dense padded form** (``path_rids`` / ``path_mult``, shape
    ``[P, MAX_CHARGE]``): fixed-width rows padded with a trailing dummy
    resource of infinite capacity, for gather-based jit kernels;
  * per-path metadata: relay flag (size-threshold gating), fill/flush
    penalty seconds, bottleneck capacity, and the concrete
    :class:`~repro.core.paths.Path` object so host plans keep reporting
    real routes;
  * the pair→candidate table ``pair_path_ids [n*n, K]`` in the
    offset-relation order of ``schedule.py`` (k=0 = least-hop / PXN).

Instances are cached under a **topology fingerprint key** (geometry + link
capacities + every cost-model knob), so repeated planner/dataplane
construction — one per MoE layer, per tenant, per benchmark section —
reuses one set of tables.  Cached arrays are frozen (``writeable=False``);
treat them as immutable.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import numpy as np

from .cost import CostModel
from .paths import DIRECT, Path, RAIL_MATCHED, TWO_HOP
from .topology import INTRA, Topology

#: fixed dense row width: 3 links + src inject + 2 relays + 2 relay injects
MAX_CHARGE = 8

#: max links per candidate path (3-stage normalized schedule)
MAX_HOPS = 3


@dataclasses.dataclass(frozen=True)
class PairCandidates:
    """Per-pair candidate incidence rows, gathered once per table build.

    Shapes are ``[n*n, K, MAX_CHARGE]`` (``rids`` / ``mult`` / ``mask``) and
    ``[n*n, K]`` (the rest); K-padding entries have ``valid=False``.  Both
    the host sweep solver and the jitted planner index these directly, so
    no gather/scatter bookkeeping is rebuilt inside their iteration loops.
    """

    valid: np.ndarray     # [n*n, K] bool
    rids: np.ndarray      # [n*n, K, MAX_CHARGE] int32 (dummy-padded)
    mult: np.ndarray      # [n*n, K, MAX_CHARGE] float32 (0-padded)
    mask: np.ndarray      # [n*n, K, MAX_CHARGE] bool (mult > 0)
    penalty: np.ndarray   # [n*n, K] float32
    relay: np.ndarray     # [n*n, K] bool
    min_cap: np.ndarray   # [n*n, K] float64 — path bottleneck capacity


@dataclasses.dataclass(frozen=True)
class PathIncidence:
    """Precomputed path→resource incidence for one (Topology, CostModel).

    Resource ids follow ``cost.ResourceModel``: ``[links (E), relay (n),
    inject (n)]``; the dense form appends one dummy resource (id
    ``n_resources - 1``, capacity 1e30) used only as row padding.
    """

    n: int                      # devices
    K: int                      # max candidate paths per pair
    n_links: int                # E
    n_resources: int            # E + 2n + 1 (incl. trailing dummy)
    caps: np.ndarray            # [n_resources] float64
    # dense padded form (jit gathers):
    path_rids: np.ndarray       # [P, MAX_CHARGE] int32, dummy-padded
    path_mult: np.ndarray       # [P, MAX_CHARGE] float32, 0-padded
    path_penalty: np.ndarray    # [P] float32 — fill/flush seconds
    path_relay: np.ndarray      # [P] bool — has relay GPUs (threshold gate)
    path_min_cap: np.ndarray    # [P] float64 — bottleneck capacity
    path_links: np.ndarray      # [P, MAX_HOPS] int32 link ids, -1-padded
    path_n_hops: np.ndarray     # [P] int32 — len(links)
    path_link_min_cap: np.ndarray  # [P] float64 — min over *link* caps only
    pair_path_ids: np.ndarray   # [n*n, K] int32, -1 invalid/self
    # CSR form over real resources (host sweeps):
    indptr: np.ndarray          # [P + 1] int32
    indices: np.ndarray         # [nnz] int32 (all < n_resources - 1)
    multipliers: np.ndarray     # [nnz] float64
    # concrete routes, one per path id (None on K-padding rows):
    paths: Tuple[Optional[Path], ...]

    @property
    def n_paths(self) -> int:
        return len(self.path_penalty)

    @property
    def dummy_rid(self) -> int:
        return self.n_resources - 1

    @functools.cached_property
    def pair_candidates(self) -> PairCandidates:
        """Candidate rows regrouped by ordered pair (cached on the tables)."""
        c = np.where(self.pair_path_ids >= 0, self.pair_path_ids, 0)
        mult = self.path_mult[c]
        return PairCandidates(
            valid=_freeze(self.pair_path_ids >= 0),
            rids=_freeze(self.path_rids[c]),
            mult=_freeze(mult),
            mask=_freeze(mult > 0),
            penalty=_freeze(self.path_penalty[c]),
            relay=_freeze(self.path_relay[c]),
            min_cap=_freeze(self.path_min_cap[c]),
        )

    @functools.cached_property
    def path_index(self) -> Dict[Path, int]:
        """Concrete :class:`Path` -> path id, for host-plan lookups.

        Host plans (``mcf``) and the incidence enumerate identical routes,
        so flows can be mapped back to their precomputed per-path metadata
        (``fabsim``'s vectorized pipeline-fill) without re-walking links.
        """
        return {p: i for i, p in enumerate(self.paths) if p is not None}

    def charges_of(self, pid: int) -> List[Tuple[int, float]]:
        """CSR row of path ``pid`` as (resource_id, multiplier) pairs."""
        lo, hi = int(self.indptr[pid]), int(self.indptr[pid + 1])
        return [
            (int(r), float(m))
            for r, m in zip(self.indices[lo:hi], self.multipliers[lo:hi])
        ]


def topology_fingerprint(topo: Topology) -> tuple:
    """Hashable key that fully determines the link graph of ``topo``."""
    return topo.fingerprint


def cost_model_key(cm: CostModel) -> tuple:
    """Hashable key over every CostModel knob that shapes the tables."""
    return dataclasses.astuple(cm)


def _freeze(a: np.ndarray) -> np.ndarray:
    a.setflags(write=False)
    return a


def _build(topo: Topology, cm: CostModel) -> PathIncidence:
    # Import here: schedule.py re-exports our tables, so a module-level
    # import would be circular.
    from .schedule import enumerate_relations, n_candidates, path_nodes

    n, G, NG = topo.n_devices, topo.group_size, topo.n_groups
    rels = enumerate_relations(NG, G)
    K = max(n_candidates(r, G) for r in rels)
    E = topo.n_links
    n_res = E + 2 * n + 1
    dummy = n_res - 1
    caps = np.empty(n_res)
    caps[:E] = topo.capacity
    caps[E : E + n] = cm.relay_cap
    caps[E + n : E + 2 * n] = cm.inject_cap
    caps[dummy] = 1e30

    P = n * len(rels) * K
    rids = np.full((P, MAX_CHARGE), dummy, dtype=np.int32)
    mult = np.zeros((P, MAX_CHARGE), dtype=np.float32)
    pen = np.zeros(P, dtype=np.float32)
    relay = np.zeros(P, dtype=bool)
    min_caps = np.full(P, np.inf)
    plinks = np.full((P, MAX_HOPS), -1, dtype=np.int32)
    pn_hops = np.zeros(P, dtype=np.int32)
    plink_min = np.full(P, np.inf)
    pair_paths = np.full((n * n, K), -1, dtype=np.int32)
    indptr = np.zeros(P + 1, dtype=np.int32)
    idx_flat: List[int] = []
    mult_flat: List[float] = []
    path_objs: List[Optional[Path]] = []

    pid = 0
    for s in range(n):
        for rel in rels:
            for k in range(K):
                if k < n_candidates(rel, G):
                    nodes = path_nodes(rel, k, s, G, NG)
                    d = nodes[-1]
                    links = [topo.link_id(a, b) for a, b in zip(nodes, nodes[1:])]
                    relayed = len(nodes) > 2
                    c = 0
                    min_cap = np.inf
                    for l in links:
                        m = (
                            1.0 / cm.rail_relay_eff
                            if relayed and topo.kind[l] != INTRA
                            else 1.0
                        )
                        rids[pid, c], mult[pid, c] = l, m
                        min_cap = min(min_cap, topo.capacity[l])
                        c += 1
                    rids[pid, c], mult[pid, c] = E + n + s, 1.0  # src inject
                    c += 1
                    for mid in nodes[1:-1]:
                        rids[pid, c], mult[pid, c] = E + mid, 1.0       # relay
                        rids[pid, c + 1], mult[pid, c + 1] = E + n + mid, 1.0
                        c += 2
                        min_cap = min(min_cap, cm.relay_cap)
                    if relayed:
                        pen[pid] = cm.hop_setup_bytes * (len(nodes) - 2) / min_cap
                        relay[pid] = True
                    min_caps[pid] = min_cap
                    plinks[pid, : len(links)] = links
                    pn_hops[pid] = len(links)
                    plink_min[pid] = topo.capacity[links].min()
                    pair_paths[s * n + d, k] = pid
                    idx_flat.extend(int(r) for r in rids[pid, :c])
                    mult_flat.extend(float(m) for m in mult[pid, :c])
                    if rel.m == 0:
                        family = DIRECT if k == 0 else TWO_HOP
                    else:
                        family = RAIL_MATCHED
                    path_objs.append(Path(tuple(links), tuple(nodes), family))
                else:
                    path_objs.append(None)
                indptr[pid + 1] = len(idx_flat)
                pid += 1

    return PathIncidence(
        n=n,
        K=K,
        n_links=E,
        n_resources=n_res,
        caps=_freeze(caps),
        path_rids=_freeze(rids),
        path_mult=_freeze(mult),
        path_penalty=_freeze(pen),
        path_relay=_freeze(relay),
        path_min_cap=_freeze(min_caps),
        path_links=_freeze(plinks),
        path_n_hops=_freeze(pn_hops),
        path_link_min_cap=_freeze(plink_min),
        pair_path_ids=_freeze(pair_paths),
        indptr=_freeze(indptr),
        indices=_freeze(np.asarray(idx_flat, dtype=np.int32)),
        multipliers=_freeze(np.asarray(mult_flat, dtype=np.float64)),
        paths=tuple(path_objs),
    )


# -- topology-keyed cache ------------------------------------------------------

_CACHE: "collections.OrderedDict[tuple, PathIncidence]" = (
    collections.OrderedDict()
)
#: LRU bound: topology events (link down/degrade) mint a fresh fingerprint
#: per distinct scale map, so the cache must evict or a long fault-injection
#: run would leak one O(n² K) table set per fault state
_CACHE_CAP = 64
_HITS = 0
_MISSES = 0


def incidence_for(topo: Topology, cm: CostModel | None = None) -> PathIncidence:
    """Cached :class:`PathIncidence` for ``(topo, cm)``.

    Two topologies with the same :func:`topology_fingerprint` share one
    instance, so per-layer / per-tenant planner construction stops paying
    the O(n² K) table build.
    """
    global _HITS, _MISSES
    cm = cm or CostModel()
    key = (topology_fingerprint(topo), cost_model_key(cm))
    hit = _CACHE.get(key)
    if hit is not None:
        _HITS += 1
        _CACHE.move_to_end(key)
        return hit
    _MISSES += 1
    inc = _build(topo, cm)
    _CACHE[key] = inc
    while len(_CACHE) > _CACHE_CAP:
        _CACHE.popitem(last=False)
    return inc


def cache_info() -> Dict[str, int]:
    return {"size": len(_CACHE), "hits": _HITS, "misses": _MISSES}


def cache_clear() -> None:
    global _HITS, _MISSES
    _CACHE.clear()
    _HITS = 0
    _MISSES = 0
