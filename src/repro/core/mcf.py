"""Algorithm 1 — Link Load Balancing with Iterative Approximation.

Host-side (numpy) implementation of the paper's multiplicative-weights /
Garg–Könemann-inspired min-congestion MCF approximation:

  * iterate over communication pairs with remaining demand;
  * for each, evaluate the candidate paths (direct / intra 2-hop /
    rail-matched) under the **bottleneck** path-cost metric;
  * route a λ fraction of the remaining demand (quantized to the chunk
    granularity ε) on the cheapest path;
  * bump the cost of every resource used (``c = F(L)``) and repeat until
    all demand is routed.

Two refresh disciplines are provided (DESIGN.md §2.3):

  * ``refresh="sweep"`` (default) — one **vectorized** pass over all live
    pairs per iteration against the cached path→resource incidence
    (``incidence.py``), with a single cost refresh per sweep.  This is the
    execution-time-budget implementation (Table I) and matches the parallel
    dynamics of the jitted planner (``planner.plan_flows``).
  * ``refresh="sequential"`` — the faithful paper loop that refreshes costs
    after *every* assignment; kept for fidelity cross-checks
    (``tests/test_planner_equivalence.py``).

The exact IP (eqs. 1–5) is NP-hard; both loops converge geometrically since
each pair keeps ``(1-λ)^n`` of its demand after ``n`` visits (paper §IV-B).

Baselines implemented alongside (paper §II-B):
  * :func:`solve_direct` — NCCL-like static fastest path **with PXN**
    semantics: inter-node traffic is staged intra-node onto the chip owning
    the *destination's* rail, then crosses that single rail.  This is what
    funnels skewed traffic onto one NIC and produces the paper's up-to-5.2x
    headroom (Fig. 7).
  * :func:`solve_static_striping` — UCX-style load-oblivious even multirail
    striping.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Tuple

import numpy as np

from .cost import CostModel, ResourceModel
from .incidence import incidence_for
from .paths import DIRECT, Path, all_pairs_paths
from .topology import INTRA, Topology

PairKey = Tuple[int, int]

#: cost refreshes per sweep in the vectorized host solver — bounds parallel
#: MWU herding on near-balanced traffic while staying fully vectorized
_SUBSWEEPS = 8

#: host-solver price tiers, mirroring the jitted planner's (planner.py):
#: a relay candidate gated by the small-message threshold is priced at
#: ``_BIG`` and a candidate crossing a *down* link at ``_BIG_DOWN`` —
#: finite, so argmin degrades in tier order (healthy > gated-relay > dead
#: path) instead of funneling early zero-cost assignments onto a dead link;
#: structurally invalid candidates stay at +inf.  On a fabric with no down
#: links a finite healthy candidate always exists (the direct path), so
#: these tiers never change the argmin — plans stay bit-identical.
_HOST_BIG = 1e30
_HOST_BIG_DOWN = 1e32


@dataclasses.dataclass
class RoutedFlow:
    path: Path
    bytes: float


@dataclasses.dataclass
class Plan:
    """Output of the planner: per-pair path flows + resource accounting."""

    topo: Topology
    rm: ResourceModel
    flows: Dict[PairKey, List[RoutedFlow]]
    resource_bytes: np.ndarray   # effective bytes per resource
    link_bytes: np.ndarray       # raw payload bytes per link (first E entries)
    iterations: int
    # degraded-mode provenance (DESIGN.md §9): True when this plan came
    # from the survivor-striping fallback instead of a converged MWU solve
    degraded: bool = False

    # -- aggregate metrics ------------------------------------------------------
    def max_normalized_load(self) -> float:
        """The IP objective Z, capacity-normalized (seconds to drain)."""
        return float(np.max(self.resource_bytes / self.rm.capacity))

    def per_pair_bytes(self) -> Dict[PairKey, float]:
        return {k: sum(f.bytes for f in fl) for k, fl in self.flows.items()}

    def n_paths_used(self, pair: PairKey) -> int:
        return len({f.path for f in self.flows.get(pair, []) if f.bytes > 0})

    def consolidated(self) -> Dict[PairKey, List[RoutedFlow]]:
        """Merge repeated routings of the same path into one flow entry."""
        out: Dict[PairKey, List[RoutedFlow]] = {}
        for key, fl in self.flows.items():
            agg: Dict[Path, float] = {}
            for f in fl:
                agg[f.path] = agg.get(f.path, 0.0) + f.bytes
            out[key] = [RoutedFlow(p, b) for p, b in agg.items() if b > 0]
        return out


def _route(plan_loads, raw, rm, path, f):
    for rid, eff in rm.charges(path, f):
        plan_loads[rid] += eff
        if rid < rm.n_links:
            raw[rid] += f


def solve_mwu(
    topo: Topology,
    demands: Mapping[PairKey, float],
    cost_model: CostModel | None = None,
    *,
    lam: float = 0.25,
    eps: float = 1 << 20,
    prev_loads: np.ndarray | None = None,
    ext_loads: np.ndarray | None = None,
    max_iters: int = 10_000,
    refresh: str = "sweep",
) -> Plan:
    """Run Algorithm 1 over ``demands`` (bytes per ordered pair).

    ``refresh`` selects the cost-refresh discipline: ``"sweep"`` (default)
    is the vectorized incidence-matrix solver with one refresh per sweep
    over all live pairs; ``"sequential"`` is the legacy per-assignment
    refresh kept for fidelity cross-checks.

    ``prev_loads`` and ``ext_loads`` both raise resource prices before the
    first assignment, but with different contracts:

      * ``prev_loads`` is *this* job's previous loads — folded through the
        EMA (``CostModel.hysteresis``) and carried into the returned plan's
        ``resource_bytes`` (oscillation damping across replans);
      * ``ext_loads`` is *other tenants'* committed load (effective bytes
        per resource, e.g. :meth:`repro.fabric.FabricArbiter.prices_for`) —
        priced as-is, never EMA-smoothed, and **excluded** from the
        returned plan's accounting, so ``resource_bytes`` stays this
        tenant's own traffic.  ``ext_loads=None`` and all-zero
        ``ext_loads`` produce bit-identical plans.
    """
    if refresh == "sweep":
        return _solve_mwu_sweep(
            topo, demands, cost_model, lam=lam, eps=eps,
            prev_loads=prev_loads, ext_loads=ext_loads, max_iters=max_iters,
        )
    if refresh == "sequential":
        return _solve_mwu_sequential(
            topo, demands, cost_model, lam=lam, eps=eps,
            prev_loads=prev_loads, ext_loads=ext_loads, max_iters=max_iters,
        )
    raise ValueError(f"unknown refresh discipline {refresh!r}")


def _quantized_fraction(r: np.ndarray, lam: float, eps: float) -> np.ndarray:
    """Algorithm 1 lines 24-28: quantized λ-fraction of the residual."""
    f = np.where(r < eps, r, np.floor(r * lam / eps) * eps)
    return np.where((r >= eps) & (f <= 0), np.minimum(eps, r), f)


def _solve_mwu_sweep(
    topo: Topology,
    demands: Mapping[PairKey, float],
    cost_model: CostModel | None = None,
    *,
    lam: float = 0.25,
    eps: float = 1 << 20,
    prev_loads: np.ndarray | None = None,
    ext_loads: np.ndarray | None = None,
    max_iters: int = 10_000,
) -> Plan:
    """Vectorized Algorithm 1: batch path-cost evaluation per sweep.

    Live pairs are priced in a few interleaved sub-batches per sweep
    (``_SUBSWEEPS`` cost refreshes per sweep instead of one per
    assignment); each pair routes a quantized λ-fraction on its cheapest
    candidate, all in a handful of numpy ops over the cached incidence
    tables.  The sub-batching bounds the herding error of fully parallel
    MWU on near-balanced traffic (DESIGN.md §2.3) at negligible cost.
    """
    rm = ResourceModel(topo, cost_model)
    cm = rm.cm
    inc = incidence_for(topo, cm)
    n, E = topo.n_devices, topo.n_links

    keys: List[PairKey] = [
        (int(s), int(d)) for (s, d), v in demands.items()
        if v > 0 and s != d
    ]
    total = float(sum(float(demands[k]) for k in keys))
    # loads carry the trailing dummy slot so padded gathers stay in-bounds
    loads = np.zeros(inc.n_resources, dtype=np.float64)
    if prev_loads is not None:
        loads[:-1] = rm.smooth_loads(prev_loads, loads[:-1])
    # external (other-tenant) committed load: priced, never accounted.
    # Adding an all-zero vector is IEEE-exact, so ext_loads=None and zeros
    # yield bit-identical plans (the arbiter's zero-overhead contract).
    ext = np.zeros(inc.n_resources, dtype=np.float64)
    if ext_loads is not None:
        ext[:-1] = np.asarray(ext_loads, dtype=np.float64)
        if (ext < 0).any():
            raise ValueError("ext_loads must be non-negative")
    raw = np.zeros(E, dtype=np.float64)
    flows: Dict[PairKey, List[RoutedFlow]] = {k: [] for k in keys}
    if not keys:
        return Plan(topo, rm, flows, loads[:-1], raw, 0)

    res = np.array([float(demands[k]) for k in keys], dtype=np.float64)
    pair_ids = np.array([s * n + d for s, d in keys], dtype=np.int64)

    # per-pair candidate incidence rows, gathered once per table build
    pcand = inc.pair_candidates
    cand_c = np.where(pcand.valid, inc.pair_path_ids, 0)[pair_ids]  # [M, K]
    cand_rids = pcand.rids[pair_ids]                    # [M, K, MC]
    cand_mask = pcand.mask[pair_ids]                    # [M, K, MC]
    cand_mult = pcand.mult[pair_ids].astype(np.float64)
    cand_pen = pcand.penalty[pair_ids].astype(np.float64)
    # tiered gating (mirrors the jitted planner): invalid candidates are
    # +inf, small-message relays +_HOST_BIG, candidates crossing a down
    # link +_HOST_BIG_DOWN — so dead paths lose to *any* live option even
    # at zero accumulated load, instead of winning the first assignments
    tier = np.where(pcand.valid[pair_ids], 0.0, np.inf)
    tier += _HOST_BIG * (
        pcand.relay[pair_ids] & (res[:, None] <= cm.split_threshold)
    )
    down = topo.down_link_ids()
    if down:
        down_res = np.zeros(inc.n_resources, dtype=bool)
        down_res[np.asarray(down, dtype=np.int64)] = True
        tier += _HOST_BIG_DOWN * (
            (down_res[cand_rids] & cand_mask).any(axis=-1)
        )

    caps = inc.caps
    sweeps: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    alive = np.arange(len(keys))
    it = 0
    while alive.size and it < max_iters:
        it += 1
        nb = min(_SUBSWEEPS, alive.size)
        for b in range(nb):
            batch = alive[b::nb]                        # interleaved sub-batch
            costs = (loads + ext) / caps                # refresh per sub-batch
            pc = (
                np.max(costs[cand_rids[batch]] * cand_mask[batch], axis=-1)
                + cand_pen[batch]
                + tier[batch]
            )                                           # [Mb, K]
            best_k = np.argmin(pc, axis=-1)             # [Mb]
            f = _quantized_fraction(res[batch], lam, eps)
            rids_sel = cand_rids[batch, best_k]         # [Mb, MC]
            mult_sel = cand_mult[batch, best_k]         # [Mb, MC]
            np.add.at(loads, rids_sel.ravel(), (f[:, None] * mult_sel).ravel())
            link_sel = rids_sel < E
            np.add.at(
                raw,
                np.where(link_sel, rids_sel, 0).ravel(),
                (f[:, None] * link_sel).ravel(),
            )
            sweeps.append((batch, cand_c[batch, best_k], f))
            res[batch] = res[batch] - f
        alive = alive[res[alive] > 1e-9]

    if sweeps:
        # consolidate all (pair, path) assignments in one vectorized pass
        all_m = np.concatenate([b for b, _, _ in sweeps])
        all_pid = np.concatenate([p for _, p, _ in sweeps]).astype(np.int64)
        all_f = np.concatenate([f for _, _, f in sweeps])
        combo = all_m * inc.n_paths + all_pid
        uniq, inv = np.unique(combo, return_inverse=True)
        tot = np.zeros(len(uniq))
        np.add.at(tot, inv, all_f)
        for u, fb in zip(uniq, tot):
            m, pid = divmod(int(u), inc.n_paths)
            flows[keys[m]].append(RoutedFlow(inc.paths[pid], float(fb)))

    routed = total - float(res.sum())
    if abs(routed - total) > 1e-6 * max(total, 1.0):
        if topo.down_link_ids():
            # degraded fabric: serve a survivor-striped plan instead of
            # crashing the replan path (DESIGN.md §9)
            return solve_degraded(topo, demands, cost_model)
        raise RuntimeError(
            f"MWU failed to route all demand: {routed} of {total} bytes"
        )
    return Plan(topo, rm, flows, loads[:-1], raw, it)


def _solve_mwu_sequential(
    topo: Topology,
    demands: Mapping[PairKey, float],
    cost_model: CostModel | None = None,
    *,
    lam: float = 0.25,
    eps: float = 1 << 20,
    prev_loads: np.ndarray | None = None,
    ext_loads: np.ndarray | None = None,
    max_iters: int = 10_000,
) -> Plan:
    """Faithful paper loop: costs refreshed after every single assignment."""
    rm = ResourceModel(topo, cost_model)
    path_table = all_pairs_paths(topo)

    loads = np.zeros(rm.n_resources, dtype=np.float64)
    if prev_loads is not None:
        loads = rm.smooth_loads(prev_loads, loads)
    ext = np.zeros(rm.n_resources, dtype=np.float64)
    if ext_loads is not None:
        ext = ext + np.asarray(ext_loads, dtype=np.float64)
        if (ext < 0).any():
            raise ValueError("ext_loads must be non-negative")
    raw = np.zeros(topo.n_links, dtype=np.float64)

    residual: Dict[PairKey, float] = {
        k: float(v) for k, v in demands.items() if v > 0 and k[0] != k[1]
    }
    msg_size: Dict[PairKey, float] = dict(residual)
    flows: Dict[PairKey, List[RoutedFlow]] = {k: [] for k in residual}

    total = sum(residual.values())
    it = 0
    while residual and it < max_iters:
        it += 1
        costs = rm.resource_cost(loads + ext)
        for key in list(residual.keys()):
            r = residual[key]
            cands = path_table[key]
            pcosts = [rm.path_cost(p, costs, msg_size[key]) for p in cands]
            best = int(np.argmin(pcosts))
            path = cands[best]
            f = float(_quantized_fraction(np.float64(r), lam, eps))
            _route(loads, raw, rm, path, f)
            costs = rm.resource_cost(loads + ext)  # refresh per assignment
            flows[key].append(RoutedFlow(path, float(f)))
            residual[key] = r - f
            if residual[key] <= 1e-9:
                residual.pop(key)
    routed = sum(sum(fl.bytes for fl in v) for v in flows.values())
    if abs(routed - total) > 1e-6 * max(total, 1.0):
        if topo.down_link_ids():
            return solve_degraded(topo, demands, cost_model)
        raise RuntimeError(
            f"MWU failed to route all demand: {routed} of {total} bytes"
        )
    return Plan(topo, rm, flows, loads, raw, it)


def pxn_path(topo: Topology, key: PairKey) -> Path:
    """Static fastest path for ``key``: intra direct, else the PXN rail.

    PXN (NCCL v2.12+, §II-B): inter-node traffic uses the rail matching the
    *destination* chip, staging intra-node at the source side if needed.
    This is the per-pair rule of :func:`solve_direct`, exposed so stale-plan
    execution (``apply_plan_fractions``) can route previously-unseen pairs
    exactly like the static baseline would.
    """
    cands = all_pairs_paths(topo)[key]
    if topo.same_group(*key):
        return next(p for p in cands if p.family == DIRECT)
    dest_rail = topo.rail_of(key[1])

    def rail_of_path(p: Path) -> int:
        for l in p.links:
            if topo.kind[l] != INTRA:
                return topo.rail_of(topo.links[l].src)
        return -1

    return next(p for p in cands if rail_of_path(p) == dest_rail)


def solve_direct(
    topo: Topology,
    demands: Mapping[PairKey, float],
    cost_model: CostModel | None = None,
) -> Plan:
    """NCCL/MPI-style static fastest-path baseline with PXN rail selection."""
    rm = ResourceModel(topo, cost_model)
    loads = np.zeros(rm.n_resources, dtype=np.float64)
    raw = np.zeros(topo.n_links, dtype=np.float64)
    flows: Dict[PairKey, List[RoutedFlow]] = {}
    for key, d in demands.items():
        if d <= 0 or key[0] == key[1]:
            continue
        path = pxn_path(topo, key)
        _route(loads, raw, rm, path, float(d))
        flows[key] = [RoutedFlow(path, float(d))]
    return Plan(topo, rm, flows, loads, raw, 1)


def solve_static_striping(
    topo: Topology,
    demands: Mapping[PairKey, float],
    cost_model: CostModel | None = None,
) -> Plan:
    """UCX-style static multirail striping (§II-B): even, load-oblivious."""
    rm = ResourceModel(topo, cost_model)
    path_table = all_pairs_paths(topo)
    loads = np.zeros(rm.n_resources, dtype=np.float64)
    raw = np.zeros(topo.n_links, dtype=np.float64)
    flows: Dict[PairKey, List[RoutedFlow]] = {}
    for key, d in demands.items():
        if d <= 0 or key[0] == key[1]:
            continue
        cands = path_table[key]
        if topo.same_group(*key):
            chosen = [(p, float(d)) for p in cands if p.family == DIRECT]
        else:
            share = float(d) / len(cands)
            chosen = [(p, share) for p in cands]
        flows[key] = []
        for p, f in chosen:
            _route(loads, raw, rm, p, f)
            flows[key].append(RoutedFlow(p, f))
    return Plan(topo, rm, flows, loads, raw, 1)


def solve_degraded(
    topo: Topology,
    demands: Mapping[PairKey, float],
    cost_model: CostModel | None = None,
) -> Plan:
    """Survivor-striping fallback for a partially-dead fabric (DESIGN.md §9).

    When a fault leaves MWU with no converging residual (every candidate
    for some pair crosses a down link, or the iteration budget burns out
    against near-zero capacities), the runtime still needs *a* plan — a
    dead dataplane is strictly worse than an uneven one.  Each pair
    stripes evenly across its candidates that avoid every down link; a
    pair with no surviving candidate routes on the single candidate with
    the largest bottleneck capacity (least-dead path).  The returned plan
    is flagged ``degraded=True`` so reports and drills can tell a fallback
    from a converged solve.
    """
    rm = ResourceModel(topo, cost_model)
    path_table = all_pairs_paths(topo)
    down = set(topo.down_link_ids())
    loads = np.zeros(rm.n_resources, dtype=np.float64)
    raw = np.zeros(topo.n_links, dtype=np.float64)
    flows: Dict[PairKey, List[RoutedFlow]] = {}
    for key, d in demands.items():
        if d <= 0 or key[0] == key[1]:
            continue
        cands = path_table[key]
        alive = [
            p for p in cands if not any(l in down for l in p.links)
        ]
        if not alive:
            alive = [
                max(
                    cands,
                    key=lambda p: min(
                        topo.links[l].capacity for l in p.links
                    ),
                )
            ]
        share = float(d) / len(alive)
        flows[key] = []
        for p in alive:
            _route(loads, raw, rm, p, share)
            flows[key].append(RoutedFlow(p, share))
    return Plan(topo, rm, flows, loads, raw, 1, degraded=True)


# -- plan bridges (orchestration runtime) ---------------------------------------

def plan_from_flows(
    topo: Topology,
    flows_nnK: np.ndarray,
    demands: Mapping[PairKey, float],
    cost_model: CostModel | None = None,
    iterations: int = 0,
) -> Plan:
    """Materialize a host :class:`Plan` from jitted planner output.

    ``flows_nnK`` is the ``[n, n, K]`` per-candidate byte assignment of
    ``planner.plan_flows`` / ``plan_flows_batch`` (one batch entry).  Each
    pair's flows are rescaled to sum *exactly* to its demand (the jit loop
    runs in float32), attached to the concrete routes of the shared
    incidence tables, and recharged onto a fresh resource vector — so the
    returned plan simulates and reports identically to a host-solved one.
    """
    rm = ResourceModel(topo, cost_model)
    inc = incidence_for(topo, rm.cm)
    n, K = topo.n_devices, inc.K
    loads = np.zeros(rm.n_resources, dtype=np.float64)
    raw = np.zeros(topo.n_links, dtype=np.float64)
    flows: Dict[PairKey, List[RoutedFlow]] = {}
    for (s, d), dem in demands.items():
        if dem <= 0 or s == d:
            continue
        row = np.asarray(flows_nnK[s, d], dtype=np.float64)
        tot = float(row.sum())
        scale = float(dem) / tot if tot > 0 else 0.0
        fl: List[RoutedFlow] = []
        for k in range(K):
            pid = int(inc.pair_path_ids[s * n + d, k])
            if pid < 0:
                continue
            b = float(row[k]) * scale if tot > 0 else (
                float(dem) if k == 0 else 0.0
            )
            if b <= 0:
                continue
            fl.append(RoutedFlow(inc.paths[pid], b))
            _route(loads, raw, rm, inc.paths[pid], b)
        flows[(s, d)] = fl
    return Plan(topo, rm, flows, loads, raw, iterations)


def apply_plan_fractions(
    plan: Plan,
    demands: Mapping[PairKey, float],
    topo: Topology | None = None,
    cost_model: CostModel | None = None,
) -> Plan:
    """Execute a (possibly stale) plan's per-pair split ratios on new demand.

    This is what actually happens between replans: the dataplane keeps
    moving traffic along the last plan's paths while the demand drifts
    underneath it.  Each pair's new demand is split across the old plan's
    paths proportionally to their planned bytes; pairs the old plan never
    routed fall back to the static PXN rule (:func:`pxn_path`).  ``topo``
    may differ from ``plan.topo`` in link capacities (degradation events) —
    geometry must match, since paths are reused by link id.
    """
    topo = topo if topo is not None else plan.topo
    rm = ResourceModel(topo, cost_model or plan.rm.cm)
    stale = plan.consolidated()
    loads = np.zeros(rm.n_resources, dtype=np.float64)
    raw = np.zeros(topo.n_links, dtype=np.float64)
    flows: Dict[PairKey, List[RoutedFlow]] = {}
    for key, dem in demands.items():
        if dem <= 0 or key[0] == key[1]:
            continue
        old = stale.get(key)
        tot = sum(f.bytes for f in old) if old else 0.0
        if tot > 0:
            fl = [
                RoutedFlow(f.path, float(dem) * f.bytes / tot)
                for f in old
                if f.bytes > 0
            ]
        else:
            fl = [RoutedFlow(pxn_path(topo, key), float(dem))]
        for f in fl:
            _route(loads, raw, rm, f.path, f.bytes)
        flows[key] = fl
    return Plan(topo, rm, flows, loads, raw, plan.iterations)


# -- optimality accounting ------------------------------------------------------

def congestion_lower_bound(topo: Topology, demands: Mapping[PairKey, float],
                           cost_model: CostModel | None = None) -> float:
    """Cut lower bound on the min-max normalized congestion Z*.

    Valid cuts: (i) egress of s over min(out-link sum, inject cap);
    (ii) ingress of d over in-link sum; (iii) inter-group demand over the
    group's rail cut.  Z* >= max cut demand/capacity.
    """
    cm = cost_model or CostModel()
    n = topo.n_devices
    out_cap = np.zeros(n)
    in_cap = np.zeros(n)
    group_rail_cap = np.zeros(topo.n_groups)
    for l in topo.links:
        out_cap[l.src] += l.capacity
        in_cap[l.dst] += l.capacity
        if l.kind != INTRA:
            group_rail_cap[topo.group_of(l.src)] += l.capacity
    out_cap = np.minimum(out_cap, cm.inject_cap)
    egress = np.zeros(n)
    ingress = np.zeros(n)
    group_out = np.zeros(topo.n_groups)
    for (s, d), v in demands.items():
        if s == d or v <= 0:
            continue
        egress[s] += v
        ingress[d] += v
        if not topo.same_group(s, d):
            group_out[topo.group_of(s)] += v
    bounds = [0.0]
    with np.errstate(divide="ignore", invalid="ignore"):
        bounds.append(float(np.max(np.where(out_cap > 0, egress / out_cap, 0.0))))
        bounds.append(float(np.max(np.where(in_cap > 0, ingress / in_cap, 0.0))))
        gb = np.where(group_rail_cap > 0, group_out / group_rail_cap, 0.0)
        if len(gb):
            bounds.append(float(np.max(gb)))
    return max(bounds)
