"""Hierarchical interconnect topology for NIMBLE.

Models the paper's target fabric (Fig. 4) adapted to a TPU pod:

  * ``n_groups`` *node groups* of ``group_size`` chips each sit along the
    NIMBLE orchestration axis (the "model" mesh axis).  A group plays the
    role of the paper's 4-GPU node: chips inside a group are all-to-all
    connected by *intra* links (NVLink analogue / intra-group ICI).
  * Chip ``i`` of every group owns *rail* ``i`` (the paper's NIC-GPU
    affinity).  Rail-matched *inter* links connect chip ``i`` of group ``A``
    to chip ``i`` of group ``B`` (NDR rail analogue / inter-group ICI).
  * Groups may span *pods*; links that cross a pod boundary use the (lower)
    DCI capacity.

All links are directed.  Capacities are bytes/second; the defaults are the
paper's H100 node numbers so the fabric simulator reproduces Fig. 6 scales,
and can be swapped for TPU v5e ICI constants via :class:`LinkCaps`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Sequence, Tuple

import numpy as np

# Link kinds -----------------------------------------------------------------
INTRA = 0  # chip->chip inside a node group (NVLink / intra-group ICI)
RAIL = 1   # rail-matched chip_i(groupA) -> chip_i(groupB), same pod
DCI = 2    # rail-matched, crossing a pod boundary

#: capacity (bytes/s) assigned to a *down* link (scale <= 0).  Non-zero so
#: load/capacity cost and drain-time math never divide by zero; any traffic
#: actually routed onto a down link shows up as a catastrophic completion
#: time, which is what the orchestration runtime's replan loop reacts to.
DOWN_CAP = 1e-3


@dataclasses.dataclass(frozen=True)
class LinkCaps:
    """Per-kind link capacity in bytes/s.

    Defaults follow the paper's testbed: NVLink4 P2P ~120 GB/s peak per
    direct GPU pair (Fig. 6a) and one NDR400 rail ~45.1 GB/s measured
    (Fig. 6d).  ``dci`` models a cross-pod link at a fraction of rail
    bandwidth (TPU DCI is ~an order of magnitude below ICI).
    """

    intra: float = 120e9
    rail: float = 45.1e9
    dci: float = 11.3e9

    def of(self, kind: int) -> float:
        return (self.intra, self.rail, self.dci)[kind]


@dataclasses.dataclass(frozen=True)
class Link:
    lid: int
    src: int
    dst: int
    kind: int
    capacity: float


class Topology:
    """Directed link graph over ``n_devices`` chips along the NIMBLE axis."""

    def __init__(
        self,
        n_devices: int,
        group_size: int = 4,
        n_pods: int = 1,
        caps: LinkCaps | None = None,
        link_scale: Mapping[Tuple[int, int], float] | None = None,
    ):
        if n_devices % group_size != 0:
            raise ValueError(
                f"n_devices={n_devices} not divisible by group_size={group_size}"
            )
        n_groups = n_devices // group_size
        if n_groups % n_pods != 0:
            raise ValueError(
                f"n_groups={n_groups} not divisible by n_pods={n_pods}"
            )
        self.n_devices = n_devices
        self.group_size = group_size
        self.n_groups = n_groups
        self.n_pods = n_pods
        self.groups_per_pod = n_groups // n_pods
        self.caps = caps or LinkCaps()
        # per-link capacity scale (fault / degradation events): (src, dst) ->
        # scale in [0, 1]; scale <= 0 means *down* (capacity DOWN_CAP).
        # Entries equal to 1.0 are dropped so the fingerprint stays canonical.
        self.link_scale: Dict[Tuple[int, int], float] = {
            (int(s), int(d)): float(sc)
            for (s, d), sc in (link_scale or {}).items()
            if float(sc) != 1.0
        }

        self.links: List[Link] = []
        self._by_endpoints: Dict[Tuple[int, int], int] = {}
        self._build()
        for s, d in self.link_scale:
            if (s, d) not in self._by_endpoints:
                raise KeyError(f"link_scale names nonexistent link {s}->{d}")

        self.capacity = np.array([l.capacity for l in self.links], dtype=np.float64)
        self.kind = np.array([l.kind for l in self.links], dtype=np.int32)

    # -- construction ---------------------------------------------------------
    def _add(self, src: int, dst: int, kind: int) -> int:
        lid = len(self.links)
        cap = self.caps.of(kind)
        scale = self.link_scale.get((src, dst), 1.0)
        cap = cap * scale if scale > 0.0 else DOWN_CAP
        self.links.append(Link(lid, src, dst, kind, cap))
        self._by_endpoints[(src, dst)] = lid
        return lid

    def _build(self) -> None:
        G = self.group_size
        # intra-group all-to-all (the paper's per-node NVLink mesh)
        for g in range(self.n_groups):
            base = g * G
            for a in range(G):
                for b in range(G):
                    if a != b:
                        self._add(base + a, base + b, INTRA)
        # rail-matched inter-group links (the paper's NIC rails)
        for ga in range(self.n_groups):
            for gb in range(self.n_groups):
                if ga == gb:
                    continue
                kind = RAIL if self.pod_of_group(ga) == self.pod_of_group(gb) else DCI
                for r in range(G):
                    self._add(ga * G + r, gb * G + r, kind)

    # -- identity -------------------------------------------------------------
    @property
    def fingerprint(self) -> Tuple:
        """Hashable key that fully determines the link graph.

        ``_build`` is deterministic in these parameters, so two topologies
        with equal fingerprints have identical link ids, kinds, and
        capacities — the caching key for planner tables (DESIGN.md §2).
        """
        return (
            self.n_devices,
            self.group_size,
            self.n_pods,
            float(self.caps.intra),
            float(self.caps.rail),
            float(self.caps.dci),
            tuple(sorted(self.link_scale.items())),
        )

    # -- fault / degradation events -------------------------------------------
    def with_link_scale(
        self, overrides: Mapping[Tuple[int, int], float]
    ) -> "Topology":
        """New :class:`Topology` with per-link capacity scales replaced.

        ``overrides`` maps ``(src, dst)`` endpoints to a new scale: ``0``
        marks the link *down* (capacity :data:`DOWN_CAP`), values in (0, 1)
        model degradation, and ``1.0`` restores the link.  Scales compose by
        replacement, not multiplication, so restoring is idempotent.  The
        link *geometry* (ids, kinds) is unchanged — only capacities move —
        which keeps candidate-path enumeration and slot schedules valid
        while forcing fresh incidence tables via the fingerprint.
        """
        merged = dict(self.link_scale)
        for (s, d), sc in overrides.items():
            if (s, d) not in self._by_endpoints:
                raise KeyError(f"no link {s}->{d} in topology")
            merged[(int(s), int(d))] = float(sc)
        return Topology(
            self.n_devices, self.group_size, self.n_pods, self.caps, merged
        )

    def down_link_ids(self) -> List[int]:
        """Link ids currently marked down (capacity == DOWN_CAP)."""
        return [l.lid for l in self.links if l.capacity <= DOWN_CAP]

    # -- lookups --------------------------------------------------------------
    def pod_of_group(self, g: int) -> int:
        return g // self.groups_per_pod

    def group_of(self, dev: int) -> int:
        return dev // self.group_size

    def rail_of(self, dev: int) -> int:
        """Rail index = position inside the group (paper: NIC ordinal)."""
        return dev % self.group_size

    def same_group(self, a: int, b: int) -> bool:
        return self.group_of(a) == self.group_of(b)

    def link_id(self, src: int, dst: int) -> int:
        try:
            return self._by_endpoints[(src, dst)]
        except KeyError:
            raise KeyError(f"no direct link {src}->{dst} in topology") from None

    def has_link(self, src: int, dst: int) -> bool:
        return (src, dst) in self._by_endpoints

    @property
    def n_links(self) -> int:
        return len(self.links)

    # -- convenience ----------------------------------------------------------
    def describe(self) -> str:
        kinds = {INTRA: "intra", RAIL: "rail", DCI: "dci"}
        counts: Dict[str, int] = {}
        for l in self.links:
            counts[kinds[l.kind]] = counts.get(kinds[l.kind], 0) + 1
        return (
            f"Topology(devices={self.n_devices}, groups={self.n_groups}x"
            f"{self.group_size}, pods={self.n_pods}, links={counts})"
        )


class LinkEventBus:
    """Synchronous fan-out of link events to every registered listener.

    One physical fabric is shared by N tenants, but each tenant runtime
    keeps its *own* :class:`~repro.runtime.events.EventLog` and derives its
    own degraded :class:`Topology`.  Without a shared bus, a NIC flap
    delivered to one tenant leaves every other tenant planning against a
    stale fingerprint.  The bus closes that gap: a publisher (typically the
    fabric arbiter) calls :meth:`publish` once and every subscriber — each
    tenant's event-scheduling callback — receives the same event batch, so
    all tenants rebuild their fingerprint-keyed planner tables for the same
    fabric state.

    Delivery is synchronous and in subscription order; callbacks must not
    publish re-entrantly.  The payload is opaque to the bus (a sequence of
    :class:`~repro.runtime.events.LinkEvent` by convention).
    """

    def __init__(self):
        self._subs: Dict[int, Callable[[Sequence], None]] = {}
        self._next_token = 0

    def subscribe(self, callback: Callable[[Sequence], None]) -> int:
        """Register ``callback(events)``; returns an unsubscribe token."""
        token = self._next_token
        self._next_token += 1
        self._subs[token] = callback
        return token

    def unsubscribe(self, token: int) -> None:
        self._subs.pop(token, None)

    def publish(self, events: Sequence) -> int:
        """Deliver ``events`` to every subscriber; returns listener count."""
        events = list(events)
        for callback in list(self._subs.values()):
            callback(events)
        return len(self._subs)

    def __len__(self) -> int:
        return len(self._subs)
