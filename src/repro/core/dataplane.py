"""NIMBLE dataplane — scheduled multi-path All-to-Allv under shard_map.

The executable counterpart of the paper's "Kernel Scatter & Buffer Pipeline"
(§IV-C/D), adapted to TPU/XLA SPMD:

  * the *structure* (slots, rounds, permutations) is static — built once from
    the topology by ``schedule.build_schedule``;
  * the *flow amounts* are dynamic — each invocation all-gathers the live
    per-destination chunk counts (the demand matrix), runs the jittable MWU
    planner identically on every device (endpoint-driven: no coordinator),
    and fills slots accordingly;
  * each round is one ``lax.ppermute`` per hop-permutation, moving only the
    slot subset whose path uses that hop; relay chunks live in the same flat
    state array, so a device forwards by construction (the analogue of the
    paper's peer-exclusive channels + P2P staging buffers);
  * per-destination reassembly (ordering, §IV "reassembly queues") falls out
    of the deterministic slot -> chunk index mapping that both sender and
    receiver compute from the replicated plan.

Also provides the two baselines of §II-B over the *same* slot machinery
(``mode="direct"`` = NCCL/PXN static least-hop; ``mode="stripe"`` = UCX-style
even multirail striping), plus ``baseline_all_to_all`` (stock XLA).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .cost import CostModel
from .planner import PlannerConfig, plan_flows, plan_flows_batch, quantize_chunks
from .schedule import (
    CommSchedule,
    PlannerTables,
    build_planner_tables,
    build_schedule,
    enumerate_relations,
)
from .topology import Topology


def rel_id_of(m: int, dq: int, G: int) -> int:
    """rel enumeration order: m-major, (0,0) skipped."""
    return m * G + dq - 1


def build_rel_of_pair(n: int, G: int) -> np.ndarray:
    """[n, n] rel id for every ordered pair (-1 on the diagonal)."""
    NG = n // G
    out = np.full((n, n), -1, dtype=np.int32)
    for s in range(n):
        g, p = divmod(s, G)
        for d in range(n):
            if s == d:
                continue
            gd, q = divmod(d, G)
            m = (gd - g) % NG
            dq = (q - p) % G
            out[s, d] = rel_id_of(m, dq, G)
    return out


class NimbleAllToAll:
    """Callable all-to-allv over one mesh axis with execution-time planning.

    Use inside ``shard_map``:  ``y, recv = comm(x, send_chunks)`` where
    ``x: [n, C, chunk_elems]`` are padded per-destination send buffers and
    ``send_chunks: [n] int32`` the live chunk counts.  ``y`` has the same
    layout indexed by source.
    """

    def __init__(
        self,
        axis_name: str,
        n_devices: int,
        group_size: int = 4,
        *,
        max_chunks: int,
        chunk_bytes: float,
        alt_frac: float = 0.5,
        planner_cfg: Optional[PlannerConfig] = None,
        cost_model: Optional[CostModel] = None,
        mode: str = "nimble",  # nimble | direct | stripe
        topo: Optional[Topology] = None,
    ):
        if mode not in ("nimble", "direct", "stripe"):
            raise ValueError(f"unknown mode {mode!r}")
        self.axis_name = axis_name
        self.mode = mode
        # ``topo`` lets a Session (or any caller with a non-default fabric:
        # custom caps, pods, degraded links) supply the exact Topology the
        # planner should price; geometry must match the dataplane axis
        if topo is not None:
            if (topo.n_devices, topo.group_size) != (n_devices, group_size):
                raise ValueError(
                    f"topology geometry ({topo.n_devices}, "
                    f"{topo.group_size}) != dataplane geometry "
                    f"({n_devices}, {group_size})"
                )
            self.topo = topo
        else:
            self.topo = Topology(n_devices, group_size)
        # direct (NCCL/PXN-like) routes everything on k=0, so it provisions
        # no alternate slots — otherwise the dry-run would charge the static
        # baseline NIMBLE's wire padding (EXPERIMENTS.md §Perf fairness note)
        if mode == "direct":
            alt_frac = 0.0
        self.sched: CommSchedule = build_schedule(self.topo, max_chunks, alt_frac)
        self.tables: PlannerTables = build_planner_tables(self.topo, cost_model)
        self.cfg = planner_cfg or PlannerConfig(chunk_bytes=chunk_bytes)
        if self.cfg.chunk_bytes != chunk_bytes:
            self.cfg = dataclasses.replace(self.cfg, chunk_bytes=chunk_bytes)
        self.rel_of_pair = build_rel_of_pair(n_devices, group_size)
        # optional execution-time telemetry sink (runtime.LinkTelemetry):
        # host-driven plan_batch calls harvest planned resource loads into it
        self.telemetry = None

        n, G = n_devices, group_size
        rels = self.sched.rels
        self._rel_m = np.array([r.m for r in rels])
        self._rel_dq = np.array([r.dq for r in rels])
        self.n_rel = len(rels)
        self.K = self.sched.K
        self.C = max_chunks

        # §Perf C2: static segment layout.  Slots are ordered by (rel, k,
        # pos), so every (rel, k) run is contiguous; rounds move whole
        # segments via slice+concat+ppermute instead of fancy gather +
        # full-state scatter (whose autodiff re-reads the full slot state
        # per round — the dominant memory-term component on the MoE pair).
        sr, sk = self.sched.slot_rel, self.sched.slot_k
        segs = []                                    # (rel, k, start, end)
        start = 0
        for i in range(1, len(sr) + 1):
            if i == len(sr) or (sr[i], sk[i]) != (sr[start], sk[start]):
                segs.append((int(sr[start]), int(sk[start]), start, i))
                start = i
        self._segments = segs
        # per round: hop -> ordered list of segment ids
        self._round_groups = []
        for rnd in self.sched.rounds:
            sel_of_hop = {hop: set(sel.tolist()) for hop, sel in rnd}
            groups = {}
            for hop, slot_set in sel_of_hop.items():
                ids = [si for si, (_, _, s, e) in enumerate(segs)
                       if s in slot_set]
                groups[hop] = ids
            self._round_groups.append(groups)

    @classmethod
    def from_session(
        cls,
        session,
        axis_name: str,
        *,
        max_chunks: int,
        chunk_bytes: float,
        alt_frac: float = 0.5,
        mode: str = "nimble",
        planner_cfg: Optional[PlannerConfig] = None,
    ) -> "NimbleAllToAll":
        """Session-wired endpoint (DESIGN.md §5).

        Topology, cost model, and planner defaults come from the session
        (duck-typed: ``.topo``, ``.cost_model``, ``.spec.planner``,
        ``.runtime`` — this module never imports ``repro.api``); when the
        session runs an orchestration runtime, the endpoint's telemetry is
        attached so host-driven ``plan_batch`` calls feed its monitor
        stage.  With an all-default session this is constructor-equivalent
        to hand-wiring ``NimbleAllToAll(...)`` — bit-identical plans.
        """
        topo = session.topo
        comm = cls(
            axis_name,
            topo.n_devices,
            topo.group_size,
            max_chunks=max_chunks,
            chunk_bytes=chunk_bytes,
            alt_frac=alt_frac,
            planner_cfg=(
                planner_cfg if planner_cfg is not None else session.spec.planner
            ),
            cost_model=session.cost_model,
            mode=mode,
            topo=topo,
        )
        runtime = getattr(session, "runtime", None)
        if runtime is not None:
            comm.attach_telemetry(runtime.telemetry)
        return comm

    # -- plan -------------------------------------------------------------------
    def _plan(self, demand_chunks: jnp.ndarray) -> jnp.ndarray:
        """[n, n] chunk demand -> [n, n, K] per-path chunk assignment."""
        n, K = self.topo.n_devices, self.K
        if self.mode == "direct":
            # static least-hop: everything on k=0 (PXN destination-rail path)
            z = jnp.zeros((n, n, K), dtype=jnp.int32)
            return z.at[..., 0].set(demand_chunks.astype(jnp.int32))
        if self.mode == "stripe":
            # UCX-style: even split across candidates, remainder on k=0
            caps = jnp.asarray(self.sched.S, dtype=jnp.int32)[
                jnp.maximum(jnp.asarray(self.rel_of_pair), 0)
            ]  # [n,n,K]
            kvalid = (caps > 0).astype(jnp.int32)
            nk = jnp.maximum(kvalid.sum(-1), 1)
            share = (demand_chunks.astype(jnp.int32)[..., None] // nk[..., None])
            share = jnp.minimum(share * kvalid, caps)
            rem = demand_chunks.astype(jnp.int32) - share.sum(-1)
            return share.at[..., 0].add(rem)
        D = demand_chunks.astype(jnp.float32) * jnp.float32(self.cfg.chunk_bytes)
        flows, _ = plan_flows(D, self.tables, self.cfg, vary_axis=self.axis_name)
        return quantize_chunks(
            flows,
            demand_chunks.astype(jnp.int32),
            self.sched.S,
            self.rel_of_pair,
            self.cfg.chunk_bytes,
        )

    def attach_telemetry(self, sink) -> None:
        """Attach a ``runtime.LinkTelemetry`` (or duck-typed) sink.

        Subsequent host-driven :meth:`plan_batch` calls record each planned
        demand matrix and its per-resource loads via ``sink.record_loads``
        (self-numbered windows), feeding the orchestration runtime's
        monitor stage from real plan executions without touching the jitted
        dataplane path.  Only ``mode="nimble"`` produces a load vector —
        the static baselines plan elementwise and record nothing.
        """
        self.telemetry = sink

    def plan_batch(self, demand_chunks: jnp.ndarray) -> jnp.ndarray:
        """Plan a batch of demand matrices in one call: [B, n, n] -> [B, n, n, K].

        Multi-tenant / per-layer entry point (host-driven, outside
        shard_map): every batch entry is planned by the vmapped MWU against
        the same cached incidence tables and quantized to slot capacities.
        Only meaningful for ``mode="nimble"``; static modes broadcast their
        elementwise rules over the batch via the same ``_plan`` math.
        """
        if self.mode != "nimble":
            return jax.vmap(self._plan)(demand_chunks)
        D = demand_chunks.astype(jnp.float32) * jnp.float32(self.cfg.chunk_bytes)
        flows, loads = plan_flows_batch(D, self.tables, self.cfg)
        if self.telemetry is not None and not isinstance(D, jax.core.Tracer):
            # strip the trailing dummy resource the planner pads with
            loads_np = np.asarray(loads)[:, :-1]
            D_np = np.asarray(D)
            for b in range(loads_np.shape[0]):
                self.telemetry.record_loads(None, loads_np[b],
                                            pair_bytes=D_np[b])
        return jax.vmap(
            lambda f, dc: quantize_chunks(
                f, dc, self.sched.S, self.rel_of_pair, self.cfg.chunk_bytes
            )
        )(flows, demand_chunks.astype(jnp.int32))

    # -- execution ----------------------------------------------------------------
    def plan_from_counts(self, send_chunks: jnp.ndarray) -> jnp.ndarray:
        """All-gather live counts and plan (endpoint-driven, replicated)."""
        D = jax.lax.all_gather(send_chunks, self.axis_name)   # [n, n]
        return self._plan(D)                                  # [n, n, K]

    def __call__(
        self, x: jnp.ndarray, send_chunks: jnp.ndarray
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """x: [n, C, E] per-destination buffers; send_chunks: [n] int32."""
        chunks = self.plan_from_counts(send_chunks)
        y = self.execute(x, chunks)
        recv_chunks = chunks[:, jax.lax.axis_index(self.axis_name)].sum(-1)
        recv_chunks = recv_chunks.astype(send_chunks.dtype)
        me = jax.lax.axis_index(self.axis_name)
        recv_chunks = recv_chunks.at[me].set(send_chunks[me])
        return y, recv_chunks

    def execute(self, x: jnp.ndarray, chunks: jnp.ndarray) -> jnp.ndarray:
        """Move data according to a (replicated) per-path chunk plan."""
        n = self.topo.n_devices
        G, NG = self.topo.group_size, self.topo.n_groups
        sched = self.sched
        axis = self.axis_name

        me = jax.lax.axis_index(axis)
        g, p = me // G, me % G
        rel_m = jnp.asarray(self._rel_m)
        rel_dq = jnp.asarray(self._rel_dq)
        dest = ((g + rel_m) % NG) * G + (p + rel_dq) % G      # [n_rel]
        src = ((g - rel_m) % NG) * G + (p - rel_dq) % G       # [n_rel]

        my_rel_chunks = chunks[me][dest]                      # [n_rel, K]
        start = jnp.cumsum(my_rel_chunks, axis=-1) - my_rel_chunks

        slot_rel = jnp.asarray(sched.slot_rel)
        slot_k = jnp.asarray(sched.slot_k)
        slot_pos = jnp.asarray(sched.slot_pos)

        chunk_idx = start[slot_rel, slot_k] + slot_pos        # [n_slots]
        valid = slot_pos < my_rel_chunks[slot_rel, slot_k]
        x_rel = x[dest]                                       # [n_rel, C, E]
        state = (
            x_rel[slot_rel, jnp.clip(chunk_idx, 0, self.C - 1)]
            * valid[:, None].astype(x.dtype)
        )                                                     # [n_slots, E]

        # three normalized rounds of uniform hop permutations (§Perf C2:
        # per-(rel,k) segments move as contiguous slices — no full-state
        # gather/scatter per round)
        segs = self._segments
        state_segs = [
            jax.lax.slice_in_dim(state, s, e, axis=0)
            for (_, _, s, e) in segs
        ]
        for t in range(len(sched.rounds)):
            for hop, seg_ids in sorted(self._round_groups[t].items()):
                sub = jnp.concatenate([state_segs[i] for i in seg_ids],
                                      axis=0)
                sub = jax.lax.ppermute(sub, axis, sched.perm_pairs(hop))
                off = 0
                for i in seg_ids:
                    ln = segs[i][3] - segs[i][2]
                    state_segs[i] = jax.lax.slice_in_dim(
                        sub, off, off + ln, axis=0)
                    off += ln
        state = jnp.concatenate(state_segs, axis=0)

        # per-destination reassembly using the source's (replicated) plan
        src_rel_chunks = chunks[src, me]                      # [n_rel, K]
        rstart = jnp.cumsum(src_rel_chunks, axis=-1) - src_rel_chunks
        recv_idx = rstart[slot_rel, slot_k] + slot_pos
        rvalid = slot_pos < src_rel_chunks[slot_rel, slot_k]
        y_rel = jnp.zeros((self.n_rel, self.C, x.shape[-1]), dtype=x.dtype)
        y_rel = y_rel.at[slot_rel, jnp.clip(recv_idx, 0, self.C - 1)].add(
            state * rvalid[:, None].astype(x.dtype)
        )
        y = jnp.zeros_like(x).at[src].set(y_rel)
        y = y.at[me].set(x[me])                               # local traffic
        return y


def baseline_all_to_all(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Stock XLA all-to-all over the same [n, C, E] layout (inside shard_map)."""
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=True)


# -- host-side oracle -----------------------------------------------------------


def ref_all_to_allv(
    x_all: np.ndarray, counts_all: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Pure-numpy oracle: x_all [n, n, C, E], counts_all [n, n] -> (y, recv).

    y[d, s, c] = x_all[s, d, c] for c < counts_all[s, d], else 0.
    """
    n, _, C, E = x_all.shape
    y = np.zeros_like(x_all)
    recv = np.zeros((n, n), dtype=counts_all.dtype)
    for s in range(n):
        for d in range(n):
            c = int(counts_all[s, d])
            y[d, s, :c] = x_all[s, d, :c]
            recv[d, s] = c
    return y, recv
