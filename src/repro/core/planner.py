"""Execution-time planner — jittable multiplicative-weights MCF.

This is Algorithm 1 restructured for the TPU runtime: a **fixed-iteration,
vectorized** MWU loop in pure ``jnp`` so it can live inside a jitted train /
serve step and re-plan from the *live* demand matrix every invocation with
zero host round-trips and zero recompilation.

Differences from the faithful host implementation (``mcf.solve_mwu``),
recorded per DESIGN.md §2:

  * all pairs route a λ-fraction **simultaneously** each iteration (parallel
    MWU) instead of sequentially — required for vectorization; with the same
    geometric demand decay the fixed point is the same min-max balance, and
    tests cross-check the two implementations;
  * iteration count ``T`` is static (compile-time); residual demand after
    T iterations is dumped on the k=0 (least-hop) path, which is also the
    correct degenerate behaviour for small messages (size-threshold policy).

The planner itself is a few thousand FLOPs on a [n², K] problem — Table I of
the paper measures the GPU version at ~0.03–0.05 ms; ours is benchmarked in
``benchmarks/bench_algo_overhead.py``.

Data layout: all path pricing/charging runs against the per-pair candidate
rows of the shared :class:`~repro.core.incidence.PathIncidence` (cached per
topology fingerprint, DESIGN.md §2).  The gather/scatter indexing is
precomputed once per table build, so the ``fori_loop`` body is pure dense
ops: one gather of live costs, a masked max, an argmin, a one-hot flow
update, and a segment-sum load accumulation.  ``plan_flows_batch`` /
``plan_chunks_batch_jit`` vmap the same loop over a batch of demand
matrices for multi-tenant planning.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .cost import CostModel
from .jax_compat import pvary
from .schedule import PlannerTables

_BIG = 1e30
# price tiers above any real path cost: a small-message-gated relay path is
# preferable to a *down* path, which is preferable to K-padding.  On a
# healthy fabric nothing is down, and the tiering reduces to the original
# single-_BIG mask (argmin tie-break picks k=0), so plans are unchanged.
_BIG_DOWN = 1e32
_BIG_INVALID = 1e34
#: paths whose bottleneck capacity falls below this are treated as down
#: (see topology.DOWN_CAP); no real interconnect link is below 1 B/s
_DEAD_PATH_CAP = 1.0


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    lam: float = 0.25            # λ — fraction of residual routed per visit
    n_iters: int = 24            # T — static MWU iterations
    chunk_bytes: float = float(1 << 20)  # ε — quantization granularity
    split_threshold: float = float(1 << 20)  # paper: <=1 MB never splits
    hysteresis: float = 0.5


def planner_provenance(cfg: PlannerConfig) -> dict:
    """Solver-parameter fingerprint recorded in plan-provenance records
    and ``solve`` trace spans (DESIGN.md §11).

    ``engine`` identifies the planning discipline — today always the MWU
    sweep; the ROADMAP's ``PlanEngine`` zoo (BvN / FAST schedulers) will
    key audit records on it.
    """
    return {
        "engine": "mwu",
        "lam": float(cfg.lam),
        "n_iters": int(cfg.n_iters),
        "chunk_bytes": float(cfg.chunk_bytes),
        "hysteresis": float(cfg.hysteresis),
    }


def plan_flows(
    demand_bytes: jnp.ndarray,        # [n, n] float32, zero diagonal
    tables: PlannerTables,
    cfg: PlannerConfig = PlannerConfig(),
    prev_loads: jnp.ndarray | None = None,
    ext_loads: jnp.ndarray | None = None,  # [n_resources] external prices
    vary_axis: str | None = None,     # set when called inside shard_map
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (flows [n, n, K] bytes, resource loads [n_resources]).

    ``prev_loads`` is this job's previous load vector, folded through the
    EMA (``cfg.hysteresis``) into the returned loads.  ``ext_loads`` is
    other tenants' committed load (the fabric arbiter's exported prices):
    it raises resource costs during the solve but is **not** carried into
    the returned loads, and is never EMA-smoothed.
    """
    n, K = tables.n, tables.K
    caps = jnp.asarray(tables.caps, dtype=jnp.float32)
    # All gather/scatter indexing is precomputed per pair on the incidence
    # tables (DESIGN.md §2.3) — the loop body below is pure dense ops.
    pcand = tables.pair_candidates
    cand_rids = jnp.asarray(pcand.rids)                # [n*n, K, MC]
    cand_mult = jnp.asarray(pcand.mult)                # [n*n, K, MC]
    cand_mask = jnp.asarray(pcand.mask, dtype=jnp.float32)
    cand_pen = jnp.asarray(pcand.penalty)              # [n*n, K]

    D = demand_bytes.astype(jnp.float32).reshape(-1)   # [n*n]
    msg = D                                            # per-pair message size
    eps = jnp.float32(cfg.chunk_bytes)
    lam = jnp.float32(cfg.lam)

    loads0 = jnp.zeros(tables.n_resources, dtype=jnp.float32)
    if prev_loads is not None:
        loads0 = jnp.float32(cfg.hysteresis) * prev_loads
    # trace-time branch: ext_loads=None keeps the cost expression (and the
    # compiled program) bit-identical to the unarbitrated planner
    ext = None if ext_loads is None else ext_loads.astype(jnp.float32)

    # static price-out tiers: relay paths for small messages (_BIG), down
    # paths — bottleneck capacity below _DEAD_PATH_CAP after a link event —
    # (_BIG_DOWN), K-padding (_BIG_INVALID)
    small = jnp.asarray(pcand.relay) & (msg[:, None] <= cfg.split_threshold)
    down_np = pcand.valid & (pcand.min_cap < _DEAD_PATH_CAP)  # [n*n, K]
    invalid = jnp.asarray(~pcand.valid)
    down = jnp.asarray(down_np)

    def body(_, state):
        flows, res, loads = state
        priced = loads if ext is None else loads + ext
        costs = priced / caps                                       # [R]
        pcK = (
            jnp.max(costs[cand_rids] * cand_mask, axis=-1) + cand_pen
        )                                                           # [n*n, K]
        pcK = jnp.where(small, _BIG, pcK)
        pcK = jnp.where(down, _BIG_DOWN, pcK)
        pcK = jnp.where(invalid, _BIG_INVALID, pcK)
        best_k = jnp.argmin(pcK, axis=-1)                           # [n*n]
        # Algorithm 1 lines 24-28: quantized λ-fraction of the residual
        f = jnp.where(
            res < eps, res, jnp.floor(res * lam / eps) * eps
        )
        f = jnp.where((res >= eps) & (f <= 0), jnp.minimum(eps, res), f)
        f = jnp.maximum(f, 0.0)
        onehot = jax.nn.one_hot(best_k, K, dtype=flows.dtype)       # [n*n, K]
        flows = flows + f[:, None] * onehot
        sel = best_k[:, None, None]
        rids = jnp.take_along_axis(cand_rids, sel, axis=1)[:, 0]    # [n*n, MC]
        mult = jnp.take_along_axis(cand_mult, sel, axis=1)[:, 0]    # [n*n, MC]
        loads = loads + jax.ops.segment_sum(
            (f[:, None] * mult).reshape(-1),
            rids.reshape(-1),
            num_segments=tables.n_resources,
        )
        res = res - f
        return flows, res, loads

    flows = jnp.zeros((n * n, K), dtype=jnp.float32)
    if vary_axis is not None:
        # inside shard_map the demand is axis-varying; the loop carries must
        # match or lax.fori_loop rejects the body signature.
        flows = pvary(flows, vary_axis)
        loads0 = pvary(loads0, vary_axis)
    flows, res, loads = jax.lax.fori_loop(
        0, cfg.n_iters, body, (flows, D, loads0)
    )
    # residual after T iterations -> least-hop *alive* path (k=0 on a
    # healthy fabric; the first non-down candidate after a link event)
    alive = pcand.valid & ~down_np
    k_dump = np.where(alive.any(-1), np.argmax(alive, axis=-1), 0)
    if (k_dump == 0).all():
        flows = flows.at[:, 0].add(res)
    else:
        flows = flows.at[jnp.arange(n * n), jnp.asarray(k_dump)].add(res)
    return flows.reshape(n, n, K), loads


def plan_flows_batch(
    demand_bytes: jnp.ndarray,        # [B, n, n]
    tables: PlannerTables,
    cfg: PlannerConfig = PlannerConfig(),
    prev_loads: jnp.ndarray | None = None,  # [B, n_resources] or None
    ext_loads: jnp.ndarray | None = None,   # [B, n_resources] or None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Plan a batch of demand matrices in one call via ``jax.vmap``.

    Multi-tenant / per-expert entry point: B independent demand matrices
    (tenants, MoE layers, microbatches) are planned against the same cached
    incidence tables in a single jit-compiled vectorized MWU, instead of B
    sequential ``plan_flows`` dispatches.  ``ext_loads`` carries per-entry
    external prices (see :func:`plan_flows`).  Returns ``(flows
    [B, n, n, K], loads [B, n_resources])``.
    """
    if prev_loads is None and ext_loads is None:
        return jax.vmap(lambda d: plan_flows(d, tables, cfg))(demand_bytes)
    if prev_loads is None:
        return jax.vmap(
            lambda d, e: plan_flows(d, tables, cfg, ext_loads=e)
        )(demand_bytes, ext_loads)
    if ext_loads is None:
        return jax.vmap(
            lambda d, p: plan_flows(d, tables, cfg, prev_loads=p)
        )(demand_bytes, prev_loads)
    return jax.vmap(
        lambda d, p, e: plan_flows(d, tables, cfg, prev_loads=p, ext_loads=e)
    )(demand_bytes, prev_loads, ext_loads)


def quantize_chunks(
    flows: jnp.ndarray,        # [n, n, K] bytes
    demand_chunks: jnp.ndarray,  # [n, n] int32 — exact chunk counts
    slot_caps: np.ndarray,     # [n_rel, K] static slot capacities
    rel_of_pair: np.ndarray,   # [n, n] static rel id (-1 on diagonal)
    chunk_bytes: float,
) -> jnp.ndarray:
    """Round flows to integer chunks: alternates floor+clamp, direct absorbs.

    Guarantees sum_k chunks[s,d,k] == demand_chunks[s,d] and
    chunks[s,d,k] <= S[rel(s,d),k], so the dataplane never overflows a slot
    segment (k=0 capacity is C >= any per-destination demand by layout).
    """
    K = flows.shape[-1]
    caps = jnp.asarray(slot_caps, dtype=jnp.int32)[
        jnp.maximum(jnp.asarray(rel_of_pair), 0)
    ]  # [n, n, K]
    remaining = demand_chunks.astype(jnp.int32)
    out = []
    for k in range(K - 1, 0, -1):  # alternates, highest k first
        want = jnp.floor(flows[..., k] / chunk_bytes).astype(jnp.int32)
        got = jnp.minimum(jnp.minimum(want, caps[..., k]), remaining)
        out.append(got)
        remaining = remaining - got
    chunks = jnp.stack([remaining] + out[::-1], axis=-1)  # k=0 absorbs rest
    return chunks


@functools.partial(jax.jit, static_argnums=(1, 2))
def plan_chunks_jit(
    demand_chunks: jnp.ndarray,   # [n, n] int32
    tables: "PlannerTablesHashable",
    cfg: PlannerConfig,
) -> jnp.ndarray:
    """demand (chunks) -> per-path chunk assignment [n, n, K]."""
    t = tables.tables
    D = demand_chunks.astype(jnp.float32) * cfg.chunk_bytes
    flows, _ = plan_flows(D, t, cfg)
    return quantize_chunks(
        flows, demand_chunks, tables.slot_caps, tables.rel_of_pair,
        cfg.chunk_bytes,
    )


@functools.partial(jax.jit, static_argnums=(1, 2))
def plan_chunks_batch_jit(
    demand_chunks: jnp.ndarray,   # [B, n, n] int32
    tables: "PlannerTablesHashable",
    cfg: PlannerConfig,
) -> jnp.ndarray:
    """Batched multi-tenant planning: [B, n, n] -> [B, n, n, K] chunks.

    One jit call plans every tenant/layer demand matrix against the shared
    incidence tables (vectorized MWU under ``vmap``) and quantizes each to
    slot capacities.
    """
    t = tables.tables
    D = demand_chunks.astype(jnp.float32) * cfg.chunk_bytes
    flows, _ = plan_flows_batch(D, t, cfg)
    return jax.vmap(
        lambda f, dc: quantize_chunks(
            f, dc, tables.slot_caps, tables.rel_of_pair, cfg.chunk_bytes
        )
    )(flows, demand_chunks.astype(jnp.int32))


class PlannerTablesHashable:
    """Static wrapper so tables can be a jit static arg (hash by identity)."""

    def __init__(self, tables: PlannerTables, slot_caps: np.ndarray,
                 rel_of_pair: np.ndarray):
        self.tables = tables
        self.slot_caps = slot_caps
        self.rel_of_pair = rel_of_pair

    def __hash__(self) -> int:
        return id(self)

    def __eq__(self, other) -> bool:
        return self is other
