"""Execution-time planner — jittable multiplicative-weights MCF.

This is Algorithm 1 restructured for the TPU runtime: a **fixed-iteration,
vectorized** MWU loop in pure ``jnp`` so it can live inside a jitted train /
serve step and re-plan from the *live* demand matrix every invocation with
zero host round-trips and zero recompilation.

Differences from the faithful host implementation (``mcf.solve_mwu``),
recorded per DESIGN.md §2:

  * all pairs route a λ-fraction **simultaneously** each iteration (parallel
    MWU) instead of sequentially — required for vectorization; with the same
    geometric demand decay the fixed point is the same min-max balance, and
    tests cross-check the two implementations;
  * iteration count ``T`` is static (compile-time); residual demand after
    T iterations is dumped on the k=0 (least-hop) path, which is also the
    correct degenerate behaviour for small messages (size-threshold policy).

The planner itself is a few thousand FLOPs on a [n², K] problem — Table I of
the paper measures the GPU version at ~0.03–0.05 ms; ours is benchmarked in
``benchmarks/bench_algo_overhead.py``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .cost import CostModel
from .schedule import PlannerTables

_BIG = 1e30


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    lam: float = 0.25            # λ — fraction of residual routed per visit
    n_iters: int = 24            # T — static MWU iterations
    chunk_bytes: float = float(1 << 20)  # ε — quantization granularity
    split_threshold: float = float(1 << 20)  # paper: <=1 MB never splits
    hysteresis: float = 0.5


def plan_flows(
    demand_bytes: jnp.ndarray,        # [n, n] float32, zero diagonal
    tables: PlannerTables,
    cfg: PlannerConfig = PlannerConfig(),
    prev_loads: jnp.ndarray | None = None,
    vary_axis: str | None = None,     # set when called inside shard_map
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (flows [n, n, K] bytes, resource loads [n_resources])."""
    n, K = tables.n, tables.K
    caps = jnp.asarray(tables.caps, dtype=jnp.float32)
    path_rids = jnp.asarray(tables.path_rids)          # [P, MC]
    path_mult = jnp.asarray(tables.path_mult)          # [P, MC]
    path_penalty = jnp.asarray(tables.path_penalty)    # [P]
    path_relay = jnp.asarray(tables.path_relay)        # [P]
    pair_paths = jnp.asarray(tables.pair_path_ids)     # [n*n, K]
    valid = pair_paths >= 0
    pair_paths_c = jnp.where(valid, pair_paths, 0)

    D = demand_bytes.astype(jnp.float32).reshape(-1)   # [n*n]
    msg = D                                            # per-pair message size
    eps = jnp.float32(cfg.chunk_bytes)
    lam = jnp.float32(cfg.lam)

    loads0 = jnp.zeros(tables.n_resources, dtype=jnp.float32)
    if prev_loads is not None:
        loads0 = jnp.float32(cfg.hysteresis) * prev_loads

    # per-path size gate: relay paths priced out for small messages
    relay_gate = (
        path_relay[pair_paths_c] & (msg[:, None] <= cfg.split_threshold)
    )  # [n*n, K]

    def body(_, state):
        flows, res, loads = state
        costs = loads / caps                                        # [R]
        pc = jnp.max(
            costs[path_rids] * (path_mult > 0), axis=-1
        ) + path_penalty                                            # [P]
        pcK = jnp.where(valid, pc[pair_paths_c], _BIG)              # [n*n, K]
        pcK = jnp.where(relay_gate, _BIG, pcK)
        best_k = jnp.argmin(pcK, axis=-1)                           # [n*n]
        best_pid = jnp.take_along_axis(
            pair_paths_c, best_k[:, None], axis=-1
        )[:, 0]
        # Algorithm 1 lines 24-28: quantized λ-fraction of the residual
        f = jnp.where(
            res < eps, res, jnp.floor(res * lam / eps) * eps
        )
        f = jnp.where((res >= eps) & (f <= 0), jnp.minimum(eps, res), f)
        f = jnp.maximum(f, 0.0)
        flows = flows.at[jnp.arange(n * n), best_k].add(f)
        charges = (f[:, None] * path_mult[best_pid]).reshape(-1)
        rids = path_rids[best_pid].reshape(-1)
        loads = loads + jnp.zeros_like(loads).at[rids].add(charges)
        res = res - f
        return flows, res, loads

    flows = jnp.zeros((n * n, K), dtype=jnp.float32)
    if vary_axis is not None:
        # inside shard_map the demand is axis-varying; the loop carries must
        # match or lax.fori_loop rejects the body signature.
        flows = jax.lax.pvary(flows, vary_axis)
        loads0 = jax.lax.pvary(loads0, vary_axis)
    flows, res, loads = jax.lax.fori_loop(
        0, cfg.n_iters, body, (flows, D, loads0)
    )
    # residual after T iterations -> least-hop path (k=0)
    flows = flows.at[:, 0].add(res)
    return flows.reshape(n, n, K), loads


def quantize_chunks(
    flows: jnp.ndarray,        # [n, n, K] bytes
    demand_chunks: jnp.ndarray,  # [n, n] int32 — exact chunk counts
    slot_caps: np.ndarray,     # [n_rel, K] static slot capacities
    rel_of_pair: np.ndarray,   # [n, n] static rel id (-1 on diagonal)
    chunk_bytes: float,
) -> jnp.ndarray:
    """Round flows to integer chunks: alternates floor+clamp, direct absorbs.

    Guarantees sum_k chunks[s,d,k] == demand_chunks[s,d] and
    chunks[s,d,k] <= S[rel(s,d),k], so the dataplane never overflows a slot
    segment (k=0 capacity is C >= any per-destination demand by layout).
    """
    K = flows.shape[-1]
    caps = jnp.asarray(slot_caps, dtype=jnp.int32)[
        jnp.maximum(jnp.asarray(rel_of_pair), 0)
    ]  # [n, n, K]
    remaining = demand_chunks.astype(jnp.int32)
    out = []
    for k in range(K - 1, 0, -1):  # alternates, highest k first
        want = jnp.floor(flows[..., k] / chunk_bytes).astype(jnp.int32)
        got = jnp.minimum(jnp.minimum(want, caps[..., k]), remaining)
        out.append(got)
        remaining = remaining - got
    chunks = jnp.stack([remaining] + out[::-1], axis=-1)  # k=0 absorbs rest
    return chunks


@functools.partial(jax.jit, static_argnums=(1, 2))
def plan_chunks_jit(
    demand_chunks: jnp.ndarray,   # [n, n] int32
    tables: "PlannerTablesHashable",
    cfg: PlannerConfig,
) -> jnp.ndarray:
    """demand (chunks) -> per-path chunk assignment [n, n, K]."""
    t = tables.tables
    D = demand_chunks.astype(jnp.float32) * cfg.chunk_bytes
    flows, _ = plan_flows(D, t, cfg)
    return quantize_chunks(
        flows, demand_chunks, tables.slot_caps, tables.rel_of_pair,
        cfg.chunk_bytes,
    )


class PlannerTablesHashable:
    """Static wrapper so tables can be a jit static arg (hash by identity)."""

    def __init__(self, tables: PlannerTables, slot_caps: np.ndarray,
                 rel_of_pair: np.ndarray):
        self.tables = tables
        self.slot_caps = slot_caps
        self.rel_of_pair = rel_of_pair

    def __hash__(self) -> int:
        return id(self)

    def __eq__(self, other) -> bool:
        return self is other
