"""Version tolerance for the handful of jax APIs that moved across releases.

The repo targets current jax, but the container may pin an older release
(e.g. 0.4.x).  Import these shims instead of reaching for the moved names:

  * :func:`shard_map` — top-level ``jax.shard_map`` on new jax,
    ``jax.experimental.shard_map.shard_map`` on old;
  * :func:`set_mesh` — ``jax.set_mesh(mesh)`` context on new jax; on old
    jax the ``Mesh`` object itself is the context manager;
  * :func:`pvary` — ``jax.lax.pvary`` on new jax (varying-axis types under
    shard_map); identity on old jax, which has no such type system.
"""

from __future__ import annotations

import inspect

import jax

try:
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax < 0.6: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_HAS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(f, **kwargs):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename papered
    over (the replication-check kwarg was renamed in new jax)."""
    if not _HAS_CHECK_VMA and "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, **kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh  # old jax: Mesh is itself a context manager


def pvary(x, axis_name):
    """Mark ``x`` as varying over ``axis_name`` (no-op on old jax)."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axis_name) if fn is not None else x
