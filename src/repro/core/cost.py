"""Capacity-normalized resource/cost model F(L) (paper §IV-B, §V-B).

The paper replaces Garg–Könemann's exponential link cost with a custom
``c_e = F(L_e)`` "designed according to hardware features and potential
overhead in multi-path routing".  Our F is *serialization time*:

    F(L_r) = L_r / capacity_r        (seconds to drain resource r)

evaluated over a **resource vector** that extends the raw link set with the
two hardware effects the paper measures but never names as resources:

  * a per-device **relay throughput** cap — a forwarding GPU streams data
    through its L2/HBM, observed at ~93.1 GB/s per relay path
    (Fig. 6a: 213.1 - 120 = 93.1 for one intermediate);
  * a per-device **injection** cap — a sender cannot source more than
    ~278.2 GB/s aggregate (Fig. 6a: three concurrent paths saturate at
    278.2, not 120 + 2 x 93.1 = 306);
  * concurrent rails derate to ``rail_relay_eff`` of single-rail bandwidth
    when fed through relays (Fig. 6b: 45.1 + 3 x 45.1 x 0.923 = 170.0).

Path cost is the **max** over the path's resources (bottleneck metric,
matching the chunked pipeline dataplane of §IV-C), so min-max routing
directly minimizes modeled completion time.

Policies from the paper, all implemented here:
  * **size threshold** — relay splitting disabled at or below
    ``split_threshold`` (paper: 1 MB, Fig. 6c);
  * **size-aware hop penalty** — relay paths pay a pipeline fill/flush cost,
    only amortized by large messages (§V-B);
  * **hysteresis** — loads fold into an EMA across invocations to avoid
    oscillation (§I).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from .paths import DIRECT, Path
from .topology import INTRA, Topology


@dataclasses.dataclass
class CostModel:
    # --- policy knobs (paper defaults) ---------------------------------------
    split_threshold: float = 1 << 20   # bytes; <=1 MB stays single-path
    hop_setup_bytes: float = 2.0e6     # pipeline fill/flush, equivalent bytes
    # EMA weight on this job's OWN previous loads (0 = off).  This is the
    # single definition of the hysteresis factor: `prev_loads` inputs are
    # folded as `hysteresis * prev + (1 - hysteresis) * now`.  External
    # (other-tenant) load must enter through the solvers' `ext_loads`
    # instead — priced raw, never EMA-folded, never accounted (the fabric
    # arbiter's export; DESIGN.md §4).
    hysteresis: float = 0.5
    # --- hardware calibration (fit to the paper's Fig. 6) --------------------
    relay_cap: float = 93.1e9          # per-device forwarding throughput
    inject_cap: float = 278.2e9        # per-device egress aggregate
    rail_relay_eff: float = 0.923      # concurrent relayed-rail derate


class ResourceModel:
    """Resource vector = [links (E), relay (n), inject (n)]."""

    def __init__(self, topo: Topology, cm: CostModel | None = None):
        self.topo = topo
        self.cm = cm or CostModel()
        n, E = topo.n_devices, topo.n_links
        self.n_links = E
        self.n_resources = E + 2 * n
        caps = np.empty(self.n_resources, dtype=np.float64)
        caps[:E] = topo.capacity
        caps[E : E + n] = self.cm.relay_cap
        caps[E + n :] = self.cm.inject_cap
        self.capacity = caps

    # resource ids -------------------------------------------------------------
    def relay_rid(self, dev: int) -> int:
        return self.n_links + dev

    def inject_rid(self, dev: int) -> int:
        return self.n_links + self.topo.n_devices + dev

    # charging -----------------------------------------------------------------
    def charges(self, path: Path, f: float) -> List[Tuple[int, float]]:
        """(resource_id, effective_bytes) pairs for routing ``f`` bytes."""
        cm = self.cm
        out: List[Tuple[int, float]] = []
        relayed = path.n_relays > 0
        for l in path.links:
            if relayed and self.topo.kind[l] != INTRA:
                out.append((l, f / cm.rail_relay_eff))
            else:
                out.append((l, f))
        src = path.nodes[0]
        out.append((self.inject_rid(src), f))
        for relay in path.nodes[1:-1]:
            out.append((self.relay_rid(relay), f))
            out.append((self.inject_rid(relay), f))  # forwarding egress
        return out

    # cost ----------------------------------------------------------------------
    def resource_cost(self, load: np.ndarray) -> np.ndarray:
        """F(L): drain time per resource (seconds)."""
        return load / self.capacity

    def path_cost(
        self, path: Path, costs: np.ndarray, msg_bytes: float
    ) -> float:
        """Bottleneck (max) cost of the path + size-aware relay policies."""
        rids = [rid for rid, _ in self.charges(path, 1.0)]
        base = float(max(costs[r] for r in rids))
        if path.n_relays == 0:
            return base
        if msg_bytes <= self.cm.split_threshold:
            return float("inf")  # paper: no multi-path for small messages
        bottleneck_cap = float(
            min(self.capacity[rid] for rid, _ in self.charges(path, 1.0))
        )
        penalty = self.cm.hop_setup_bytes * path.n_relays / bottleneck_cap
        return base + penalty

    def smooth_loads(self, prev: np.ndarray | None, now: np.ndarray) -> np.ndarray:
        if prev is None or self.cm.hysteresis <= 0.0:
            return now
        return self.cm.hysteresis * prev + (1.0 - self.cm.hysteresis) * now


def capacity_normalized(topo: Topology, loads: np.ndarray) -> np.ndarray:
    """Per-link normalized congestion L_e / cap_e (the IP objective Z)."""
    return loads / topo.capacity
