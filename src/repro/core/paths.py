"""Candidate path enumeration (paper §IV-B).

NIMBLE restricts the MCF search space to three path families, matching the
paper exactly:

  * intra-node **direct**:    ``s -> d``                       (1 hop)
  * intra-node **2-hop**:     ``s -> i -> d``  (i in same node) (2 hops)
  * inter-node **rail-matched**: ``s -> rail_r(node_s) -> rail_r(node_d) -> d``
    where the middle hop is the rail link and the first/last hops are elided
    when ``s``/``d`` already sit on rail ``r``            (1..3 hops)

Deeper multi-hop is deliberately excluded (§V-B "Deeper multi-hop paths":
negative returns beyond one intra-node hop).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Tuple

from .topology import Topology

# path families
DIRECT = 0
TWO_HOP = 1
RAIL_MATCHED = 2


@dataclasses.dataclass(frozen=True)
class Path:
    """A candidate route: ordered link ids from source to destination."""

    links: Tuple[int, ...]
    nodes: Tuple[int, ...]  # device sequence, len(links)+1
    family: int

    @property
    def n_hops(self) -> int:
        return len(self.links)

    @property
    def n_relays(self) -> int:
        """Intermediate devices that only forward (paper's relay GPUs)."""
        return max(0, len(self.nodes) - 2)


def enumerate_paths(topo: Topology, s: int, d: int) -> List[Path]:
    """All candidate paths for ordered pair (s, d), direct-first."""
    if s == d:
        return []
    G = topo.group_size
    out: List[Path] = []
    if topo.same_group(s, d):
        # direct NVLink-analogue
        out.append(Path((topo.link_id(s, d),), (s, d), DIRECT))
        # one intermediate hop via every other chip in the group
        base = topo.group_of(s) * G
        for i in range(base, base + G):
            if i in (s, d):
                continue
            out.append(
                Path((topo.link_id(s, i), topo.link_id(i, d)), (s, i, d), TWO_HOP)
            )
    else:
        # rail-matched only (paper: PXN-style, avoids switch-level mismatch)
        gs, gd = topo.group_of(s), topo.group_of(d)
        for r in range(G):
            rs = gs * G + r
            rd = gd * G + r
            links: List[int] = []
            nodes: List[int] = [s]
            if rs != s:
                links.append(topo.link_id(s, rs))
                nodes.append(rs)
            links.append(topo.link_id(rs, rd))
            nodes.append(rd)
            if rd != d:
                links.append(topo.link_id(rd, d))
                nodes.append(d)
            out.append(Path(tuple(links), tuple(nodes), RAIL_MATCHED))
        # put the fully rail-matched route (no relay at either end) first so
        # that "direct" indexing (k=0) means the least-hop path, as in NCCL.
        out.sort(key=lambda p: (p.n_hops, p.nodes))
    return out


_PATHS_CACHE: "collections.OrderedDict[tuple, Dict[Tuple[int, int], List[Path]]]" = (
    collections.OrderedDict()
)
#: LRU bound — link events mint fresh fingerprints (see incidence._CACHE_CAP)
_PATHS_CACHE_CAP = 64


def all_pairs_paths(topo: Topology) -> Dict[Tuple[int, int], List[Path]]:
    """Candidate path table for every ordered device pair.

    Memoized under the topology fingerprint (two topologies with equal
    fingerprints have identical link ids) — callers must treat the returned
    table as read-only.
    """
    hit = _PATHS_CACHE.get(topo.fingerprint)
    if hit is not None:
        _PATHS_CACHE.move_to_end(topo.fingerprint)
        return hit
    table: Dict[Tuple[int, int], List[Path]] = {}
    for s in range(topo.n_devices):
        for d in range(topo.n_devices):
            if s != d:
                table[(s, d)] = enumerate_paths(topo, s, d)
    _PATHS_CACHE[topo.fingerprint] = table
    while len(_PATHS_CACHE) > _PATHS_CACHE_CAP:
        _PATHS_CACHE.popitem(last=False)
    return table


def max_candidates(topo: Topology) -> int:
    """Upper bound on candidate paths per pair (used for dense padding)."""
    # intra: 1 direct + (G-2) two-hop ; inter: G rail paths
    return max(topo.group_size - 1, topo.group_size)
