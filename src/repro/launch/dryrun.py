import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Proves the distribution config is coherent without hardware:
  * builds the production mesh (16x16 single pod / 2x16x16 multi-pod);
  * instantiates abstract params/optimizer/caches via ``jax.eval_shape``
    (ShapeDtypeStruct only — no allocation);
  * ``jax.jit(step, in_shardings=...).lower(...).compile()`` must succeed;
  * records ``memory_analysis()`` (fits?), ``cost_analysis()`` (FLOPs/bytes)
    and the parsed collective bytes into experiments/dryrun/*.json for the
    roofline table (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import functools
import sys
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jax_compat import set_mesh
from repro.jsonio import json_dumps
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model
from repro.optim import adamw
from repro.roofline.analysis import analyze, count_params, model_flops
from repro.serve.engine import make_serve_step
from repro.sharding.context import ParallelContext
from repro.sharding.specs import (
    build_cache_specs,
    build_param_specs,
    input_specs_sharding,
)
from repro.train.step import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")


def make_ctx(mesh, multi_pod: bool, moe_mode: str = "nimble",
             planner_iters: int = 12) -> ParallelContext:
    return ParallelContext(
        mesh=mesh,
        data_axes=("pod", "data") if multi_pod else ("data",),
        model_axis="model",
        ep_size=16,
        group_size=4,
        moe_mode=moe_mode,
        param_dtype=jnp.bfloat16,
        compute_dtype=jnp.bfloat16,
        remat=True,
    )


def _shardings_of(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs)


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            moe_mode: str = "nimble", alt_frac: float = 0.5,
            cfg_overrides: Dict | None = None,
            ctx_overrides: Dict | None = None) -> Dict:
    t0 = time.time()
    import dataclasses as _dc
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    ctx = make_ctx(mesh, multi_pod, moe_mode)
    if alt_frac != 0.5:
        ctx = _dc.replace(ctx, moe_alt_frac=alt_frac)
    if ctx_overrides:
        ctx = _dc.replace(ctx, **ctx_overrides)
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    model = build_model(cfg, ctx)
    rec: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": moe_mode,
    }
    if not model.supports(shape):
        rec["status"] = "skipped (DESIGN.md §7)"
        return rec
    if shape.name == "long_500k" and cfg.arch_type == "audio":
        rec["status"] = "skipped"
        return rec

    rng = jax.random.PRNGKey(0)
    params_abs = jax.eval_shape(model.init, rng)
    n_params = count_params(params_abs)
    rec["n_params"] = n_params
    p_specs = build_param_specs(params_abs, ctx)
    p_shard = _shardings_of(p_specs, mesh)

    ispecs = model.input_specs(shape)

    with set_mesh(mesh):
        if shape.kind in ("train",):
            opt_cfg = adamw.AdamWConfig()
            opt_abs = jax.eval_shape(adamw.init, params_abs)
            o_shard = jax.tree.map(
                lambda l, s=None: None, opt_abs)  # placeholder
            o_specs = {
                "m": p_specs, "v": p_specs,
            }
            o_shard = adamw.OptState(
                m=_shardings_of(p_specs, mesh),
                v=_shardings_of(p_specs, mesh),
                step=NamedSharding(mesh, P()),
            )
            step_fn = make_train_step(model, opt_cfg)
            b_shard = input_specs_sharding(ispecs, ctx, shape)
            jf = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard),
                donate_argnums=(0, 1),
            )
            lowered = jf.lower(params_abs, opt_abs, ispecs)
            tokens = shape.global_batch * shape.seq_len
            kind = "train"
        elif shape.kind == "prefill":
            # §Perf B1: slice hidden state before lm_head (last_only) so the
            # TP logits collective is [B, 1, V] not [B, S, V].  Disable via
            # --set-ctx to measure the baseline.
            last_only = bool(int(os.environ.get("NIMBLE_PREFILL_FULL", "0")) == 0)

            def prefill(params, batch):
                logits, _ = model.forward(params, batch, last_only=last_only)
                return logits[:, -1]
            b_shard = input_specs_sharding(ispecs, ctx, shape)
            jf = jax.jit(prefill, in_shardings=(p_shard, b_shard))
            lowered = jf.lower(params_abs, ispecs)
            tokens = shape.global_batch * shape.seq_len
            kind = "prefill"
        else:  # decode
            cache_abs = jax.eval_shape(
                functools.partial(model.init_cache, shape.global_batch, shape)
            )
            c_specs = build_cache_specs(cache_abs, ctx)
            c_shard = _shardings_of(c_specs, mesh)
            serve = make_serve_step(model)
            tok_shard = input_specs_sharding(ispecs, ctx, shape)
            jf = jax.jit(
                serve,
                in_shardings=(p_shard, c_shard, tok_shard["token"],
                              tok_shard["pos"]),
                donate_argnums=(1,),
            )
            lowered = jf.lower(params_abs, cache_abs, ispecs["token"],
                               ispecs["pos"])
            tokens = shape.global_batch
            kind = "decode"

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    rec["bytes_per_device"] = {
        "argument": getattr(mem, "argument_size_in_bytes", None),
        "output": getattr(mem, "output_size_in_bytes", None),
        "temp": getattr(mem, "temp_size_in_bytes", None),
        "peak": (getattr(mem, "argument_size_in_bytes", 0) or 0)
        + (getattr(mem, "temp_size_in_bytes", 0) or 0),
    }
    mf = model_flops(cfg, n_params, tokens, kind)
    roof = analyze(compiled, n_chips, mf)
    rec["roofline"] = roof.as_dict()
    rec["status"] = "ok"
    rec["compile_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-mode", default="nimble",
                    choices=["nimble", "direct", "stripe"])
    ap.add_argument("--alt-frac", type=float, default=0.5)
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="ModelConfig override, e.g. --set mlstm_chunk=64")
    ap.add_argument("--set-ctx", action="append", default=[], metavar="K=V",
                    help="ParallelContext override, e.g. --set-ctx remat=False")
    ap.add_argument("--tag", default="", help="suffix for the output json")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    def _parse_kv(items):
        out = {}
        for it in items:
            k, v = it.split("=", 1)
            for cast in (int, float):
                try:
                    v = cast(v)
                    break
                except ValueError:
                    continue
            if v in ("True", "true"):
                v = True
            elif v in ("False", "false"):
                v = False
            out[k] = v
        return out

    cfg_overrides = _parse_kv(args.set)
    ctx_overrides = _parse_kv(args.set_ctx)

    os.makedirs(args.out, exist_ok=True)
    combos = []
    archs = ARCH_IDS[:-1] if args.all else [args.arch]  # paper-moe via bench
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            combos.append((a, s))

    failures = 0
    for a, s in combos:
        tag = f"{a}_{s}_{'2x16x16' if args.multi_pod else '16x16'}_{args.moe_mode}"
        if args.alt_frac != 0.5:
            tag += f"_alt{args.alt_frac}"
        if args.tag:
            tag += f"_{args.tag}"
        path = os.path.join(args.out, tag + ".json")
        try:
            rec = run_one(a, s, multi_pod=args.multi_pod,
                          moe_mode=args.moe_mode, alt_frac=args.alt_frac,
                          cfg_overrides=cfg_overrides,
                          ctx_overrides=ctx_overrides)
            if cfg_overrides or ctx_overrides:
                rec["overrides"] = {**cfg_overrides,
                                    **{f"ctx.{k}": v
                                       for k, v in ctx_overrides.items()}}
        except Exception as e:
            rec = {"arch": a, "shape": s, "status": "FAIL",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            failures += 1
        with open(path, "wb") as f:
            f.write(json_dumps(rec, indent=True))
        status = rec.get("status")
        roof = rec.get("roofline", {})
        print(
            f"[dryrun] {a:24s} {s:12s} {status:8s} "
            f"dom={roof.get('dominant','-'):10s} "
            f"comp={roof.get('compute_s',0):.3e}s "
            f"mem={roof.get('memory_s',0):.3e}s "
            f"coll={roof.get('collective_s',0):.3e}s "
            f"({rec.get('compile_s','-')}s)",
            flush=True,
        )
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
