"""Serving launcher: batched greedy/temperature generation.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --batch 4 --prompt-len 8 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.registry import build_model
from repro.serve.engine import ServeEngine
from repro.sharding.context import SINGLE


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, SINGLE)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.new_tokens)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(
        np.int32
    )
    t0 = time.time()
    out = engine.generate(prompts, n_new=args.new_tokens,
                          temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    tok_s = args.batch * args.new_tokens / dt
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({tok_s:.1f} tok/s)")
    print("[serve] sample:", out[0][:12].tolist())
    return out


if __name__ == "__main__":
    main()
