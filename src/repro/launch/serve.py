"""Serving launcher: scenario control plane + batched generation.

Scenario mode — run a registry (or JSON-file) scenario through the
continuous-traffic control plane (DESIGN.md §10) and print the SLO
verdict:

    PYTHONPATH=src python -m repro.launch.serve --scenario steady
    PYTHONPATH=src python -m repro.launch.serve --scenario path/to/spec.json \
        --mode static --json report.json
    PYTHONPATH=src python -m repro.launch.serve --list-scenarios

With ``--trace-out PATH`` the run is flight-recorded (DESIGN.md §11): a
:class:`repro.obs.FlightRecorder` rides the adaptive arm and the
resulting ``nimble.trace/v1`` record — valid Chrome/Perfetto trace JSON
with one correlation id across serve / runtime / fabric / planner — is
written to PATH (open it at ``ui.perfetto.dev`` or ``chrome://tracing``).
``--metrics-out PATH`` writes the final ``nimble.metrics/v1`` snapshot;
either flag also prints trace and plan-provenance summaries:

    PYTHONPATH=src python -m repro.launch.serve --scenario flap_under_load \
        --mode adaptive --trace-out trace.json --metrics-out metrics.json

Generation mode — batched greedy/temperature token generation through
``ServeEngine``:

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --batch 4 --prompt-len 8 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time


def _run_scenario(args) -> int:
    from repro.jsonio import write_json_file
    from repro.serve import (
        evaluate_scenario,
        load_scenario,
        run_scenario,
        scenario_names,
    )

    spec = load_scenario(args.scenario)
    recorder = None
    if args.trace_out or args.metrics_out:
        from repro.obs import FlightRecorder

        recorder = FlightRecorder()
    t0 = time.time()
    if args.mode == "both":
        res = evaluate_scenario(spec, recorder=recorder)
        report, slo = res["adaptive"], res["slo"]
    else:
        report, slo = run_scenario(spec, args.mode, recorder=recorder), None
    dt = time.time() - t0

    tenants = report.tenants
    print(
        f"[serve] scenario {spec.name!r}: {spec.windows} windows, "
        f"{len(tenants)} tenant(s), mode={report.mode} ({dt:.1f}s)"
    )
    print(
        f"[serve] cluster: total {report.total_completion_s:.4f}s, "
        f"median {report.median_latency_s() * 1e3:.2f}ms, "
        f"availability {report.availability:.2f}, "
        f"Jain {report.jain_index:.3f}"
    )
    for name, led in sorted(tenants.items()):
        life = f"w{led.joined}-" + (
            f"w{led.left}" if led.left is not None else "end"
        )
        print(
            f"[serve]   {name}: {life} {led.windows}w "
            f"{led.completion_s:.4f}s drain, {led.replans} replans"
            + (" (crashed)" if led.crashed else "")
        )
    if slo is not None:
        for gate, v in slo["gates"].items():
            val = v["value"]
            shown = f"{val:.3f}" if isinstance(val, float) else str(val)
            print(
                f"[serve]   gate {gate}: "
                f"{'PASS' if v['ok'] else 'FAIL'} "
                f"(value {shown}, limit {v['limit']})"
            )
        print(f"[serve] SLO: {'PASS' if slo['pass'] else 'FAIL'}")
    if recorder is not None:
        from repro.obs import validate_trace

        trace = recorder.export_trace()
        info = validate_trace(trace)
        print(
            f"[serve] trace: {info['events']} events, {info['spans']} spans, "
            f"layers={sorted(info['cats'])}, corr={info['correlation_id']}"
        )
        print(
            f"[serve] provenance: {len(recorder.provenance)} plans issued, "
            f"{len(recorder.provenance.swapped())} swapped"
        )
        if args.trace_out:
            write_json_file(args.trace_out, trace)
            print(f"[serve] trace -> {args.trace_out}")
        if args.metrics_out:
            write_json_file(args.metrics_out, recorder.metrics_snapshot())
            print(f"[serve] metrics -> {args.metrics_out}")
    if args.json:
        obj = report.to_json_obj()
        if slo is not None:
            obj["slo"] = slo
        write_json_file(args.json, obj)
        print(f"[serve] report -> {args.json}")
    return 0 if slo is None or slo["pass"] else 1


def _run_generate(args):
    import jax
    import numpy as np

    from repro.configs.base import get_config
    from repro.models.registry import build_model
    from repro.serve.engine import ServeEngine
    from repro.sharding.context import SINGLE

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, SINGLE)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params,
                         max_len=args.prompt_len + args.new_tokens)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)).astype(
        np.int32
    )
    t0 = time.time()
    out = engine.generate(prompts, n_new=args.new_tokens,
                          temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    tok_s = args.batch * args.new_tokens / dt
    print(f"[serve] {cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({tok_s:.1f} tok/s)")
    print("[serve] sample:", out[0][:12].tolist())
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    # scenario mode
    ap.add_argument("--scenario", default=None,
                    help="registry name or scenario JSON path")
    ap.add_argument("--mode", default="both",
                    choices=("adaptive", "static", "both"),
                    help="control-plane arm; 'both' also gates the SLOs")
    ap.add_argument("--json", default=None,
                    help="write the nimble.serve/v1 report here")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="flight-record the run and write the "
                         "nimble.trace/v1 Chrome trace JSON here")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="write the final nimble.metrics/v1 snapshot here")
    ap.add_argument("--list-scenarios", action="store_true")
    # generation mode
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.list_scenarios:
        from repro.serve import scenario_names
        print("\n".join(scenario_names()))
        return 0
    if args.scenario is not None:
        return _run_scenario(args)
    return _run_generate(args)


if __name__ == "__main__":
    main()
