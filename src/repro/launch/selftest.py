import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Multi-device selftest (run as a subprocess from pytest).

Validates on 8 forced host devices:
  1. the NIMBLE dataplane (all modes) is bit-exact vs the numpy oracle;
  2. MoE dispatch/combine matches the dense per-token reference under skew;
  3. an EP MoE train step runs under shard_map on a 2x4 mesh and the loss
     is finite and matches the single-device loss to tolerance.

Exit code 0 = all pass.
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.dataplane import NimbleAllToAll, ref_all_to_allv
from repro.core.jax_compat import set_mesh, shard_map
from repro.core.moe_comm import MoECommConfig, MoEDispatcher


def test_dataplane(n=8, C=16, E=32) -> bool:
    mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
    rng = np.random.default_rng(0)
    x_all = rng.normal(size=(n, n, C, E)).astype(np.float32)
    counts = rng.integers(0, C + 1, size=(n, n)).astype(np.int32)
    for s in range(n):
        for d in range(n):
            x_all[s, d, counts[s, d]:] = 0.0
    ok = True
    for mode in ["direct", "stripe", "nimble"]:
        comm = NimbleAllToAll("x", n, 4, max_chunks=C, chunk_bytes=E * 4,
                              mode=mode)
        fm = shard_map(lambda x, c: comm(x, c), mesh=mesh,
                       in_specs=(P("x"), P("x")), out_specs=(P("x"), P("x")))
        y, r = jax.jit(fm)(jnp.asarray(x_all.reshape(n * n, C, E)),
                           jnp.asarray(counts.reshape(n * n)))
        y = np.asarray(y).reshape(n, n, C, E)
        r = np.asarray(r).reshape(n, n)
        yref, rref = ref_all_to_allv(x_all, counts)
        good = np.allclose(y, yref) and np.array_equal(r, rref)
        print(f"[selftest] dataplane {mode}: {'OK' if good else 'FAIL'}")
        ok &= good
    return ok


def test_moe_comm(n=8, T=64, d=16, k=2, n_exp=16) -> bool:
    mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
    rng = np.random.default_rng(1)
    toks = rng.normal(size=(n * T, d)).astype(np.float32)
    eidx = rng.integers(0, n_exp, size=(n * T, k)).astype(np.int32)
    hot = rng.random((n * T, k)) < 0.5
    eidx = np.where(hot, rng.integers(0, 2, size=(n * T, k)), eidx).astype(
        np.int32
    )
    gw = rng.random((n * T, k)).astype(np.float32)
    ok = True
    for mode in ["direct", "nimble"]:
        cfg = MoECommConfig(n_devices=n, n_experts=n_exp, d_model=d,
                            chunk_tokens=4, capacity_factor=8.0, mode=mode)
        disp = MoEDispatcher("x", cfg)

        def f(tok, ei, w):
            rt, el, st = disp.dispatch(tok, ei)
            me = jax.lax.axis_index("x")
            scale = jnp.where(
                el >= 0,
                (el + me * cfg.experts_per_device + 1).astype(jnp.float32),
                0.0,
            )
            return disp.combine(rt * scale[..., None], st, w)

        fm = shard_map(f, mesh=mesh, in_specs=(P("x"),) * 3,
                       out_specs=P("x"))
        y = np.asarray(jax.jit(fm)(jnp.asarray(toks), jnp.asarray(eidx),
                                   jnp.asarray(gw)))
        yref = np.zeros_like(toks)
        for j in range(k):
            yref += gw[:, j:j + 1] * toks * (eidx[:, j:j + 1] + 1.0)
        good = np.abs(y - yref).max() < 1e-4
        print(f"[selftest] moe_comm {mode}: {'OK' if good else 'FAIL'}")
        ok &= good
    return ok


def test_ep_train_step() -> bool:
    import dataclasses

    from repro.configs.base import get_config
    from repro.models.registry import build_model
    from repro.optim import adamw
    from repro.sharding.context import ParallelContext
    from repro.sharding.specs import build_param_shardings
    from repro.train.step import make_train_step

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(
        get_config("granite-moe-1b-a400m").reduced(),
        n_experts=8, top_k=2,
    )
    ctx = ParallelContext(mesh=mesh, data_axes=("data",), ep_size=4,
                          group_size=2, moe_mode="nimble")
    model = build_model(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32), dtype=np.int64).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32), dtype=np.int64).astype(np.int32)),
    }
    step = make_train_step(model, adamw.AdamWConfig())
    with set_mesh(mesh):
        p_sh = build_param_shardings(params, ctx)
        params_s = jax.device_put(params, p_sh)
        _, _, metrics = jax.jit(step)(params_s, opt, batch)
        loss_ep = float(metrics["loss"])
    # single-device reference
    from repro.sharding.context import SINGLE
    model1 = build_model(cfg, SINGLE)
    step1 = make_train_step(model1, adamw.AdamWConfig())
    _, _, m1 = jax.jit(step1)(params, adamw.init(params), batch)
    loss_1 = float(m1["loss"])
    good = np.isfinite(loss_ep) and abs(loss_ep - loss_1) < 5e-2
    print(f"[selftest] EP train step: loss_ep={loss_ep:.4f} "
          f"loss_single={loss_1:.4f} {'OK' if good else 'FAIL'}")
    return good


def main():
    ok = test_dataplane() and test_moe_comm() and test_ep_train_step()
    print(f"[selftest] {'ALL OK' if ok else 'FAILURES'}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
