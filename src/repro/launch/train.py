"""Training launcher.

Runs real training on whatever devices exist: single CPU device for the
examples, a forced-host-device mesh for multi-device runs, a real TPU pod
slice in production (same code path — mesh axes from --mesh).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 256 --d-model 256 --layers 4
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticLM, add_modality_stubs
from repro.models.registry import build_model
from repro.optim import adamw
from repro.sharding.context import ParallelContext, SINGLE
from repro.train.step import make_train_step


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale reduced config")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args(argv)


def build_cfg(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers or args.d_model:
        heads = cfg.n_heads
        d = args.d_model or cfg.d_model
        d = max(d // heads, 8) * heads
        cfg = dataclasses.replace(
            cfg,
            n_layers=args.layers or cfg.n_layers,
            d_model=d,
            d_ff=(d * 3 if cfg.d_ff else 0),
            n_enc_layers=min(cfg.n_enc_layers, args.layers or cfg.n_enc_layers),
        )
    return cfg


def main(argv=None):
    args = parse_args(argv)
    cfg = build_cfg(args)
    ctx = SINGLE
    model = build_model(cfg, ctx)
    print(f"[train] {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab} arch={cfg.arch_type}")

    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] params: {n_params/1e6:.2f}M")

    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps
    )
    opt_state = adamw.init(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    data = SyntheticLM(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
    ))

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        batch = add_modality_stubs(data.batch(step), cfg, rng_seed=step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
        if args.ckpt_every and args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1,
                      {"params": params, "opt": opt_state})
    first = np.mean(losses[: max(3, len(losses) // 10)])
    last = np.mean(losses[-max(3, len(losses) // 10):])
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
