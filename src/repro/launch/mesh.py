"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips.  Multi-pod: (pod=2, data=16,
model=16) = 512 chips; the pod axis is pure data parallel (gradient
all-reduce over DCI), the model axis hosts tensor/expert parallelism and is
the NIMBLE orchestration axis (DESIGN.md §8).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import jax.sharding as jsh
    return jax.make_mesh(shape, axes,
                         axis_types=(jsh.AxisType.Auto,) * len(axes))


def make_test_mesh(n_devices: int | None = None, model: int | None = None):
    """Small mesh over whatever devices exist (selftests, examples)."""
    n = n_devices or len(jax.devices())
    model = model or n
    data = n // model
    import jax.sharding as jsh
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jsh.AxisType.Auto,) * 2)
