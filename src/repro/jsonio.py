"""JSON bytes IO with an orjson fast path and a stdlib fallback.

The container may not ship ``orjson``; all writers (checkpoint index,
dry-run records, fabric-sim results, runtime telemetry, benches) use these
helpers so the fallback lives in one place and the on-disk format stays
identical either way.

Records that cross files (fabsim results, telemetry windows, runtime trace
summaries, bench outputs) share one envelope: :func:`tag` stamps a
``schema`` field of the form ``nimble.<kind>/v<version>`` so
``experiments/make_report.py`` and the benches can consume each other's
output without per-file format knowledge.
"""

from __future__ import annotations

try:
    import orjson

    def json_dumps(obj, *, indent: bool = False) -> bytes:
        return orjson.dumps(obj, option=orjson.OPT_INDENT_2 if indent else 0)

    def json_loads(data: bytes):
        return orjson.loads(data)

except ImportError:  # stdlib fallback — same on-disk format, just slower
    import json

    def json_dumps(obj, *, indent: bool = False) -> bytes:
        return json.dumps(obj, indent=2 if indent else None).encode()

    def json_loads(data: bytes):
        return json.loads(data)


# -- shared record schema -------------------------------------------------------

import re as _re

SCHEMA_PREFIX = "nimble"

#: a well-formed kind: lowercase snake, leading letter
_KIND_RE = _re.compile(r"^[a-z][a-z0-9_]*$")

#: registry of known record kinds -> current schema version.  The static
#: schema-discipline rule (``repro.analysis``) requires every kind emitted
#: under ``src/repro`` to be registered here, and the
#: ``schemas.lock.json`` generator walks this registry alongside the
#: source scan — bumping a version in a ``tag()`` call without updating
#: this table is a lint failure *and* a runtime ValueError.
KNOWN_SCHEMAS = {
    # core / fabsim
    "simresult": 1,
    # runtime (telemetry, estimator, controller, events)
    "telemetry_window": 1,
    "telemetry_aggregate": 1,
    "telemetry_log": 1,
    "runtime_window": 1,
    "runtime_stats": 1,
    "runtime_trace": 1,
    "link_event": 1,
    # fabric
    "fabric_state": 1,
    "fabric_arbiter": 1,
    "fabric_arbiter_stats": 1,
    "fabric_fairness": 1,
    # faults
    "fault_schedule": 1,
    "fault_drill": 1,
    # serve
    "serve_scenario": 1,
    "serve": 1,
    # api
    "session": 1,
    # obs
    "trace": 1,
    "metrics": 1,
    "plan_provenance": 1,
    "provenance_log": 1,
    # analysis (ISSUE 9)
    "lint": 1,
    "lint_baseline": 1,
    "schemas_lock": 1,
    # analysis dataflow (ISSUE 10)
    "retrace": 1,
    "retrace_lock": 1,
    "units": 1,
    "callgraph": 1,
    "lint_debt": 1,
    # bench outputs (benchmarks/run.py)
    "bench_runtime_adapt": 1,
    "bench_fairness": 1,
    "bench_faults": 1,
    "bench_obs": 1,
    "bench_lint": 1,
}


def known_schemas() -> dict:
    """Copy of the kind -> current-version registry (consumed by the
    schema-discipline lint rule and the ``schemas.lock.json`` generator)."""
    return dict(KNOWN_SCHEMAS)


def parse_schema_id(schema_id: str):
    """Strictly parse ``nimble.<kind>/v<version>`` -> ``(kind, version)``.

    Rejects malformed ids — wrong prefix, bad kind spelling, missing or
    non-integer version — with a ``ValueError`` naming the offending id.
    """
    if not isinstance(schema_id, str):
        raise ValueError(f"schema id must be a string, got {schema_id!r}")
    prefix, dot, rest = schema_id.partition(".")
    if not dot or prefix != SCHEMA_PREFIX:
        raise ValueError(
            f"malformed schema id {schema_id!r}: expected prefix "
            f"'{SCHEMA_PREFIX}.'"
        )
    kind, slash, tail = rest.rpartition("/")
    if not slash:
        raise ValueError(
            f"malformed schema id {schema_id!r}: missing '/v<version>'"
        )
    if not _KIND_RE.match(kind):
        raise ValueError(
            f"malformed schema id {schema_id!r}: kind {kind!r} must match "
            f"{_KIND_RE.pattern}"
        )
    if not tail.startswith("v") or not tail[1:].isdigit():
        raise ValueError(
            f"malformed schema id {schema_id!r}: version {tail!r} must be "
            "'v<integer>'"
        )
    version = int(tail[1:])
    if version < 1:
        raise ValueError(
            f"malformed schema id {schema_id!r}: version must be >= 1"
        )
    return kind, version


def tag(kind: str, payload: dict, version: int = 1) -> dict:
    """Wrap ``payload`` in the shared record envelope.

    Adds a ``schema`` field (``nimble.<kind>/v<version>``) for consumers to
    dispatch on; ``payload`` keys are carried unchanged.  Key *order* is
    not part of the contract — file writers sort keys for diff stability.

    Strict by construction: a malformed kind or version raises, and a
    *registered* kind (:data:`KNOWN_SCHEMAS`) tagged at a version other
    than its registered one raises — version bumps go through the
    registry, never through a lone call site.
    """
    if not _KIND_RE.match(kind or ""):
        raise ValueError(
            f"malformed schema kind {kind!r}: must match {_KIND_RE.pattern}"
        )
    if not isinstance(version, int) or isinstance(version, bool) or version < 1:
        raise ValueError(
            f"malformed schema version {version!r} for kind {kind!r}: "
            "must be an integer >= 1"
        )
    registered = KNOWN_SCHEMAS.get(kind)
    if registered is not None and version != registered:
        raise ValueError(
            f"schema kind {kind!r} is registered at v{registered} but was "
            f"tagged v{version} — update repro.jsonio.KNOWN_SCHEMAS (and "
            "regenerate schemas.lock.json) to bump it"
        )
    return {"schema": f"{SCHEMA_PREFIX}.{kind}/v{version}", **payload}


def schema_kind(record: dict) -> str:
    """Extract ``<kind>`` from a tagged record ('' if untagged)."""
    schema = record.get("schema", "")
    if "." not in schema or "/" not in schema:
        return ""
    return schema.split(".", 1)[1].rsplit("/", 1)[0]


def schema_version(record: dict) -> int:
    """Extract ``<version>`` from a tagged record (0 if untagged/bad).

    Consumers that must stay comparable across PRs — the trace validator,
    ``benchmarks/run.py --compare`` — dispatch on this rather than string
    matching the whole envelope.
    """
    schema = record.get("schema", "")
    if "/" not in schema:
        return 0
    tail = schema.rsplit("/", 1)[1]
    if not tail.startswith("v"):
        return 0
    try:
        return int(tail[1:])
    except ValueError:
        return 0


def write_json_file(path: str, obj, *, indent: bool = True) -> None:
    """Serialize ``obj`` to ``path`` with sorted keys + trailing newline.

    Sorted keys keep git-tracked artifacts (bench metrics, reports) free of
    pure key-reordering churn between runs.
    """
    with open(path, "wb") as f:
        f.write(json_dumps(_sorted(obj), indent=indent))
        f.write(b"\n")


def _sorted(obj):
    """Recursively sort dict keys (orjson has no stdlib sort_keys knob for
    nested tuples-in-dataclasses, so normalize before dumping)."""
    if isinstance(obj, dict):
        return {k: _sorted(obj[k]) for k in sorted(obj)}
    if isinstance(obj, (list, tuple)):
        return [_sorted(x) for x in obj]
    return obj


def read_json_file(path: str):
    with open(path, "rb") as f:
        return json_loads(f.read())
