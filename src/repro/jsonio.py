"""JSON bytes IO with an orjson fast path and a stdlib fallback.

The container may not ship ``orjson``; all writers (checkpoint index,
dry-run records, fabric-sim results, runtime telemetry, benches) use these
helpers so the fallback lives in one place and the on-disk format stays
identical either way.

Records that cross files (fabsim results, telemetry windows, runtime trace
summaries, bench outputs) share one envelope: :func:`tag` stamps a
``schema`` field of the form ``nimble.<kind>/v<version>`` so
``experiments/make_report.py`` and the benches can consume each other's
output without per-file format knowledge.
"""

from __future__ import annotations

try:
    import orjson

    def json_dumps(obj, *, indent: bool = False) -> bytes:
        return orjson.dumps(obj, option=orjson.OPT_INDENT_2 if indent else 0)

    def json_loads(data: bytes):
        return orjson.loads(data)

except ImportError:  # stdlib fallback — same on-disk format, just slower
    import json

    def json_dumps(obj, *, indent: bool = False) -> bytes:
        return json.dumps(obj, indent=2 if indent else None).encode()

    def json_loads(data: bytes):
        return json.loads(data)


# -- shared record schema -------------------------------------------------------

SCHEMA_PREFIX = "nimble"


def tag(kind: str, payload: dict, version: int = 1) -> dict:
    """Wrap ``payload`` in the shared record envelope.

    Adds a ``schema`` field (``nimble.<kind>/v<version>``) for consumers to
    dispatch on; ``payload`` keys are carried unchanged.  Key *order* is
    not part of the contract — file writers sort keys for diff stability.
    """
    return {"schema": f"{SCHEMA_PREFIX}.{kind}/v{version}", **payload}


def schema_kind(record: dict) -> str:
    """Extract ``<kind>`` from a tagged record ('' if untagged)."""
    schema = record.get("schema", "")
    if "." not in schema or "/" not in schema:
        return ""
    return schema.split(".", 1)[1].rsplit("/", 1)[0]


def schema_version(record: dict) -> int:
    """Extract ``<version>`` from a tagged record (0 if untagged/bad).

    Consumers that must stay comparable across PRs — the trace validator,
    ``benchmarks/run.py --compare`` — dispatch on this rather than string
    matching the whole envelope.
    """
    schema = record.get("schema", "")
    if "/" not in schema:
        return 0
    tail = schema.rsplit("/", 1)[1]
    if not tail.startswith("v"):
        return 0
    try:
        return int(tail[1:])
    except ValueError:
        return 0


def write_json_file(path: str, obj, *, indent: bool = True) -> None:
    """Serialize ``obj`` to ``path`` with sorted keys + trailing newline.

    Sorted keys keep git-tracked artifacts (bench metrics, reports) free of
    pure key-reordering churn between runs.
    """
    with open(path, "wb") as f:
        f.write(json_dumps(_sorted(obj), indent=indent))
        f.write(b"\n")


def _sorted(obj):
    """Recursively sort dict keys (orjson has no stdlib sort_keys knob for
    nested tuples-in-dataclasses, so normalize before dumping)."""
    if isinstance(obj, dict):
        return {k: _sorted(obj[k]) for k in sorted(obj)}
    if isinstance(obj, (list, tuple)):
        return [_sorted(x) for x in obj]
    return obj


def read_json_file(path: str):
    with open(path, "rb") as f:
        return json_loads(f.read())
