"""JSON bytes IO with an orjson fast path and a stdlib fallback.

The container may not ship ``orjson``; both writers (checkpoint index,
dry-run records) use these helpers so the fallback lives in one place and
the on-disk format stays identical either way.
"""

from __future__ import annotations

try:
    import orjson

    def json_dumps(obj, *, indent: bool = False) -> bytes:
        return orjson.dumps(obj, option=orjson.OPT_INDENT_2 if indent else 0)

    def json_loads(data: bytes):
        return orjson.loads(data)

except ImportError:  # stdlib fallback — same on-disk format, just slower
    import json

    def json_dumps(obj, *, indent: bool = False) -> bytes:
        return json.dumps(obj, indent=2 if indent else None).encode()

    def json_loads(data: bytes):
        return json.loads(data)
