"""Chrome/Perfetto trace emission for the flight recorder (DESIGN.md §11).

The :class:`Tracer` collects typed spans and instant events — ``solve``,
``arbitrate``, ``swap``, ``replan``, ``fault``, ``scenario-window``,
``drain`` and friends — from every layer of the stack and exports them
as Chrome trace-event JSON (the object format, tagged
``nimble.trace/v1``) that loads directly in ``chrome://tracing`` or
https://ui.perfetto.dev.

Timestamps are *causal*, not wall-clock: the orchestration stack is a
windowed simulation, so the tracer keeps a monotonic microsecond
counter that every emission advances by one tick, and the serve /
runtime layers align window boundaries to 1 ms marks via
:meth:`Tracer.advance_to`.  The result renders as a per-tenant timeline
(one Perfetto track per tenant plus ``fabric`` and ``cluster`` tracks)
where ordering and nesting are exact even though durations are
synthetic.

Every event carries the recorder's correlation id in ``args["corr"]``
so multi-layer traces can be joined back to one run after the fact;
:func:`validate_trace` checks the invariants the test-suite and
selfcheck pin (sorted ``ts``, matched ``B``/``E`` pairs, properly
nested ``X`` spans per track, one correlation id).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from ..jsonio import schema_kind, schema_version, tag

TRACE_KIND = "trace"


class _Span:
    """Handle returned by :meth:`Tracer.begin`; closed by :meth:`Tracer.end`."""

    __slots__ = ("name", "cat", "tid", "args", "start", "closed")

    def __init__(self, name: str, cat: str, tid: str, args: Optional[dict],
                 start: int):
        self.name = name
        self.cat = cat
        self.tid = tid
        self.args = args
        self.start = start
        self.closed = False


class _SpanContext:
    """Context-manager sugar over ``begin``/``end``."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: _Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> _Span:
        return self._span

    def __exit__(self, *exc) -> None:
        self._tracer.end(self._span)


class Tracer:
    """Collects trace events; zero work unless methods are called.

    Disabled runs never construct one — the instrumentation sites guard
    on ``recorder is None`` so the disabled path stays bit-identical.
    """

    def __init__(self, correlation_id: str, capacity: int = 1_000_000):
        self.correlation_id = correlation_id
        self.capacity = int(capacity)
        self.dropped = 0
        self._events: List[dict] = []
        self._now = 0                      # causal microsecond clock
        self._tids: Dict[str, int] = {}    # track name -> tid int

    # -- clock ---------------------------------------------------------------

    def _tick(self) -> int:
        t = self._now
        self._now += 1
        return t

    def advance_to(self, ts_us: int) -> None:
        """Advance the causal clock to at least ``ts_us`` (never backwards)."""
        if ts_us > self._now:
            self._now = int(ts_us)

    # -- emission ------------------------------------------------------------

    def _tid(self, name: str) -> int:
        tid = self._tids.get(name)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[name] = tid
        return tid

    def _emit(self, ev: dict) -> None:
        if len(self._events) >= self.capacity:
            self.dropped += 1
            return
        self._events.append(ev)

    def instant(self, name: str, cat: str, tid: str,
                args: Optional[dict] = None) -> None:
        """Emit an instant (``i``) marker — swap/fault/admit/... points."""
        a = {"corr": self.correlation_id}
        if args:
            a.update(args)
        self._emit({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self._tick(), "pid": 1, "tid": self._tid(tid), "args": a,
        })

    def begin(self, name: str, cat: str, tid: str,
              args: Optional[dict] = None) -> _Span:
        """Open a span; close it with :meth:`end` (emits one ``X`` event)."""
        return _Span(name, cat, tid, args, self._tick())

    def end(self, span: _Span, extra_args: Optional[dict] = None) -> None:
        if span.closed:
            return
        span.closed = True
        end = self._tick()
        if end <= span.start:
            end = span.start + 1
        a = {"corr": self.correlation_id}
        if span.args:
            a.update(span.args)
        if extra_args:
            a.update(extra_args)
        self._emit({
            "name": span.name, "cat": span.cat, "ph": "X",
            "ts": span.start, "dur": end - span.start,
            "pid": 1, "tid": self._tid(span.tid), "args": a,
        })

    def span(self, name: str, cat: str, tid: str,
             args: Optional[dict] = None) -> _SpanContext:
        """``with tracer.span(...):`` — begin/end as a context manager."""
        return _SpanContext(self, self.begin(name, cat, tid, args))

    # -- export --------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._events)

    def export(self) -> dict:
        """Chrome trace-event JSON (object format), tagged ``nimble.trace/v1``.

        Events are sorted by ``ts`` (emission order breaks ties) — the
        sortedness is part of the schema contract and is pinned by
        :func:`validate_trace`.
        """
        meta = [{
            "name": "process_name", "ph": "M", "pid": 1, "ts": 0,
            "args": {"name": f"nimble:{self.correlation_id}"},
        }]
        for track, tid in self._tids.items():
            meta.append({
                "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
                "ts": 0, "args": {"name": track},
            })
        events = meta + sorted(
            self._events, key=lambda e: e["ts"]
        )
        return tag(TRACE_KIND, {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "correlation_id": self.correlation_id,
                "dropped": self.dropped,
            },
        })


def validate_trace(record: dict) -> dict:
    """Validate a ``nimble.trace/v1`` export; raise ``ValueError`` on the
    first violated invariant, return a summary dict on success.

    Checks: schema tag; ``traceEvents`` sorted by ``ts``; every ``X``
    event carries a non-negative ``dur``; ``B``/``E`` pairs match per
    track; ``X`` spans nest properly per track; all non-metadata events
    carry the same correlation id.
    """
    if schema_kind(record) != TRACE_KIND or schema_version(record) != 1:
        raise ValueError(
            f"not a nimble.trace/v1 record: {record.get('schema')!r}"
        )
    events = record.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("trace has no traceEvents")
    corr = None
    last_ts = None
    open_be: Dict[Tuple[int, int], list] = {}     # (pid, tid) -> B stack
    open_x: Dict[Tuple[int, int], list] = {}      # (pid, tid) -> [end ts]
    n_spans = 0
    cats = set()
    for ev in events:
        ph = ev.get("ph")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, int) or ts < 0:
            raise ValueError(f"event {ev.get('name')!r} has bad ts {ts!r}")
        if last_ts is not None and ts < last_ts:
            raise ValueError(
                f"traceEvents not sorted: ts {ts} after {last_ts}"
            )
        last_ts = ts
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, int) or dur < 0:
                raise ValueError(
                    f"X event {ev.get('name')!r} has bad dur {dur!r}"
                )
            stack = open_x.setdefault(key, [])
            while stack and ts >= stack[-1]:
                stack.pop()
            if stack and ts + dur > stack[-1]:
                raise ValueError(
                    f"X event {ev.get('name')!r} at ts={ts} dur={dur} "
                    f"overlaps its enclosing span (ends {stack[-1]}) on "
                    f"track {key}"
                )
            stack.append(ts + dur)
            n_spans += 1
        elif ph == "B":
            open_be.setdefault(key, []).append(ev.get("name"))
            n_spans += 1
        elif ph == "E":
            stack = open_be.get(key)
            if not stack:
                raise ValueError(
                    f"E event on track {key} with no open B span"
                )
            stack.pop()
        elif ph not in ("i", "I", "C"):
            raise ValueError(f"unsupported event phase {ph!r}")
        ev_corr = (ev.get("args") or {}).get("corr")
        if ev_corr is None:
            raise ValueError(
                f"event {ev.get('name')!r} missing args.corr"
            )
        if corr is None:
            corr = ev_corr
        elif ev_corr != corr:
            raise ValueError(
                f"mixed correlation ids: {corr!r} vs {ev_corr!r}"
            )
        cats.add(ev.get("cat"))
    for key, stack in open_be.items():
        if stack:
            raise ValueError(
                f"unmatched B event(s) {stack!r} on track {key}"
            )
    return {
        "events": sum(1 for e in events if e.get("ph") != "M"),
        "spans": n_spans,
        "cats": sorted(c for c in cats if c),
        "correlation_id": corr,
    }
