"""``repro.obs`` — the flight recorder (DESIGN.md §11).

One :class:`FlightRecorder` bundles the three observability surfaces:

* :class:`~repro.obs.trace.Tracer` — typed spans/events exported as
  Chrome/Perfetto trace JSON (``nimble.trace/v1``);
* :class:`~repro.obs.metrics.MetricsRegistry` — counters / gauges /
  histograms snapshot as ``nimble.metrics/v1``;
* :class:`~repro.obs.provenance.ProvenanceLog` — a plan-provenance
  audit trail queryable after the run.

Attach one recorder at the top (``Session(spec, recorder=rec)`` or
``ControlPlane(spec, mode, recorder=rec)``) and every layer below —
runtime, fabric arbiter, planner solves — records into it under one
correlation id.  The instrumentation sites are duck-typed and guarded
by a single ``is None`` check, so a run without a recorder executes the
exact same instructions as before this module existed (pinned by the
``obs`` test suite and the ``obs_overhead`` smoke gate).
"""

from __future__ import annotations

import itertools

from .metrics import (
    MetricsRegistry,
    collect_arbiter,
    collect_runtime,
    collect_session,
)
from .provenance import PlanProvenance, ProvenanceLog, price_summary
from .trace import Tracer, validate_trace

_CORR_COUNTER = itertools.count(1)


class FlightRecorder:
    """Tracer + metrics + provenance under one correlation id."""

    def __init__(self, correlation_id: str | None = None, *,
                 enabled: bool = True, trace_capacity: int = 1_000_000):
        if correlation_id is None:
            correlation_id = f"nimble-{next(_CORR_COUNTER)}"
        self.correlation_id = correlation_id
        self.enabled = bool(enabled)
        self.tracer = Tracer(correlation_id, capacity=trace_capacity)
        self.metrics = MetricsRegistry()
        self.provenance = ProvenanceLog()

    @classmethod
    def disabled(cls) -> "FlightRecorder":
        """A recorder every instrumentation site treats as absent."""
        return cls("disabled", enabled=False)

    def export_trace(self) -> dict:
        """``nimble.trace/v1`` Chrome trace JSON of everything recorded."""
        return self.tracer.export()

    def metrics_snapshot(self) -> dict:
        """``nimble.metrics/v1`` snapshot of the registry."""
        return self.metrics.snapshot()

    def __repr__(self) -> str:
        return (
            f"FlightRecorder({self.correlation_id!r}, "
            f"enabled={self.enabled}, events={len(self.tracer)}, "
            f"metrics={len(self.metrics)}, plans={len(self.provenance)})"
        )


__all__ = [
    "FlightRecorder",
    "MetricsRegistry",
    "PlanProvenance",
    "ProvenanceLog",
    "Tracer",
    "collect_arbiter",
    "collect_runtime",
    "collect_session",
    "price_summary",
    "validate_trace",
]
