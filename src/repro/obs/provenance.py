"""Plan-provenance audit trail (DESIGN.md §11).

Every plan the runtime issues gets a :class:`PlanProvenance` record in
the recorder's :class:`ProvenanceLog`: who asked for it (trigger
reason), what demand it solved (signature hash + totals), whether the
plan cache hit, the congestion prices at issue vs. at swap, the solver
source (``solve`` / ``cache`` / ``reprice`` / ``watchdog`` / initial),
and the fault context active when it was issued.  The record outlives
the Session that produced it — retired tenants' plans stay queryable —
so "why did tenant B swap at window 17?" is answerable after the run.

Records are mutated in place as the plan moves through its lifecycle
(`issue` → `mark_ready` → `mark_swapped` | `mark_abandoned`); the
runtime holds the record on ``PlanHandle.provenance`` and the log keeps
the authoritative ordered list.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional, Tuple

from ..jsonio import tag

PROVENANCE_KIND = "plan_provenance"
PROVENANCE_LOG_KIND = "provenance_log"


def signature_hash(signature) -> str:
    """Stable short hash of a plan-cache demand signature."""
    return hashlib.sha1(repr(signature).encode()).hexdigest()[:12]


def price_summary(prices) -> Optional[dict]:
    """Compact JSON summary of a congestion-price vector (or None)."""
    if prices is None:
        return None
    import numpy as np

    arr = np.asarray(prices, dtype=float).ravel()
    if arr.size == 0:
        return {"links": 0, "max": 0.0, "mean": 0.0, "nonzero": 0}
    return {
        "links": int(arr.size),
        "max": float(arr.max()),
        "mean": float(arr.mean()),
        "nonzero": int(np.count_nonzero(arr)),
    }


@dataclasses.dataclass
class PlanProvenance:
    """Audit record for one issued plan (see module docstring)."""

    tenant: str
    version: int
    source: str                 # solve | cache | reprice | watchdog | initial
    trigger: str                # replan reason (congestion/topology/...) or
                                # "initial" for the construction-time plan
    cache_hit: bool
    issued_window: int
    signature: str              # short demand-signature hash
    demand_bytes: float
    baseline_ratio: float
    planner: dict               # solver-parameter fingerprint
    prices_at_issue: Optional[dict] = None
    repriced: bool = False
    ready_window: Optional[int] = None
    swapped_window: Optional[int] = None
    prices_at_swap: Optional[dict] = None
    reprice_rel_change: Optional[float] = None
    abandoned: bool = False
    fault_context: Tuple[str, ...] = ()

    @property
    def swapped(self) -> bool:
        return self.swapped_window is not None

    def mark_ready(self, window: int) -> None:
        self.ready_window = int(window)

    def mark_swapped(self, window: int, prices=None,
                     rel_change: Optional[float] = None,
                     repriced: bool = False) -> None:
        self.swapped_window = int(window)
        self.prices_at_swap = price_summary(prices)
        if rel_change is not None:
            self.reprice_rel_change = float(rel_change)
        if repriced:
            self.repriced = True

    def mark_abandoned(self) -> None:
        self.abandoned = True

    def to_json_obj(self) -> dict:
        return tag(PROVENANCE_KIND, dataclasses.asdict(self))


class ProvenanceLog:
    """Ordered, queryable log of every plan issued under one recorder."""

    def __init__(self):
        self._records: List[PlanProvenance] = []

    def issue(self, *, tenant: str, version: int, source: str, trigger: str,
              cache_hit: bool, issued_window: int, signature,
              demand_bytes: float, baseline_ratio: float, planner: dict,
              prices=None, repriced: bool = False,
              fault_context: Tuple[str, ...] = ()) -> PlanProvenance:
        rec = PlanProvenance(
            tenant=tenant,
            version=int(version),
            source=source,
            trigger=trigger,
            cache_hit=bool(cache_hit),
            issued_window=int(issued_window),
            signature=signature_hash(signature),
            demand_bytes=float(demand_bytes),
            baseline_ratio=float(baseline_ratio),
            planner=dict(planner),
            prices_at_issue=price_summary(prices),
            repriced=bool(repriced),
            fault_context=tuple(fault_context),
        )
        self._records.append(rec)
        return rec

    def __len__(self) -> int:
        return len(self._records)

    def __iter__(self):
        return iter(self._records)

    def records(self) -> List[PlanProvenance]:
        return list(self._records)

    def for_tenant(self, tenant: str) -> List[PlanProvenance]:
        return [r for r in self._records if r.tenant == tenant]

    def swapped(self) -> List[PlanProvenance]:
        return [r for r in self._records if r.swapped]

    def find(self, tenant: Optional[str] = None,
             version: Optional[int] = None) -> List[PlanProvenance]:
        out = self._records
        if tenant is not None:
            out = [r for r in out if r.tenant == tenant]
        if version is not None:
            out = [r for r in out if r.version == version]
        return list(out)

    def to_json_obj(self) -> dict:
        return tag(PROVENANCE_LOG_KIND, {
            "records": [dataclasses.asdict(r) for r in self._records],
        })
