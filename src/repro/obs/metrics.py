"""Metrics registry for the flight recorder (DESIGN.md §11).

One process-wide bag of named counters / gauges / histograms with
labels, snapshot as a ``nimble.metrics/v1`` record.  The registry
absorbs the health signals that previously lived in scattered stats
objects — ``RuntimeStats.reprices``, ``ArbiterStats.evictions``, gated
windows, telemetry ``rejected`` counters, estimator ``confidence`` —
into one scrapeable schema embedded in ``Session.report()`` and the
``nimble.serve/v1`` record.

Naming convention (pinned in DESIGN.md §11): ``nimble_<layer>_<name>``
with ``_total`` suffix for monotonic counts, snake-case labels
(``tenant``, ``scenario``, ``mode``).  Snapshots are deterministic
(sorted by name then labels) and JSON-native, so they round-trip
bit-exact through :mod:`repro.jsonio`.

The collectors at the bottom (:func:`collect_runtime`,
:func:`collect_arbiter`) are pull-based: they duck-type over live
runtime / arbiter objects at snapshot time, so the hot per-window path
pays nothing for them.
"""

from __future__ import annotations

import re
from typing import Dict, Optional, Sequence, Tuple

from ..jsonio import tag

METRICS_KIND = "metrics"

_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")

#: Default histogram bucket upper bounds (seconds-ish, log-spaced).
DEFAULT_BUCKETS = (
    0.0001, 0.0003, 0.001, 0.003, 0.01, 0.03, 0.1, 0.3, 1.0, 3.0, 10.0,
)

_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[dict]) -> _LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic count.  ``inc`` rejects negative increments."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self.value += v


class Gauge:
    """Last-write-wins value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Cumulative-bucket histogram with explicit upper bounds."""

    __slots__ = ("bounds", "counts", "total", "count", "vmin", "vmax")

    def __init__(self, bounds: Sequence[float] = DEFAULT_BUCKETS):
        b = tuple(float(x) for x in bounds)
        if list(b) != sorted(b) or len(set(b)) != len(b):
            raise ValueError(f"histogram bounds must be sorted unique: {b}")
        self.bounds = b
        self.counts = [0] * (len(b) + 1)   # last bucket = +inf
        self.total = 0.0
        self.count = 0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for bound in self.bounds:
            if v <= bound:
                break
            i += 1
        self.counts[i] += 1
        self.total += v
        self.count += 1
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)


class MetricsRegistry:
    """Named metrics with labels; deterministic JSON snapshots."""

    def __init__(self):
        # (name, label_key) -> (type, instrument)
        self._metrics: Dict[Tuple[str, _LabelKey], Tuple[str, object]] = {}

    def _get(self, kind: str, name: str, labels: Optional[dict],
             factory) -> object:
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        key = (name, _label_key(labels))
        hit = self._metrics.get(key)
        if hit is not None:
            if hit[0] != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {hit[0]}, "
                    f"requested {kind}"
                )
            return hit[1]
        inst = factory()
        self._metrics[key] = (kind, inst)
        return inst

    def counter(self, name: str, labels: Optional[dict] = None) -> Counter:
        return self._metrics_typed("counter", name, labels, Counter)

    def gauge(self, name: str, labels: Optional[dict] = None) -> Gauge:
        return self._metrics_typed("gauge", name, labels, Gauge)

    def histogram(self, name: str, labels: Optional[dict] = None,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        factory = (lambda: Histogram(buckets)) if buckets else Histogram
        return self._metrics_typed("histogram", name, labels, factory)

    def _metrics_typed(self, kind, name, labels, factory):
        return self._get(kind, name, labels, factory)

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> dict:
        """All metrics as one ``nimble.metrics/v1`` record (sorted)."""
        out = []
        for (name, lkey), (kind, inst) in sorted(self._metrics.items()):
            rec = {"name": name, "type": kind, "labels": dict(lkey)}
            if kind == "histogram":
                h = inst
                rec.update({
                    "count": h.count,
                    "sum": h.total,
                    "min": h.vmin,
                    "max": h.vmax,
                    "buckets": [
                        [b, c] for b, c in zip(
                            list(h.bounds) + ["+inf"], h.counts
                        )
                    ],
                })
            else:
                rec["value"] = inst.value
            out.append(rec)
        return tag(METRICS_KIND, {"metrics": out})


# -- pull-based collectors --------------------------------------------------
#
# Duck-typed over the live objects so repro.obs never imports the runtime
# or fabric layers (no cycles); called at snapshot/report time only.

def collect_runtime(reg: MetricsRegistry, runtime,
                    tenant: str = "default") -> None:
    """Absorb OrchestrationRuntime stats + estimator/telemetry health."""
    labels = {"tenant": tenant}
    s = runtime.stats

    def g(name: str, value) -> None:
        reg.gauge(name, labels).set(float(value))

    g("nimble_runtime_windows_total", s.windows)
    g("nimble_runtime_replans_total", s.replans)
    g("nimble_runtime_solves_total", s.solves)
    g("nimble_runtime_cache_hits_total", s.cache_hits)
    g("nimble_runtime_swaps_total", s.swaps)
    g("nimble_runtime_fault_events_total", s.events)
    g("nimble_runtime_reprices_total", s.reprices)
    g("nimble_runtime_watchdog_abandons_total", s.watchdog_abandons)
    g("nimble_runtime_gated_windows_total", getattr(s, "gated", 0))
    g("nimble_estimator_confidence", runtime.estimator.confidence)
    g("nimble_estimator_missing_windows_total",
      runtime.estimator.missing_windows)
    health = runtime.telemetry.health()
    g("nimble_telemetry_windows_total", health["windows"])
    g("nimble_telemetry_rejected_records_total", health["rejected"])
    g("nimble_telemetry_utilization_imbalance",
      health["utilization_imbalance"])
    pol = runtime.policy.state_snapshot()
    g("nimble_policy_armed", int(pol["armed"]))
    g("nimble_policy_breach_windows", pol["breach"])
    g("nimble_policy_flap_level", pol["flap_level"])
    g("nimble_plan_version", runtime.active_version)


def collect_arbiter(reg: MetricsRegistry, arbiter) -> None:
    """Absorb FabricArbiter stats + per-tenant ledger staleness."""
    s = arbiter.stats

    def g(name: str, value, labels: Optional[dict] = None) -> None:
        reg.gauge(name, labels).set(float(value))

    g("nimble_fabric_solves_total", s.solves)
    g("nimble_fabric_sweeps_total", s.sweeps)
    g("nimble_fabric_admitted_total", s.admitted)
    g("nimble_fabric_throttled_total", s.throttled)
    g("nimble_fabric_commits_total", s.commits)
    g("nimble_fabric_price_hints_total", s.price_hints)
    g("nimble_fabric_reprices_total", s.reprices)
    g("nimble_fabric_evictions_total", s.evictions)
    g("nimble_fabric_tenants", len(arbiter.tenants()))
    summary = arbiter.state.summary()
    g("nimble_fabric_clock", summary["clock"])
    g("nimble_fabric_combined_drain_s", summary["combined_drain_s"])
    for tenant, stale in summary["staleness"].items():
        g("nimble_fabric_ledger_staleness", stale, {"tenant": tenant})


def collect_session(reg: MetricsRegistry, session) -> None:
    """One call per Session — runtime (if adaptive) + arbiter (if priced)."""
    runtime = getattr(session, "runtime", None)
    if runtime is not None:
        collect_runtime(reg, runtime, tenant=session.spec.tenant)
    arbiter = getattr(session, "arbiter", None)
    if arbiter is not None:
        collect_arbiter(reg, arbiter)
