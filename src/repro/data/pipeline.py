"""Data pipeline: deterministic synthetic LM stream + host-sharded loading.

Synthetic corpus generator produces a stationary Zipf-ish token process with
local n-gram structure (so losses decrease measurably during the example
training runs), deterministic in (seed, step) — every host computes its own
shard without coordination, the standard TPU pattern.

Skew control: ``expert_hotspot`` biases token ids so a learned-router MoE
sees skewed expert traffic — used by the benchmarks to reproduce the
paper's hotspot-ratio sweeps end-to-end.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1        # host shards
    shard: int = 0
    zipf_a: float = 1.2
    ngram_repeat: float = 0.3   # P(copy a recent token) — learnable structure


class SyntheticLM:
    """Deterministic, shardable synthetic LM batches."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_shards:
            raise ValueError("global_batch must divide by n_shards")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_shards
        # stationary Zipf token distribution
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / np.power(ranks, cfg.zipf_a)
        self._p = p / p.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.shard])
        )
        B, S = self.local_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab, size=(B, S + 1), p=self._p)
        # inject learnable bigram structure: with prob ngram_repeat, token
        # t+1 = f(token t) for a fixed random permutation f.
        perm_rng = np.random.default_rng(cfg.seed)  # fixed across steps
        f = perm_rng.permutation(cfg.vocab)
        copy = rng.random((B, S)) < cfg.ngram_repeat
        # apply sequentially so chained copies still satisfy t+1 = f(t) on
        # the FINAL sequence (vectorised-over-batch, loop over positions).
        for t in range(S):
            toks[:, t + 1] = np.where(copy[:, t], f[toks[:, t]], toks[:, t + 1])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def add_modality_stubs(batch: Dict[str, np.ndarray], cfg, rng_seed: int = 0
                       ) -> Dict[str, np.ndarray]:
    """Attach stub frame/patch embeddings for audio/vlm archs (carve-out)."""
    rng = np.random.default_rng(rng_seed)
    B = batch["tokens"].shape[0]
    if cfg.arch_type == "audio":
        batch = dict(batch)
        batch["frames"] = rng.normal(
            size=(B, cfg.n_audio_frames, cfg.d_model)
        ).astype(np.float32)
        # whisper decoder max target length
        batch["tokens"] = batch["tokens"][:, :448]
        batch["labels"] = batch["labels"][:, :448]
    if cfg.arch_type == "vlm":
        batch = dict(batch)
        batch["patches"] = rng.normal(
            size=(B, cfg.n_patches, cfg.d_model)
        ).astype(np.float32)
    return batch
