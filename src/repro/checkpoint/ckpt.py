"""Sharded checkpointing: npz payload shards + orjson index.

Layout:
    <dir>/step_<N>/index.json      — tree structure, dtypes, shapes, shard map
    <dir>/step_<N>/shard_<k>.npz   — flat arrays owned by host shard k

Arrays are flattened with stable path keys (``a/b/0/c``); restore rebuilds
the exact pytree (dicts, lists, tuples, OptState namedtuples survive via a
structure descriptor).  Multi-host: each host writes the arrays it owns
(here: single host writes shard 0; the shard field keeps the format
forward-compatible with per-host saving).
"""

from __future__ import annotations

import os
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.jsonio import json_dumps as _json_dumps, json_loads as _json_loads


def _flatten(tree, prefix="") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out += _flatten(tree[k], f"{prefix}{k}/")
        return out
    if isinstance(tree, (list, tuple)):
        out = []
        for i, v in enumerate(tree):
            out += _flatten(v, f"{prefix}{i}/")
        return out
    return [(prefix.rstrip("/"), tree)]


def _structure(tree) -> Any:
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _structure(v) for k, v in tree.items()}}
    if hasattr(tree, "_fields"):  # namedtuple
        return {"__kind__": "namedtuple", "name": type(tree).__name__,
                "items": [_structure(v) for v in tree]}
    if isinstance(tree, tuple):
        return {"__kind__": "tuple", "items": [_structure(v) for v in tree]}
    if isinstance(tree, list):
        return {"__kind__": "list", "items": [_structure(v) for v in tree]}
    return {"__kind__": "leaf"}


def _rebuild(struct, leaves: List[Any], namedtuple_types: Dict[str, Any]):
    kind = struct["__kind__"]
    if kind == "leaf":
        return leaves.pop(0)
    if kind == "dict":
        return {k: _rebuild(v, leaves, namedtuple_types)
                for k, v in sorted(struct["items"].items())}
    items = [_rebuild(v, leaves, namedtuple_types) for v in struct["items"]]
    if kind == "namedtuple":
        t = namedtuple_types.get(struct["name"])
        return t(*items) if t else tuple(items)
    return tuple(items) if kind == "tuple" else items


def save(path: str, step: int, tree, shard: int = 0) -> str:
    d = os.path.join(path, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat}
    np.savez(os.path.join(d, f"shard_{shard}.npz"), **arrays)
    index = {
        "step": step,
        "structure": _structure(tree),
        "keys": [k for k, _ in flat],
        "meta": {
            k: {"shape": list(a.shape), "dtype": str(a.dtype), "shard": shard}
            for k, a in arrays.items()
        },
    }
    with open(os.path.join(d, "index.json"), "wb") as f:
        f.write(_json_dumps(index))
    return d


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(n.split("_")[1]) for n in os.listdir(path)
             if n.startswith("step_")]
    return max(steps) if steps else None


def restore(path: str, step: int | None = None,
            namedtuple_types: Dict[str, Any] | None = None):
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "index.json"), "rb") as f:
        index = _json_loads(f.read())
    shards = {}
    for m in index["meta"].values():
        s = m["shard"]
        if s not in shards:
            shards[s] = np.load(os.path.join(d, f"shard_{s}.npz"))
    leaves = [jnp.asarray(shards[index["meta"][k]["shard"]][k])
              for k in index["keys"]]
    tree = _rebuild(index["structure"], leaves, namedtuple_types or {})
    return tree, step
