"""NIMBLE-aware static invariant checker (DESIGN.md §12).

The repo's core contract — "preserves ordering, determinism, and low
overhead" — is re-stated as *conventions* in many places: jit entry
points must stay retrace-free, ``core``/``fabric``/``faults`` must stay
seed-deterministic, every cross-file record carries a frozen
``nimble.<kind>/vN`` schema, frozen specs stay frozen, and NaN is a
telemetry sentinel that must never meet ``==``.  Runtime tests catch
violations after the fact; this package catches them before: an
AST-based lint engine (stdlib ``ast``, no new deps) with

  * a :class:`~repro.analysis.engine.Rule` protocol + registry
    (:data:`RULES`) of repo-specific rules (``jit-purity``,
    ``determinism``, ``schema-discipline``, ``frozen-spec``,
    ``float-eq``, plus ``suppression`` hygiene);
  * a shared per-file resolution context
    (:class:`~repro.analysis.context.FileContext`): import/alias
    resolution, decorator chains, frozen-dataclass detection, known jit
    entry points and ``lax.scan`` bodies;
  * inline suppressions — ``# nimble: ignore[<rule-id>] -- reason`` —
    with a mandatory written justification;
  * a committed baseline (``baseline.json``) for grandfathered findings
    (ships empty for ``src/``);
  * a generated ``schemas.lock.json`` key manifest the schema rule
    checks emitted records against (regenerate with ``--write-lock``);
  * a ``nimble.lint/v1`` JSON report through :mod:`repro.jsonio`.

CLI::

    python -m repro.analysis                 # lint src/repro, exit != 0 on findings
    python -m repro.analysis --json report.json
    python -m repro.analysis --write-lock    # regenerate schemas.lock.json
    python -m repro.analysis --check-lock    # lock freshness (no-op regen?)

Gating: ``python -m repro.api.selfcheck`` check 8 and the
``static_gate`` in ``benchmarks/run.py --smoke`` both fail closed on any
non-baselined finding or a stale lock.
"""

from __future__ import annotations

from .context import FileContext, build_context
from .engine import (
    AnalysisEngine,
    AnalysisReport,
    Finding,
    Rule,
    analyze_paths,
    analyze_source,
    default_baseline_path,
    default_lock_path,
    load_baseline,
)
from .rules import RULES, generate_schema_lock
from .schemas import lock_is_fresh

__all__ = [
    "AnalysisEngine",
    "AnalysisReport",
    "FileContext",
    "Finding",
    "RULES",
    "Rule",
    "analyze_paths",
    "analyze_source",
    "build_context",
    "default_baseline_path",
    "default_lock_path",
    "generate_schema_lock",
    "load_baseline",
    "lock_is_fresh",
]
