"""NIMBLE-aware static invariant checker (DESIGN.md §12).

The repo's core contract — "preserves ordering, determinism, and low
overhead" — is re-stated as *conventions* in many places: jit entry
points must stay retrace-free, ``core``/``fabric``/``faults`` must stay
seed-deterministic, every cross-file record carries a frozen
``nimble.<kind>/vN`` schema, frozen specs stay frozen, and NaN is a
telemetry sentinel that must never meet ``==``.  Runtime tests catch
violations after the fact; this package catches them before: an
AST-based lint engine (stdlib ``ast``, no new deps) with

  * a :class:`~repro.analysis.engine.Rule` protocol + registry
    (:data:`RULES`) of repo-specific rules (``jit-purity``,
    ``determinism``, ``schema-discipline``, ``frozen-spec``,
    ``float-eq``, plus ``suppression`` and ``baseline`` hygiene);
  * a shared per-file resolution context
    (:class:`~repro.analysis.context.FileContext`): import/alias
    resolution, decorator chains, frozen-dataclass detection, known jit
    entry points and ``lax.scan`` bodies;
  * a whole-program layer (:mod:`repro.analysis.callgraph`) — cached
    per-function summaries + call graph — driving three
    *interprocedural* rules (DESIGN.md §12.2): ``retrace-provenance``
    (the {TOPOLOGY_STABLE, WINDOW_DEPENDENT, PLAN_DEPENDENT} lattice
    over every jit/scan/pallas trace boundary, inventoried as
    ``nimble.retrace/v1`` and pinned by ``retrace.lock.json``),
    ``units`` (bytes | bytes_per_s | fraction | price | windows mixing),
    and ``xmodule-determinism`` (hash order flowing across calls);
  * inline suppressions — ``# nimble: ignore[<rule-id>] -- reason`` —
    with a mandatory written justification;
  * a committed baseline (``baseline.json``) for grandfathered findings
    (ships empty for ``src/``; stale or reasonless entries are
    themselves findings, and ``--debt`` prints the full ledger);
  * a generated ``schemas.lock.json`` key manifest the schema rule
    checks emitted records against (regenerate with ``--write-lock``);
  * a ``nimble.lint/v1`` JSON report through :mod:`repro.jsonio`.

CLI::

    python -m repro.analysis                 # lint src/repro, exit != 0 on findings
    python -m repro.analysis --json report.json
    python -m repro.analysis --write-lock    # regenerate both locks + cache
    python -m repro.analysis --check-lock    # lock freshness (no-op regen?)
    python -m repro.analysis --debt          # suppression/baseline ledger
    python -m repro.analysis --retrace-out - # nimble.retrace/v1 inventory

Gating: ``python -m repro.api.selfcheck`` check 8 and the
``static_gate`` in ``benchmarks/run.py --smoke`` both fail closed on any
non-baselined finding or a stale lock.
"""

from __future__ import annotations

from .callgraph import (
    CallGraph,
    FunctionSummary,
    Program,
    SummaryCache,
    build_program,
)
from .context import FileContext, build_context
from .engine import (
    AnalysisEngine,
    AnalysisReport,
    Finding,
    Rule,
    analyze_paths,
    analyze_source,
    analyze_sources,
    collect_debt,
    default_baseline_path,
    default_lock_path,
    load_baseline,
)
from .provenance import (
    PLAN_DEPENDENT,
    TOPOLOGY_STABLE,
    WINDOW_DEPENDENT,
    analyze_program,
    build_retrace_inventory,
    default_retrace_lock_path,
    retrace_lock_is_fresh,
)
from .rules import RULES, generate_schema_lock
from .schemas import lock_is_fresh
from .units import analyze_units, build_units_inventory

__all__ = [
    "AnalysisEngine",
    "AnalysisReport",
    "CallGraph",
    "FileContext",
    "Finding",
    "FunctionSummary",
    "PLAN_DEPENDENT",
    "Program",
    "RULES",
    "Rule",
    "SummaryCache",
    "TOPOLOGY_STABLE",
    "WINDOW_DEPENDENT",
    "analyze_paths",
    "analyze_program",
    "analyze_source",
    "analyze_sources",
    "analyze_units",
    "build_context",
    "build_program",
    "build_retrace_inventory",
    "build_units_inventory",
    "collect_debt",
    "default_baseline_path",
    "default_lock_path",
    "default_retrace_lock_path",
    "generate_schema_lock",
    "load_baseline",
    "lock_is_fresh",
    "retrace_lock_is_fresh",
]
