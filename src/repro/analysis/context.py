"""Shared per-file resolution context for the lint rules.

One parse + one resolution pass per file, consumed by every rule:

  * **import/alias resolution** — ``import numpy as np`` makes
    ``np.random.rand`` resolve to ``numpy.random.rand``; relative
    imports (``from ..jsonio import tag``) canonicalize against the
    file's package so ``tag(...)`` resolves to ``repro.jsonio.tag``;
  * **module-level string constants** — ``TRACE_KIND = "trace"`` lets
    the schema rule see through ``tag(TRACE_KIND, ...)``;
  * **dataclass detection** — which classes are ``@dataclasses.dataclass``
    (and which are ``frozen=True``), their field names/default nodes, so
    the frozen-spec rule and ``dataclasses.asdict(self)`` key inference
    work without executing anything;
  * **jit entry points** — functions decorated ``@jax.jit`` /
    ``@functools.partial(jax.jit, static_argnums=...)`` (static params
    resolved to names), plus ``lax.scan`` / ``pallas_call`` body
    functions and lambdas, so the jit-purity rule knows which bodies are
    traced;
  * **parent links** — every node knows its enclosing function/class.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

#: decorator spellings that mark a traced jit entry point
_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}
#: call targets whose first function argument is a traced body
_TRACED_CALLS = {"jax.lax.scan": "scan", "lax.scan": "scan"}
_TRACED_CALL_SUFFIXES = {"pallas_call": "pallas"}
_DATACLASS_NAMES = {"dataclasses.dataclass", "dataclass"}


@dataclasses.dataclass
class DataclassInfo:
    """A ``@dataclass`` class found in the file."""

    node: ast.ClassDef
    frozen: bool
    # field name -> default expression node (None when no default)
    fields: Dict[str, Optional[ast.expr]]

    @property
    def name(self) -> str:
        return self.node.name


@dataclasses.dataclass
class JitFunctionInfo:
    """A function whose body is traced (jit entry point or scan body)."""

    node: ast.AST                  # FunctionDef or Lambda
    kind: str                      # "jit" | "scan" | "pallas"
    static_params: Set[str]        # params marked static (never traced)

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")


class FileContext:
    """Everything the rules need to know about one parsed file."""

    def __init__(self, path: str, source: str, package: str = ""):
        self.path = path
        self.source = source
        self.package = package
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        self.aliases = self._collect_imports()
        self.constants = self._collect_constants()
        self.dataclasses = self._collect_dataclasses()
        self.jit_functions = self._collect_jit_functions()
        self._jit_nodes = {info.node: info for info in self.jit_functions}

    # -- imports ---------------------------------------------------------------
    def _collect_imports(self) -> Dict[str, str]:
        aliases: Dict[str, str] = {}
        pkg_parts = self.package.split(".") if self.package else []
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    # ``import a.b`` binds ``a``; ``import a.b as c`` binds c
                    if a.asname:
                        aliases[a.asname] = a.name
                    else:
                        root = a.name.split(".")[0]
                        aliases.setdefault(root, root)
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if node.level:
                    # relative: resolve against this file's package
                    base = pkg_parts[: len(pkg_parts) - (node.level - 1)]
                    mod = ".".join(base + ([mod] if mod else []))
                for a in node.names:
                    if a.name == "*":
                        continue
                    bound = a.asname or a.name
                    aliases[bound] = f"{mod}.{a.name}" if mod else a.name
        return aliases

    def _collect_constants(self) -> Dict[str, str]:
        """Module-level ``NAME = "literal"`` string constants."""
        out: Dict[str, str] = {}
        for node in self.tree.body:
            if (
                isinstance(node, ast.Assign)
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, str)
            ):
                out[node.targets[0].id] = node.value.value
        return out

    # -- name resolution -------------------------------------------------------
    def resolve(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted name for a Name/Attribute chain (else None).

        ``np.random.rand`` -> ``numpy.random.rand`` given
        ``import numpy as np``; bare builtins resolve to themselves.
        """
        parts: List[str] = []
        cur = node
        while isinstance(cur, ast.Attribute):
            parts.append(cur.attr)
            cur = cur.value
        if not isinstance(cur, ast.Name):
            return None
        root = self.aliases.get(cur.id, cur.id)
        parts.append(root)
        return ".".join(reversed(parts))

    def string_value(self, node: ast.AST) -> Optional[str]:
        """Constant string value of a node, seeing through module constants."""
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return node.value
        if isinstance(node, ast.Name):
            return self.constants.get(node.id)
        return None

    # -- dataclasses -----------------------------------------------------------
    def _collect_dataclasses(self) -> Dict[str, DataclassInfo]:
        out: Dict[str, DataclassInfo] = {}
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            frozen = None
            for dec in node.decorator_list:
                target, kws = self._decorator_call(dec)
                if target in _DATACLASS_NAMES:
                    frozen = any(
                        kw.arg == "frozen"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True
                        for kw in kws
                    )
            if frozen is None:
                continue
            fields: Dict[str, Optional[ast.expr]] = {}
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) and isinstance(
                    stmt.target, ast.Name
                ):
                    if isinstance(stmt.annotation, ast.Name) and (
                        stmt.annotation.id == "ClassVar"
                    ):
                        continue
                    fields[stmt.target.id] = stmt.value
            out[node.name] = DataclassInfo(node, frozen, fields)
        return out

    def _decorator_call(
        self, dec: ast.AST
    ) -> Tuple[Optional[str], List[ast.keyword]]:
        """(resolved target, keywords) of a decorator, Call or bare."""
        if isinstance(dec, ast.Call):
            return self.resolve(dec.func), dec.keywords
        return self.resolve(dec), []

    # -- jit entry points ------------------------------------------------------
    def _collect_jit_functions(self) -> List[JitFunctionInfo]:
        out: List[JitFunctionInfo] = []
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                info = self._jit_decorated(node)
                if info is not None:
                    out.append(info)
            elif isinstance(node, ast.Call):
                out.extend(self._traced_call_bodies(node))
        # dedupe: a scan body that is also @jit-decorated keeps the jit entry
        seen: Set[ast.AST] = set()
        unique: List[JitFunctionInfo] = []
        for info in out:
            if info.node not in seen:
                seen.add(info.node)
                unique.append(info)
        return unique

    def _jit_decorated(
        self, node: ast.FunctionDef
    ) -> Optional[JitFunctionInfo]:
        for dec in node.decorator_list:
            if self.resolve(dec) in _JIT_NAMES:
                return JitFunctionInfo(node, "jit", set())
            if isinstance(dec, ast.Call):
                target = self.resolve(dec.func)
                if target in _JIT_NAMES:
                    return JitFunctionInfo(
                        node, "jit", self._static_params(node, dec.keywords)
                    )
                if (
                    target in _PARTIAL_NAMES
                    and dec.args
                    and self.resolve(dec.args[0]) in _JIT_NAMES
                ):
                    return JitFunctionInfo(
                        node, "jit", self._static_params(node, dec.keywords)
                    )
        return None

    def _static_params(
        self, node: ast.FunctionDef, keywords: List[ast.keyword]
    ) -> Set[str]:
        """Param names marked static via static_argnums/static_argnames."""
        params = [a.arg for a in node.args.posonlyargs + node.args.args]
        static: Set[str] = set()
        for kw in keywords:
            names = _constant_leaves(kw.value)
            if kw.arg == "static_argnums":
                for v in names:
                    if isinstance(v, int) and 0 <= v < len(params):
                        static.add(params[v])
            elif kw.arg == "static_argnames":
                for v in names:
                    if isinstance(v, str):
                        static.add(v)
        return static

    def _traced_call_bodies(self, call: ast.Call) -> List[JitFunctionInfo]:
        """Bodies handed to lax.scan / pallas_call (traced, all-dynamic)."""
        target = self.resolve(call.func)
        kind = None
        if target in _TRACED_CALLS:
            kind = _TRACED_CALLS[target]
        elif target:
            for suffix, k in _TRACED_CALL_SUFFIXES.items():
                if target.endswith(suffix):
                    kind = k
        if kind is None or not call.args:
            return []
        body = call.args[0]
        if isinstance(body, ast.Lambda):
            return [JitFunctionInfo(body, kind, set())]
        if isinstance(body, ast.Name):
            # nearest enclosing def of that name: walk up from the call
            scope: Optional[ast.AST] = call
            while scope is not None:
                for node in ast.walk(scope):
                    if (
                        isinstance(node, ast.FunctionDef)
                        and node.name == body.id
                    ):
                        return [JitFunctionInfo(node, kind, set())]
                scope = self.parents.get(scope)
        return []

    # -- lexical queries -------------------------------------------------------
    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return cur
            cur = self.parents.get(cur)
        return None

    def enclosing_class(self, node: ast.AST) -> Optional[ast.ClassDef]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return cur
            cur = self.parents.get(cur)
        return None

    def enclosing_jit(self, node: ast.AST) -> Optional[JitFunctionInfo]:
        """The innermost traced body ``node`` sits in, if any."""
        cur: Optional[ast.AST] = node
        while cur is not None:
            info = self._jit_nodes.get(cur)
            if info is not None:
                return info
            cur = self.parents.get(cur)
        return None


def _constant_leaves(node: ast.AST) -> List[object]:
    """Constant scalars inside a (possibly nested) literal expression."""
    if isinstance(node, ast.Constant):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out: List[object] = []
        for elt in node.elts:
            out.extend(_constant_leaves(elt))
        return out
    return []


def build_context(path: str, source: str, package: str = "") -> FileContext:
    """Parse ``source`` into a :class:`FileContext` (rules' entry point)."""
    return FileContext(path, source, package)
