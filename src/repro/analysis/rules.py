"""The NIMBLE rule catalog (DESIGN.md §12).

Five rules, each grounded in a convention the repo already states in
prose or pins with runtime tests:

  * ``jit-purity`` — retrace/impurity hazards inside traced bodies
    (``@jax.jit`` entry points, ``lax.scan`` / ``pallas_call`` bodies):
    host pulls (``.item()`` / ``.tolist()`` / ``float()`` on traced
    values), Python branching on traced parameters, trace-time side
    effects (``print``, wall-clock, RNG), closures that mutate state,
    and unhashable ``static_argnums`` / ``static_argnames`` specs;
  * ``determinism`` — wall-clock, unseeded RNG, and order-sensitive
    ``set`` iteration in the seed-deterministic layers (``core/``,
    ``fabric/``, ``faults/``, ``serve/scenario.py``) whose digests,
    arbitration order, and schedules must be bit-stable;
  * ``schema-discipline`` — every ``nimble.<kind>/vN`` literal and
    ``tag()`` call must strict-parse, use a kind registered in
    ``repro.jsonio.KNOWN_SCHEMAS`` at the registered version, and emit
    only keys recorded in ``schemas.lock.json`` (new keys require a
    version bump + lock regeneration);
  * ``frozen-spec`` — ``object.__setattr__`` outside a frozen
    dataclass's ``__post_init__``, and mutable defaults on frozen spec
    fields;
  * ``float-eq`` — ``==`` / ``!=`` against NaN anywhere (always False —
    NaN is a *sentinel* in telemetry/estimator paths, probed with
    ``isnan``), and float-literal equality in those paths.

Rules are stateless over a :class:`~repro.analysis.context.FileContext`;
scoping is by path prefix so test fixtures opt in by naming their
virtual path accordingly.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

from ..jsonio import known_schemas
from .context import FileContext, JitFunctionInfo
from .engine import Finding
from .schemas import collect_schema_sites, generate_lock_obj, load_lock


def _norm(path: str) -> str:
    return path.replace("\\", "/")


def _in_scope(path: str, prefixes: Sequence[str]) -> bool:
    p = _norm(path)
    return any(f"/{frag}" in f"/{p}" for frag in prefixes)


# -- rule 1: jit-purity ----------------------------------------------------------

#: impure calls that capture trace-time state (baked into the jaxpr once)
_IMPURE_IN_JIT = {
    "time.time", "time.time_ns", "time.monotonic", "time.perf_counter",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "os.urandom", "uuid.uuid4", "print",
}
_HOST_PULL_ATTRS = {"item", "tolist"}
_HOST_CASTS = {"float", "int", "bool"}
#: attribute accesses that stay static under trace (shape metadata)
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_MUTATING_METHODS = {
    "append", "extend", "insert", "add", "update", "pop", "remove",
    "clear", "setdefault", "popitem", "discard",
}


class JitPurityRule:
    rule_id = "jit-purity"
    description = (
        "retrace/impurity hazards inside jit, lax.scan, and pallas bodies"
    )

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for info in ctx.jit_functions:
            yield from self._check_body(ctx, info)
        # static-spec hygiene lives on the decorators, outside the body
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_static_spec(ctx, node)

    # each traced body: walk it once, skipping nested traced bodies that
    # will be visited on their own (they are still traced content, so the
    # same checks apply — visiting them from their own info is enough)
    def _check_body(
        self, ctx: FileContext, info: JitFunctionInfo
    ) -> Iterator[Finding]:
        params = self._params(info.node)
        traced = params - info.static_params
        for node in ast.walk(info.node):
            if ctx.enclosing_jit(node) is not info and node is not info.node:
                continue  # belongs to a nested traced body
            if isinstance(node, ast.Call):
                yield from self._check_call(ctx, info, node)
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                yield Finding(
                    self.rule_id, ctx.path, node.lineno, node.col_offset,
                    f"`{'global' if isinstance(node, ast.Global) else 'nonlocal'}"
                    f" {', '.join(node.names)}` inside traced body "
                    f"`{info.name}` — jit closures must not mutate "
                    "enclosing state (runs at trace time only)",
                )
            elif isinstance(node, (ast.If, ast.While)):
                yield from self._check_branch(ctx, info, node, traced)

    def _params(self, node: ast.AST) -> Set[str]:
        args = getattr(node, "args", None)
        if args is None:
            return set()
        names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
        if args.vararg:
            names.append(args.vararg.arg)
        if args.kwarg:
            names.append(args.kwarg.arg)
        return set(names)

    def _check_call(
        self, ctx: FileContext, info: JitFunctionInfo, call: ast.Call
    ) -> Iterator[Finding]:
        target = ctx.resolve(call.func)
        if target in _IMPURE_IN_JIT or (
            target
            and (target.startswith("random.")
                 or (target.startswith("numpy.random.")
                     and target != "numpy.random.default_rng"))
        ):
            yield Finding(
                self.rule_id, ctx.path, call.lineno, call.col_offset,
                f"`{target}` inside traced body `{info.name}` — executes "
                "at trace time only and bakes its value into the jaxpr",
            )
            return
        if isinstance(call.func, ast.Attribute) and (
            call.func.attr in _HOST_PULL_ATTRS and not call.args
        ):
            base = ctx.resolve(call.func.value)
            if not self._static_expr(ctx, info, call.func.value):
                yield Finding(
                    self.rule_id, ctx.path, call.lineno, call.col_offset,
                    f"`.{call.func.attr}()` on "
                    f"{'`' + base + '`' if base else 'a traced value'} "
                    f"inside traced body `{info.name}` — host pull forces "
                    "a sync (ConcretizationTypeError under jit)",
                )
            return
        if (
            target in _HOST_CASTS
            and len(call.args) == 1
            and not isinstance(call.args[0], ast.Constant)
            and not self._static_expr(ctx, info, call.args[0])
        ):
            yield Finding(
                self.rule_id, ctx.path, call.lineno, call.col_offset,
                f"`{target}()` on a traced value inside `{info.name}` — "
                "concretizes the tracer (retrace hazard); keep it a jnp "
                "array or hoist to the host side",
            )
            return
        # in-place mutation of closed-over (non-local) state
        if isinstance(call.func, ast.Attribute) and (
            call.func.attr in _MUTATING_METHODS
            and isinstance(call.func.value, ast.Name)
        ):
            name = call.func.value.id
            if name not in self._local_bindings(info):
                yield Finding(
                    self.rule_id, ctx.path, call.lineno, call.col_offset,
                    f"`{name}.{call.func.attr}(...)` inside traced body "
                    f"`{info.name}` mutates closed-over state — trace-time "
                    "side effect, silently stale on cache hits",
                )

    def _local_bindings(self, info: JitFunctionInfo) -> Set[str]:
        cached = getattr(info, "locals_cache", None)
        if cached is not None:
            return cached
        names = set(self._params(info.node))
        for node in ast.walk(info.node):
            if isinstance(node, ast.Name) and isinstance(
                node.ctx, ast.Store
            ):
                names.add(node.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node is not info.node:
                    names.add(node.name)
        info.locals_cache = names
        return names

    def _static_expr(
        self, ctx: FileContext, info: JitFunctionInfo, node: ast.AST
    ) -> bool:
        """Conservatively true when ``node`` only touches static material:
        shape/dtype metadata, static params, or plain constants."""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
                return True
        names = {
            n.id for n in ast.walk(node) if isinstance(n, ast.Name)
        }
        params = self._params(info.node)
        dynamic = (names & params) - info.static_params
        return not dynamic and not (names - params)

    def _check_branch(
        self,
        ctx: FileContext,
        info: JitFunctionInfo,
        node: ast.AST,
        traced: Set[str],
    ) -> Iterator[Finding]:
        test = node.test
        # `x is None` branches on pytree *structure*, not a traced value
        if isinstance(test, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops
        ):
            return
        if any(
            isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS
            for sub in ast.walk(test)
        ):
            return
        hit = sorted(
            n.id for n in ast.walk(test)
            if isinstance(n, ast.Name) and n.id in traced
        )
        if hit:
            kw = "if" if isinstance(node, ast.If) else "while"
            yield Finding(
                self.rule_id, ctx.path, node.lineno, node.col_offset,
                f"Python `{kw}` on traced parameter(s) {hit} inside "
                f"`{info.name}` — branches at trace time "
                "(TracerBoolConversionError / silent retrace); use "
                "lax.cond/jnp.where or mark the argument static",
            )

    def _check_static_spec(
        self, ctx: FileContext, node: ast.FunctionDef
    ) -> Iterator[Finding]:
        for dec in node.decorator_list:
            if not isinstance(dec, ast.Call):
                continue
            target = ctx.resolve(dec.func)
            is_jit = target in ("jax.jit", "jit") or (
                target in ("functools.partial", "partial")
                and dec.args
                and ctx.resolve(dec.args[0]) in ("jax.jit", "jit")
            )
            if not is_jit:
                continue
            for kw in dec.keywords:
                if kw.arg not in ("static_argnums", "static_argnames"):
                    continue
                if not self._hashable_literal(kw.value):
                    yield Finding(
                        self.rule_id, ctx.path, kw.value.lineno,
                        kw.value.col_offset,
                        f"`{kw.arg}` on `{node.name}` is not a hashable "
                        "constant literal (int/str or tuple thereof) — "
                        "lists/dynamic specs break the jit cache key",
                    )

    def _hashable_literal(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant):
            return isinstance(node.value, (int, str))
        if isinstance(node, ast.Tuple):
            return all(self._hashable_literal(e) for e in node.elts)
        return False


# -- rule 2: determinism ---------------------------------------------------------

#: layers whose outputs must be seed/ordering-deterministic
_DETERMINISM_SCOPE = (
    "repro/core/", "repro/fabric/", "repro/faults/",
    "repro/serve/scenario.py",
)
_WALLCLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
}
_ENTROPY = {
    "os.urandom", "uuid.uuid1", "uuid.uuid4",
    "secrets.token_bytes", "secrets.token_hex", "secrets.randbits",
    "secrets.choice",
}
_NP_RANDOM_ALLOWED = {
    "numpy.random.default_rng", "numpy.random.Generator",
    "numpy.random.SeedSequence",
}
#: iteration-order-sensitive consumers of a set-producing expression
_ORDER_SENSITIVE_CALLS = {"list", "tuple", "enumerate"}


class DeterminismRule:
    rule_id = "determinism"
    description = (
        "wall-clock, unseeded RNG, and set-iteration in deterministic layers"
    )

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if not _in_scope(ctx.path, _DETERMINISM_SCOPE):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(ctx, node)
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                if self._set_producing(ctx, it):
                    yield Finding(
                        self.rule_id, ctx.path, it.lineno, it.col_offset,
                        "iteration over a set — order is hash-dependent; "
                        "wrap in sorted(...) to keep digests/arbitration "
                        "order bit-stable",
                    )

    def _check_call(self, ctx: FileContext, call: ast.Call) -> Iterator[Finding]:
        target = ctx.resolve(call.func)
        if target in _WALLCLOCK:
            yield Finding(
                self.rule_id, ctx.path, call.lineno, call.col_offset,
                f"`{target}` in a deterministic layer — wall-clock breaks "
                "replayability; thread a window/clock value in instead",
            )
        elif target in _ENTROPY:
            yield Finding(
                self.rule_id, ctx.path, call.lineno, call.col_offset,
                f"`{target}` in a deterministic layer — unseeded entropy; "
                "derive from the scenario seed",
            )
        elif target and target.startswith("random."):
            yield Finding(
                self.rule_id, ctx.path, call.lineno, call.col_offset,
                f"`{target}` uses the process-global RNG — use a seeded "
                "`random.Random(seed)` / `np.random.default_rng(seed)`",
            )
        elif (
            target
            and target.startswith("numpy.random.")
            and target not in _NP_RANDOM_ALLOWED
        ):
            yield Finding(
                self.rule_id, ctx.path, call.lineno, call.col_offset,
                f"`{target}` uses numpy's global RNG — use a seeded "
                "`np.random.default_rng(seed)` generator",
            )
        elif (
            target in _ORDER_SENSITIVE_CALLS
            and call.args
            and self._set_producing(ctx, call.args[0])
        ):
            yield Finding(
                self.rule_id, ctx.path, call.lineno, call.col_offset,
                f"`{target}(<set>)` materializes hash order — use "
                "sorted(...) for a deterministic sequence",
            )

    def _set_producing(self, ctx: FileContext, node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            if ctx.resolve(node.func) in ("set", "frozenset"):
                return True
            # set.union/intersection/difference chains keep set order
            if isinstance(node.func, ast.Attribute) and node.func.attr in (
                "union", "intersection", "difference",
                "symmetric_difference",
            ):
                return self._set_producing(ctx, node.func.value)
        if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)
        ):
            return self._set_producing(ctx, node.left) or self._set_producing(
                ctx, node.right
            )
        return False


# -- rule 3: schema-discipline ---------------------------------------------------

class SchemaDisciplineRule:
    rule_id = "schema-discipline"
    description = (
        "frozen nimble.<kind>/vN ids: strict parse, registry, lock manifest"
    )

    def __init__(self, lock: Optional[dict] = None):
        # default: the committed lock, loaded lazily so fixture runs can
        # inject their own manifest
        self._lock = lock
        self._lock_loaded = lock is not None

    @property
    def lock(self) -> Optional[dict]:
        if not self._lock_loaded:
            from .engine import default_lock_path

            self._lock = load_lock(default_lock_path())
            self._lock_loaded = True
        return self._lock

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        registry = known_schemas()
        lock_kinds = (self.lock or {}).get("kinds", {})
        for site in collect_schema_sites(ctx):
            if site.error is not None:
                yield Finding(
                    self.rule_id, ctx.path, site.line, site.col,
                    f"malformed schema reference {site.raw!r}: {site.error}",
                )
                continue
            assert site.kind is not None and site.version is not None
            if site.kind not in registry:
                yield Finding(
                    self.rule_id, ctx.path, site.line, site.col,
                    f"schema kind {site.kind!r} is not registered in "
                    "repro.jsonio.KNOWN_SCHEMAS",
                )
                continue
            if site.version != registry[site.kind]:
                yield Finding(
                    self.rule_id, ctx.path, site.line, site.col,
                    f"{site.raw} pins v{site.version} but "
                    f"{site.kind!r} is registered at "
                    f"v{registry[site.kind]} — stale reference or missing "
                    "registry bump",
                )
                continue
            if site.source != "tag" or site.keys is None:
                continue
            locked = lock_kinds.get(site.kind)
            if locked is None:
                yield Finding(
                    self.rule_id, ctx.path, site.line, site.col,
                    f"kind {site.kind!r} is emitted here but absent from "
                    "schemas.lock.json — regenerate with "
                    "`python -m repro.analysis --write-lock`",
                )
                continue
            if locked.get("version") != site.version:
                yield Finding(
                    self.rule_id, ctx.path, site.line, site.col,
                    f"{site.raw} emits v{site.version} but the lock "
                    f"records v{locked.get('version')} — bump the registry "
                    "and regenerate the lock",
                )
                continue
            locked_keys = locked.get("keys")
            if locked_keys is None:
                continue
            extra = sorted(site.keys - set(locked_keys))
            if extra:
                yield Finding(
                    self.rule_id, ctx.path, site.line, site.col,
                    f"{site.raw} emits key(s) {extra} not in "
                    "schemas.lock.json — emitted keys changed: bump the "
                    "schema version and regenerate the lock",
                )


# -- rule 4: frozen-spec ---------------------------------------------------------

_MUTABLE_DEFAULT_CALLS = {
    "list", "dict", "set", "bytearray",
    "numpy.array", "numpy.zeros", "numpy.ones", "numpy.empty",
    "numpy.full", "numpy.arange",
}


class FrozenSpecRule:
    rule_id = "frozen-spec"
    description = (
        "object.__setattr__ outside __post_init__; mutable frozen defaults"
    )

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for info in ctx.dataclasses.values():
            if not info.frozen:
                continue
            for name, default in info.fields.items():
                if default is not None and self._mutable_default(ctx, default):
                    yield Finding(
                        self.rule_id, ctx.path, default.lineno,
                        default.col_offset,
                        f"frozen spec `{info.name}.{name}` has a mutable "
                        "default — shared across every instance; use "
                        "dataclasses.field(default_factory=...) or an "
                        "immutable value",
                    )
        for node in ast.walk(ctx.tree):
            if not (
                isinstance(node, ast.Call)
                and ctx.resolve(node.func) == "object.__setattr__"
            ):
                continue
            fn = ctx.enclosing_function(node)
            cls = ctx.enclosing_class(node)
            in_post_init = (
                fn is not None
                and getattr(fn, "name", "") == "__post_init__"
                and cls is not None
                and cls.name in ctx.dataclasses
                and ctx.dataclasses[cls.name].frozen
            )
            if not in_post_init:
                yield Finding(
                    self.rule_id, ctx.path, node.lineno, node.col_offset,
                    "object.__setattr__ outside a frozen dataclass's "
                    "__post_init__ — defeats the frozen-spec contract "
                    "(hash/eq stability, safe sharing across sessions)",
                )

    def _mutable_default(self, ctx: FileContext, node: ast.expr) -> bool:
        if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            return ctx.resolve(node.func) in _MUTABLE_DEFAULT_CALLS
        return False


# -- rule 5: float-eq ------------------------------------------------------------

#: files where NaN is a live sentinel and float equality is a trap
_FLOAT_EQ_SCOPE = (
    "repro/runtime/telemetry.py", "repro/runtime/estimator.py",
)
_NAN_NAMES = {"numpy.nan", "numpy.NaN", "math.nan", "jax.numpy.nan"}


class FloatEqRule:
    rule_id = "float-eq"
    description = "== / != against NaN or float literals in sentinel paths"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        scoped = _in_scope(ctx.path, _FLOAT_EQ_SCOPE)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Compare):
                continue
            if not any(isinstance(op, (ast.Eq, ast.NotEq)) for op in node.ops):
                continue
            operands = [node.left, *node.comparators]
            if any(self._is_nan(ctx, o) for o in operands):
                yield Finding(
                    self.rule_id, ctx.path, node.lineno, node.col_offset,
                    "comparison against NaN is always False — NaN is a "
                    "telemetry sentinel; probe with np.isnan/math.isnan",
                )
            elif scoped and any(
                isinstance(o, ast.Constant) and isinstance(o.value, float)
                for o in operands
            ):
                yield Finding(
                    self.rule_id, ctx.path, node.lineno, node.col_offset,
                    "float-literal equality in a NaN-sentinel path — "
                    "rounding/telemetry noise makes exact equality flaky; "
                    "compare with a tolerance or an integer state",
                )

    def _is_nan(self, ctx: FileContext, node: ast.AST) -> bool:
        if ctx.resolve(node) in _NAN_NAMES:
            return True
        return (
            isinstance(node, ast.Call)
            and ctx.resolve(node.func) == "float"
            and len(node.args) == 1
            and isinstance(node.args[0], ast.Constant)
            and isinstance(node.args[0].value, str)
            and node.args[0].value.lower() == "nan"
        )


# -- interprocedural rules (DESIGN.md §12.2) -------------------------------------
#
# These run over the whole-program view: the engine calls ``prepare(program)``
# once per run, the rule computes findings there, and ``check(ctx)`` replays
# them per file so suppressions/baseline apply exactly like per-file rules.

class _InterprocRule:
    """Shared prepare/replay plumbing for whole-program rules."""

    def __init__(self):
        self._findings: Dict[str, List[Finding]] = {}

    def _store(self, finding: Finding) -> None:
        self._findings.setdefault(finding.path, []).append(finding)

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        yield from self._findings.get(ctx.path, ())


class RetraceProvenanceRule(_InterprocRule):
    """PLAN_DEPENDENT values baked into trace boundaries (tentpole 1).

    The inventory itself (``nimble.retrace/v1``) records *every* boundary
    with its lattice class; only PLAN_DEPENDENT sites are findings — they
    are the constants that defeat zero-retrace hot swap (ROADMAP item 2).
    WINDOW_DEPENDENT sites stay inventory-only: a per-window retrace is a
    cost decision for the swap PR, not a silent correctness hazard.
    """

    rule_id = "retrace-provenance"
    description = (
        "plan-dependent trace-time constants at jit/scan/pallas boundaries"
    )

    def __init__(self):
        super().__init__()
        self.analysis = None
        self.sites: List = []

    def prepare(self, program) -> None:
        from .provenance import PLAN_DEPENDENT, analyze_program

        self._findings = {}
        self.analysis = analyze_program(program)
        self.sites = self.analysis.trace_sites()
        for s in self.sites:
            if s.provenance != PLAN_DEPENDENT:
                continue
            self._store(Finding(
                self.rule_id, s.path, s.line, 0,
                f"{s.kind} `{s.detail}` in `{s.function}` is "
                f"PLAN_DEPENDENT — {s.note}",
            ))


class UnitsRule(_InterprocRule):
    """Unit mixing across bytes | bytes_per_s | fraction | price | windows."""

    rule_id = "units"
    description = (
        "unit mixing against the seeded bytes/rate/fraction/price/window "
        "lattice"
    )

    def __init__(self):
        super().__init__()
        self.analysis = None

    def prepare(self, program) -> None:
        from .units import analyze_units

        self._findings = {}
        self.analysis = analyze_units(program)
        for m in self.analysis.mixes:
            self._store(Finding(
                self.rule_id, m.path, m.line, m.col,
                f"`{m.function}` {m.message}",
            ))


class CrossModuleDeterminismRule(_InterprocRule):
    """Hash-ordered returns iterated in deterministic layers.

    The per-file determinism rule sees ``for x in {a, b}``; it cannot see
    ``for x in other_module.live_set()``.  This rule propagates the
    "returns set-iteration order" bit through the call graph (a function
    returning another hash-ordered function's result is hash-ordered too)
    and flags order-sensitive consumption — ledger commit order, schedule
    order, report key order — anywhere in the deterministic scope.
    """

    rule_id = "xmodule-determinism"
    description = (
        "set-iteration order flowing across call boundaries into "
        "deterministic outputs"
    )

    _CONSUMERS = {"list", "tuple", "enumerate"}

    def prepare(self, program) -> None:
        from .callgraph import module_name_of

        self._findings = {}
        hash_order = {
            q for q, s in program.summaries.items() if s.return_hash_order
        }
        # propagate through return_calls until stable (finite, monotone)
        while True:
            grew = False
            for qual, s in sorted(program.summaries.items()):
                if qual in hash_order:
                    continue
                for target in s.return_calls:
                    resolved = program.resolve_target(target, s.module)
                    if resolved in hash_order:
                        hash_order.add(qual)
                        grew = True
                        break
            if not grew:
                break
        self._hash_order = hash_order
        for ctx in program.contexts:
            if not _in_scope(ctx.path, _DETERMINISM_SCOPE):
                continue
            module = module_name_of(ctx.path)
            for node in ast.walk(ctx.tree):
                call = self._consumed_call(ctx, node)
                if call is None:
                    continue
                target = ctx.resolve(call.func)
                if target is None:
                    continue
                resolved = program.resolve_target(target, module)
                if resolved is None or resolved not in hash_order:
                    continue
                # anchor on the call: `ast.comprehension` has no lineno
                self._store(Finding(
                    self.rule_id, ctx.path, call.lineno, call.col_offset,
                    f"iterates the hash-ordered return of `{resolved}` — "
                    "set iteration order leaks into a deterministic "
                    "output; sort at the producer or wrap in sorted(...)",
                ))

    def _consumed_call(
        self, ctx: FileContext, node: ast.AST
    ) -> Optional[ast.Call]:
        """The function call whose result ``node`` consumes order from."""
        if isinstance(node, (ast.For, ast.comprehension)):
            it = node.iter
            return it if isinstance(it, ast.Call) else None
        if isinstance(node, ast.Call) and ctx.resolve(node.func) in (
            self._CONSUMERS
        ):
            if node.args and isinstance(node.args[0], ast.Call):
                return node.args[0]
        return None


# -- registry --------------------------------------------------------------------

RULES = (
    JitPurityRule(),
    DeterminismRule(),
    SchemaDisciplineRule(),
    FrozenSpecRule(),
    FloatEqRule(),
    RetraceProvenanceRule(),
    UnitsRule(),
    CrossModuleDeterminismRule(),
)


def generate_schema_lock(contexts: Iterable[FileContext]) -> dict:
    """Public alias for the lock generator (CLI + bench gate)."""
    return generate_lock_obj(contexts)
