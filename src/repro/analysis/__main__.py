"""CLI: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean, 1 findings (or stale lock under ``--check-lock``),
2 usage error.  Default target is the ``src/repro`` tree this module
ships in; paths are reported relative to ``src/`` so baseline entries
stay machine-independent.

``--write-lock`` regenerates *both* committed manifests —
``schemas.lock.json`` (emitted record kinds/keys) and
``retrace.lock.json`` (trace-boundary site inventory, line-free keys) —
plus the digest-keyed function-summary cache; ``--check-lock`` fails
when regenerating either lock is not a no-op.  ``--debt`` prints the
suppression/baseline ledger; ``--retrace-out`` / ``--units-out`` dump
the ``nimble.retrace/v1`` / ``nimble.units/v1`` inventories.
"""

from __future__ import annotations

import argparse
import os
import sys

from ..jsonio import json_dumps, tag, write_json_file
from .callgraph import SummaryCache, build_program
from .engine import (
    AnalysisEngine,
    build_contexts,
    collect_debt,
    default_baseline_path,
    default_lock_path,
    load_baseline,
    write_baseline,
)
from .provenance import (
    analyze_program,
    build_retrace_inventory,
    default_retrace_lock_path,
    retrace_lock_is_fresh,
    write_retrace_lock,
)
from .rules import RULES, RetraceProvenanceRule
from .schemas import lock_is_fresh, write_lock
from .units import build_units_inventory

DEBT_KIND = "lint_debt"


def _default_root() -> str:
    # src/repro/analysis/__main__.py -> src/repro
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def default_summary_cache_path() -> str:
    return os.path.join(os.path.dirname(__file__), "summaries.cache.json")


def _emit(path: str, obj: dict) -> None:
    if path == "-":
        sys.stdout.write(json_dumps(obj, indent=True).decode() + "\n")
    else:
        write_json_file(path, obj)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="NIMBLE static invariant checker (DESIGN.md §12)",
    )
    parser.add_argument(
        "paths", nargs="*", help="files/dirs to lint (default: src/repro)"
    )
    parser.add_argument(
        "--json", metavar="PATH",
        help="write the nimble.lint/v1 report here ('-' for stdout)",
    )
    parser.add_argument(
        "--baseline", metavar="PATH", default=None,
        help=f"baseline file (default: {default_baseline_path()})",
    )
    parser.add_argument(
        "--no-baseline", action="store_true",
        help="ignore the baseline — report grandfathered findings too",
    )
    parser.add_argument(
        "--update-baseline", action="store_true",
        help="rewrite the baseline from this run's findings and exit 0",
    )
    parser.add_argument(
        "--write-lock", action="store_true",
        help="regenerate schemas.lock.json + retrace.lock.json + the "
        "summary cache from the scanned files",
    )
    parser.add_argument(
        "--check-lock", action="store_true",
        help="also fail when regenerating either lock is not a no-op",
    )
    parser.add_argument(
        "--debt", action="store_true",
        help="list every inline suppression and baseline entry, then exit",
    )
    parser.add_argument(
        "--retrace-out", metavar="PATH",
        help="write the nimble.retrace/v1 site inventory ('-' for stdout)",
    )
    parser.add_argument(
        "--units-out", metavar="PATH",
        help="write the nimble.units/v1 inventory ('-' for stdout)",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    parser.add_argument(
        "-q", "--quiet", action="store_true", help="summary line only"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in RULES:
            print(f"{rule.rule_id:20s} {rule.description}")
        print(f"{'suppression':20s} suppression hygiene (engine built-in)")
        print(f"{'baseline':20s} stale/reasonless baseline entries "
              "(engine built-in)")
        return 0

    root = _default_root()
    paths = args.paths or [root]
    rel_to = os.path.dirname(root)  # .../src — reports say repro/...
    contexts = build_contexts(paths, rel_to=rel_to)

    if args.write_lock:
        cache = SummaryCache(default_summary_cache_path())
        program = build_program(contexts, cache=cache)
        analysis = analyze_program(program)
        lock = write_lock(contexts, default_lock_path())
        retrace = write_retrace_lock(
            program, default_retrace_lock_path(), analysis
        )
        cache.save()
        print(
            f"[analysis] wrote {default_lock_path()} "
            f"({len(lock['kinds'])} kinds)"
        )
        print(
            f"[analysis] wrote {default_retrace_lock_path()} "
            f"({len(retrace['entries'])} sites)"
        )
        print(
            f"[analysis] wrote {default_summary_cache_path()} "
            f"({cache.hits} cached, {cache.misses} summarized)"
        )
        return 0

    baseline = (
        [] if args.no_baseline else load_baseline(args.baseline)
    )

    if args.debt:
        debt = collect_debt(contexts, baseline)
        for s in debt["suppressions"]:
            rules = ",".join(s["rules"])
            print(
                f"{s['path']}:{s['line']}: suppressed [{rules}] "
                f"-- {s['reason'] or '(no reason)'}"
            )
        for e in debt["baseline"]:
            age = f" since {e['since']}" if e.get("since") else ""
            reason = e.get("reason") or "(no reason)"
            print(
                f"{e['path']}: baselined [{e['rule']}]{age} -- {reason}: "
                f"{e['message']}"
            )
        print(
            f"[analysis] debt: {len(debt['suppressions'])} suppression(s), "
            f"{len(debt['baseline'])} baseline entr(ies)"
        )
        if args.json:
            _emit(args.json, tag(DEBT_KIND, debt))
        return 0

    cache = None
    if os.path.exists(default_summary_cache_path()) and not args.paths:
        cache = SummaryCache(default_summary_cache_path())
    engine = AnalysisEngine(RULES, baseline)
    report = engine.run(contexts, root=";".join(paths), cache=cache)

    if args.update_baseline:
        path = args.baseline or default_baseline_path()
        write_baseline(report.findings, path)
        print(
            f"[analysis] baselined {len(report.findings)} finding(s) -> {path}"
        )
        return 0

    if not args.quiet:
        for f in report.findings:
            print(f)

    retrace_rule = next(
        r for r in engine.rules if isinstance(r, RetraceProvenanceRule)
    )
    program = engine.program
    if args.retrace_out and program is not None:
        _emit(args.retrace_out, build_retrace_inventory(
            program, retrace_rule.analysis
        ))
    if args.units_out and program is not None:
        _emit(args.units_out, build_units_inventory(program))

    lock_fresh = True
    if args.check_lock:
        lock_fresh = lock_is_fresh(default_lock_path(), contexts)
        if not lock_fresh:
            print(
                "[analysis] schemas.lock.json is stale — regenerate with "
                "--write-lock (and bump versions for changed kinds)"
            )
        if program is not None:
            retrace_fresh = retrace_lock_is_fresh(
                default_retrace_lock_path(), program, retrace_rule.analysis
            )
            if not retrace_fresh:
                print(
                    "[analysis] retrace.lock.json is stale — the "
                    "trace-boundary inventory changed; regenerate with "
                    "--write-lock"
                )
            lock_fresh = lock_fresh and retrace_fresh
    status = "clean" if report.clean and lock_fresh else "FAIL"
    print(
        f"[analysis] {status}: {report.files} files, "
        f"{len(report.findings)} finding(s), "
        f"{len(report.suppressed)} suppressed, "
        f"{len(report.baselined)} baselined"
    )
    if args.json:
        _emit(args.json, report.to_json_obj())
    return 0 if report.clean and lock_fresh else 1


if __name__ == "__main__":
    sys.exit(main())
