"""CLI: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean, 1 findings (or stale lock under ``--check-lock``),
2 usage error.  Default target is the ``src/repro`` tree this module
ships in; paths are reported relative to ``src/`` so baseline entries
stay machine-independent.
"""

from __future__ import annotations

import argparse
import os
import sys

from ..jsonio import json_dumps, write_json_file
from .engine import (
    AnalysisEngine,
    build_contexts,
    default_baseline_path,
    default_lock_path,
    load_baseline,
    write_baseline,
)
from .rules import RULES
from .schemas import lock_is_fresh, write_lock


def _default_root() -> str:
    # src/repro/analysis/__main__.py -> src/repro
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="NIMBLE static invariant checker (DESIGN.md §12)",
    )
    parser.add_argument(
        "paths", nargs="*", help="files/dirs to lint (default: src/repro)"
    )
    parser.add_argument(
        "--json", metavar="PATH",
        help="write the nimble.lint/v1 report here ('-' for stdout)",
    )
    parser.add_argument(
        "--baseline", metavar="PATH", default=None,
        help=f"baseline file (default: {default_baseline_path()})",
    )
    parser.add_argument(
        "--no-baseline", action="store_true",
        help="ignore the baseline — report grandfathered findings too",
    )
    parser.add_argument(
        "--update-baseline", action="store_true",
        help="rewrite the baseline from this run's findings and exit 0",
    )
    parser.add_argument(
        "--write-lock", action="store_true",
        help="regenerate schemas.lock.json from the scanned files",
    )
    parser.add_argument(
        "--check-lock", action="store_true",
        help="also fail when regenerating schemas.lock.json is not a no-op",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    parser.add_argument(
        "-q", "--quiet", action="store_true", help="summary line only"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in RULES:
            print(f"{rule.rule_id:20s} {rule.description}")
        print(f"{'suppression':20s} suppression hygiene (engine built-in)")
        return 0

    root = _default_root()
    paths = args.paths or [root]
    rel_to = os.path.dirname(root)  # .../src — reports say repro/...
    contexts = build_contexts(paths, rel_to=rel_to)

    if args.write_lock:
        lock = write_lock(contexts, default_lock_path())
        print(
            f"[analysis] wrote {default_lock_path()} "
            f"({len(lock['kinds'])} kinds)"
        )
        return 0

    baseline = (
        [] if args.no_baseline else load_baseline(args.baseline)
    )
    engine = AnalysisEngine(RULES, baseline)
    report = engine.run(contexts, root=";".join(paths))

    if args.update_baseline:
        path = args.baseline or default_baseline_path()
        write_baseline(report.findings, path)
        print(
            f"[analysis] baselined {len(report.findings)} finding(s) -> {path}"
        )
        return 0

    if not args.quiet:
        for f in report.findings:
            print(f)
    lock_fresh = True
    if args.check_lock:
        lock_fresh = lock_is_fresh(default_lock_path(), contexts)
        if not lock_fresh:
            print(
                "[analysis] schemas.lock.json is stale — regenerate with "
                "--write-lock (and bump versions for changed kinds)"
            )
    status = "clean" if report.clean and lock_fresh else "FAIL"
    print(
        f"[analysis] {status}: {report.files} files, "
        f"{len(report.findings)} finding(s), "
        f"{len(report.suppressed)} suppressed, "
        f"{len(report.baselined)} baselined"
    )
    if args.json:
        obj = report.to_json_obj()
        if args.json == "-":
            sys.stdout.write(json_dumps(obj, indent=True).decode() + "\n")
        else:
            write_json_file(args.json, obj)
    return 0 if report.clean and lock_fresh else 1


if __name__ == "__main__":
    sys.exit(main())
