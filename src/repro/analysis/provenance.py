"""Retrace-hazard provenance: which trace-boundary values track the plan?

NIMBLE's zero-retrace hot swap (ROADMAP item 2) only works once every
plan-varying trace-time constant is found and demoted to runtime data —
the CUDA-graphs idiom (arxiv 2604.22228) pre-records the transfer graph
and swaps by *parameter update*, so anything plan-shaped that is baked
into a jaxpr forces a re-record.  This module classifies every value
reaching a trace boundary into a three-point lattice

    TOPOLOGY_STABLE  ⊑  WINDOW_DEPENDENT  ⊑  PLAN_DEPENDENT

(stable: changes only with cluster geometry — shapes, incidence tables,
config; window: changes per telemetry window — prices, loads, demand
estimates; plan: changes on every plan swap — flows, chunk schedules,
slot schedules) by running a bounded interprocedural fixpoint over the
:class:`~repro.analysis.callgraph.Program` summaries.

Boundaries inventoried (``nimble.retrace/v1``):

  * ``jit-static`` — each ``static_argnums``/``static_argnames`` param,
    classified by joining the provenance of every call-site argument
    across the whole program;
  * ``pallas-arg`` — ``pallas_call`` grid / BlockSpecs / out_shape /
    scratch_shapes / grid_spec expressions;
  * ``scan-carry`` — ``lax.scan`` carry *shapes* (plan-dependent carry
    values are traced and fine; plan-dependent ``zeros(...)`` shapes
    retrace), so only shape-forming calls inside the init are classified;
  * ``slot-target`` — a scratch-ref subscript inside a Pallas kernel
    whose index derives from ``program_id`` *arithmetic* is a trace-time
    slot schedule: the plan owns slot assignment (ROADMAP item 2), so a
    baked schedule is PLAN_DEPENDENT.  An index read out of a
    (scalar-prefetched) ref is runtime data and cuts the taint — that is
    exactly the demotion `kernels/relay_copy` performs.

``retrace.lock.json`` (``nimble.retrace_lock/v1``) pins the inventory
with line-free keys so line churn never invalidates it; PLAN_DEPENDENT
findings fire from classification alone — regenerating the lock cannot
launder a new hazard past the gate.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..jsonio import read_json_file, tag, write_json_file
from .callgraph import Program, module_name_of
from .context import FileContext

RETRACE_KIND = "retrace"
RETRACE_LOCK_KIND = "retrace_lock"

# -- the lattice -----------------------------------------------------------------

TOPOLOGY_STABLE = "TOPOLOGY_STABLE"
WINDOW_DEPENDENT = "WINDOW_DEPENDENT"
PLAN_DEPENDENT = "PLAN_DEPENDENT"

_ORDER = {TOPOLOGY_STABLE: 0, WINDOW_DEPENDENT: 1, PLAN_DEPENDENT: 2}


def join(a: str, b: str) -> str:
    """Least upper bound — plan-dependence absorbs everything below it."""
    return a if _ORDER[a] >= _ORDER[b] else b


# -- seeds -----------------------------------------------------------------------

#: callables whose return value IS the plan (or a plan artifact): the
#: Algorithm-1 solvers, the jitted planner entry points, the dataplane
#: chunk schedulers.  Matched by basename so wrappers inherit via the
#: interprocedural pass, not by listing.
PLAN_RETURNING = {
    "solve_mwu", "solve_direct", "solve_static_striping", "solve_degraded",
    "plan_from_flows", "apply_plan_fractions",
    "plan_flows", "plan_flows_batch", "quantize_chunks",
    "plan_chunks_jit", "plan_chunks_batch_jit",
    "solve_plans_batch", "plan_batch", "plan_from_counts", "plan_batched",
    "_plan",
}

#: identifier tokens (underscore-split, exact match) that seed a class
#: when no call-site evidence exists.  Deliberately exact: ``block_chunk``
#: (a block *size*) must not match ``chunks`` (a chunk *schedule*).
PLAN_TOKENS = {"plan", "plans", "flow", "flows", "chunks", "slots"}
WINDOW_TOKENS = {
    "window", "windows", "price", "prices", "telemetry",
    "demand", "demands", "load", "loads", "staleness",
}

#: attribute accesses that stay static under trace — reading shape
#: metadata off a plan-dependent array yields geometry, not plan
_METADATA_ATTRS = {"shape", "dtype", "ndim", "size"}

#: shape-forming calls whose *arguments* become trace-time shapes
_SHAPE_FORMING = {"zeros", "ones", "full", "empty", "arange"}

_PALLAS_BOUNDARY_KWARGS = (
    "grid", "in_specs", "out_specs", "out_shape", "scratch_shapes",
    "grid_spec",
)


def classify_name(name: str) -> str:
    tokens = set(name.lower().split("_"))
    if tokens & PLAN_TOKENS:
        return PLAN_DEPENDENT
    if tokens & WINDOW_TOKENS:
        return WINDOW_DEPENDENT
    return TOPOLOGY_STABLE


# -- sites -----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TraceSite:
    """One value flowing into one trace boundary."""

    kind: str        # jit-static | pallas-arg | scan-carry | slot-target
    path: str
    line: int
    function: str    # qualname of the function owning the boundary
    detail: str      # which value: "static:<param>" / "kwarg:<name>" / ...
    provenance: str
    note: str = ""

    def lock_key(self) -> str:
        """Line-free identity — line churn must not invalidate the lock."""
        return f"{self.kind}:{self.path}:{self.function}:{self.detail}"

    def to_json_obj(self) -> dict:
        return {
            "kind": self.kind,
            "path": self.path,
            "line": self.line,
            "function": self.function,
            "detail": self.detail,
            "provenance": self.provenance,
            "note": self.note,
        }


# -- interprocedural fixpoint ----------------------------------------------------

class ProvenanceAnalysis:
    """Bounded fixpoint: call-site args -> param provenance -> returns.

    Monotone over a finite 3-point lattice, so ≤ 8 sorted rounds is far
    past convergence for any real call chain in this tree; iteration is
    sorted everywhere so the result is bit-stable run to run.
    """

    MAX_ROUNDS = 8

    def __init__(self, program: Program):
        self.program = program
        #: qualname -> param -> joined call-site provenance
        self.param_prov: Dict[str, Dict[str, str]] = {}
        #: qualname -> return-value provenance
        self.ret_prov: Dict[str, str] = {}
        self.rounds = 0
        self._env_cache: Dict[str, Dict[str, str]] = {}
        for qual, summary in sorted(program.summaries.items()):
            self.param_prov[qual] = {}
            base = qual.rsplit(".", 1)[1]
            self.ret_prov[qual] = (
                PLAN_DEPENDENT if base in PLAN_RETURNING
                else classify_name(base)
            )

    # -- expression provenance --------------------------------------------------
    def param_provenance(self, qual: str, param: str) -> str:
        """Final class of a param: name seed ⊔ every call-site argument."""
        seeded = classify_name(param)
        return join(seeded, self.param_prov.get(qual, {}).get(
            param, TOPOLOGY_STABLE
        ))

    def _expr(self, ctx: FileContext, env: Dict[str, str],
              node: ast.AST) -> str:
        if isinstance(node, ast.Constant):
            return TOPOLOGY_STABLE
        if isinstance(node, ast.Name):
            return env.get(node.id, TOPOLOGY_STABLE)
        if isinstance(node, ast.Attribute):
            if node.attr in _METADATA_ATTRS:
                return TOPOLOGY_STABLE  # shape/dtype of anything is geometry
            return join(
                classify_name(node.attr), self._expr(ctx, env, node.value)
            )
        if isinstance(node, ast.Subscript):
            base = node.value
            if isinstance(base, ast.Name) and base.id.endswith("_ref"):
                # a ref read is runtime memory — the taint cut that makes
                # scalar-prefetched slot maps retrace-free
                return TOPOLOGY_STABLE
            return join(
                self._expr(ctx, env, base), self._expr(ctx, env, node.slice)
            )
        if isinstance(node, ast.Call):
            target = ctx.resolve(node.func)
            base = target.rsplit(".", 1)[-1] if target else ""
            if base in PLAN_RETURNING:
                return PLAN_DEPENDENT
            if target is not None:
                resolved = self.program.resolve_target(
                    target, module_name_of(ctx.path)
                )
                if resolved is not None:
                    return self.ret_prov.get(resolved, TOPOLOGY_STABLE)
            if base == "program_id":
                return TOPOLOGY_STABLE  # grid coordinate: shape-derived
            out = TOPOLOGY_STABLE
            for arg in node.args:
                out = join(out, self._expr(ctx, env, arg))
            for kw in node.keywords:
                out = join(out, self._expr(ctx, env, kw.value))
            return out
        if isinstance(node, ast.Lambda):
            return TOPOLOGY_STABLE  # a lambda value is code, not data
        out = TOPOLOGY_STABLE
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.expr, ast.keyword)):
                target = child.value if isinstance(child, ast.keyword) else child
                out = join(out, self._expr(ctx, env, target))
        return out

    # -- per-function environment -----------------------------------------------
    def _env_for(self, qual: str, cache: bool = False) -> Dict[str, str]:
        if cache and qual in self._env_cache:
            return self._env_cache[qual]
        ctx, node = self.program.nodes[qual]
        summary = self.program.summaries[qual]
        env: Dict[str, str] = {
            p: self.param_provenance(qual, p) for p in summary.params
        }
        # two forward passes in source order picks up loop-carried joins
        stmts = sorted(
            (
                n for n in ast.walk(node)
                if isinstance(n, (ast.Assign, ast.AnnAssign, ast.AugAssign,
                                  ast.For, ast.NamedExpr))
            ),
            key=lambda n: (n.lineno, n.col_offset),
        )
        for _ in range(2):
            for stmt in stmts:
                if isinstance(stmt, ast.For):
                    prov = self._expr(ctx, env, stmt.iter)
                    self._bind(env, stmt.target, prov)
                    continue
                value = stmt.value
                if value is None:
                    continue
                prov = self._expr(ctx, env, value)
                targets = (
                    stmt.targets if isinstance(stmt, ast.Assign)
                    else [stmt.target]
                )
                for t in targets:
                    self._bind(env, t, prov, augment=isinstance(
                        stmt, ast.AugAssign
                    ))
        if cache:
            self._env_cache[qual] = env
        return env

    def _bind(self, env: Dict[str, str], target: ast.AST, prov: str,
              augment: bool = False) -> None:
        if isinstance(target, ast.Name):
            old = env.get(target.id, TOPOLOGY_STABLE)
            env[target.id] = join(old, prov) if augment else join(
                prov, old if target.id in env else TOPOLOGY_STABLE
            )
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(env, elt, prov, augment)
        elif isinstance(target, ast.Starred):
            self._bind(env, target.value, prov, augment)

    # -- fixpoint ---------------------------------------------------------------
    def run(self) -> "ProvenanceAnalysis":
        for self.rounds in range(1, self.MAX_ROUNDS + 1):
            if not self._round():
                break
        self._env_cache.clear()
        return self

    def _round(self) -> bool:
        changed = False
        for qual in sorted(self.program.nodes):
            ctx, node = self.program.nodes[qual]
            summary = self.program.summaries[qual]
            env = self._env_for(qual)
            module = summary.module
            # returns: only this function's own return statements
            ret = self.ret_prov[qual]
            for sub in ast.walk(node):
                if isinstance(sub, ast.Return) and sub.value is not None:
                    if ctx.enclosing_function(sub) is node:
                        ret = join(ret, self._expr(ctx, env, sub.value))
                elif isinstance(sub, ast.Call):
                    changed |= self._flow_call(ctx, env, module, sub)
            if ret != self.ret_prov[qual]:
                self.ret_prov[qual] = ret
                changed = True
        return changed

    def _flow_call(self, ctx: FileContext, env: Dict[str, str],
                   module: str, call: ast.Call) -> bool:
        target = ctx.resolve(call.func)
        if target is None:
            return False
        resolved = self.program.resolve_target(target, module)
        if resolved is None:
            return False
        callee = self.program.summaries[resolved]
        params = list(callee.params)
        offset = 0
        if params and params[0] in ("self", "cls") and isinstance(
            call.func, ast.Attribute
        ):
            offset = 1
        changed = False
        slots = self.param_prov[resolved]
        for i, arg in enumerate(call.args):
            if isinstance(arg, ast.Starred):
                continue
            idx = i + offset
            if idx >= len(params):
                break
            changed |= self._join_param(
                slots, params[idx], self._expr(ctx, env, arg)
            )
        for kw in call.keywords:
            if kw.arg is None or kw.arg not in params:
                continue
            changed |= self._join_param(
                slots, kw.arg, self._expr(ctx, env, kw.value)
            )
        return changed

    @staticmethod
    def _join_param(slots: Dict[str, str], param: str, prov: str) -> bool:
        old = slots.get(param, TOPOLOGY_STABLE)
        new = join(old, prov)
        if new != old:
            slots[param] = new
            return True
        return False

    # -- boundary extraction ----------------------------------------------------
    def trace_sites(self) -> List[TraceSite]:
        sites: List[TraceSite] = []
        node_to_qual = {
            node: qual for qual, (_, node) in self.program.nodes.items()
        }
        for ctx in self.program.contexts:
            module = module_name_of(ctx.path)
            for info in ctx.jit_functions:
                qual = node_to_qual.get(info.node)
                if qual is None:
                    qual = f"{module}.{info.name}"
                if info.kind == "jit" and info.static_params:
                    sites.extend(self._jit_sites(ctx, info, qual))
                elif info.kind == "pallas":
                    sites.extend(self._slot_sites(ctx, info, qual))
            sites.extend(self._call_boundary_sites(ctx, module, node_to_qual))
        dedup: Dict[str, TraceSite] = {}
        for s in sorted(sites, key=lambda s: (s.path, s.line, s.detail)):
            dedup.setdefault(s.lock_key(), s)
        return sorted(
            dedup.values(), key=lambda s: (s.path, s.line, s.detail)
        )

    def _jit_sites(self, ctx, info, qual) -> Iterable[TraceSite]:
        for p in sorted(info.static_params):
            prov = self.param_provenance(qual, p)
            yield TraceSite(
                "jit-static", ctx.path, info.node.lineno, qual,
                f"static:{p}", prov,
                "every distinct value recompiles; plan-dependent statics "
                "defeat hot swap" if prov == PLAN_DEPENDENT else
                "recompiles per distinct value",
            )

    def _call_boundary_sites(
        self, ctx: FileContext, module: str, node_to_qual: Dict
    ) -> Iterable[TraceSite]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            target = ctx.resolve(node.func)
            if target is None:
                continue
            fn = ctx.enclosing_function(node)
            qual = node_to_qual.get(fn)
            if qual is None:
                qual = f"{module}.<module>"
            env = (
                self._env_for(qual, cache=True)
                if qual in self.program.nodes else {}
            )
            if target.endswith("pallas_call"):
                for kw in node.keywords:
                    if kw.arg not in _PALLAS_BOUNDARY_KWARGS:
                        continue
                    prov = self._expr(ctx, env, kw.value)
                    yield TraceSite(
                        "pallas-arg", ctx.path, kw.value.lineno, qual,
                        f"kwarg:{kw.arg}", prov,
                        "kernel re-lowers when this changes",
                    )
            elif target in ("jax.lax.scan", "lax.scan"):
                init = None
                if len(node.args) >= 2:
                    init = node.args[1]
                for kw in node.keywords:
                    if kw.arg == "init":
                        init = kw.value
                if init is None:
                    continue
                prov = self._carry_shape_prov(ctx, env, init)
                yield TraceSite(
                    "scan-carry", ctx.path, init.lineno, qual, "carry",
                    prov,
                    "carry *shape* provenance (values are traced and free)",
                )

    def _carry_shape_prov(self, ctx, env, init: ast.AST) -> str:
        """Plan-dependent carry values are fine; plan-dependent carry
        *shapes* retrace — classify only shape-forming call arguments."""
        out = TOPOLOGY_STABLE
        for sub in ast.walk(init):
            if not isinstance(sub, ast.Call):
                continue
            target = ctx.resolve(sub.func) or ""
            if target.rsplit(".", 1)[-1] not in _SHAPE_FORMING:
                continue
            for arg in sub.args:
                out = join(out, self._expr(ctx, env, arg))
            for kw in sub.keywords:
                if kw.arg == "shape":
                    out = join(out, self._expr(ctx, env, kw.value))
        return out

    # -- slot targets ------------------------------------------------------------
    def _slot_sites(self, ctx, info, qual) -> Iterable[TraceSite]:
        params = {
            a.arg for a in getattr(info.node, "args").posonlyargs
            + getattr(info.node, "args").args
        } if hasattr(info.node, "args") else set()
        # local one-hop defs: name -> index classification of its RHS
        local: Dict[str, str] = {}
        for sub in ast.walk(info.node):
            if isinstance(sub, ast.Assign) and len(sub.targets) == 1 and (
                isinstance(sub.targets[0], ast.Name)
            ):
                local[sub.targets[0].id] = self._index_class(
                    ctx, params, local, sub.value
                )
        for sub in ast.walk(info.node):
            if not isinstance(sub, ast.Subscript):
                continue
            base = sub.value
            if not (isinstance(base, ast.Name) and base.id in params):
                continue
            cls = self._index_class(ctx, params, local, sub.slice)
            if cls == "const":
                continue  # x_ref[...] block reads are not slot targets
            if cls == "ref":
                prov, note = TOPOLOGY_STABLE, (
                    "slot read from a ref — runtime data, retargetable "
                    "without retrace"
                )
            elif cls == "pid-arith":
                prov, note = PLAN_DEPENDENT, (
                    "slot schedule baked from program_id arithmetic at "
                    "trace time — the plan owns slot assignment "
                    "(ROADMAP item 2); demote to a scalar-prefetched "
                    "slot map"
                )
            else:  # bare program_id: the grid coordinate itself
                prov, note = TOPOLOGY_STABLE, (
                    "indexed by the raw grid coordinate"
                )
            yield TraceSite(
                "slot-target", ctx.path, sub.lineno, qual,
                f"slot:{base.id}", prov, note,
            )

    def _index_class(self, ctx, params: Set[str], local: Dict[str, str],
                     node: ast.AST) -> str:
        """'ref' | 'pid-arith' | 'pid' | 'const' for a subscript index."""
        if isinstance(node, ast.Name):
            return local.get(node.id, "const")
        if isinstance(node, ast.Subscript):
            base = node.value
            if isinstance(base, ast.Name) and base.id in params:
                return "ref"
            return self._index_class(ctx, params, local, base)
        if isinstance(node, ast.Call):
            target = ctx.resolve(node.func) or ""
            if target.rsplit(".", 1)[-1] == "program_id":
                return "pid"
            classes = [
                self._index_class(ctx, params, local, a) for a in node.args
            ]
            return _strongest(classes)
        if isinstance(node, (ast.BinOp, ast.UnaryOp, ast.BoolOp,
                             ast.Compare, ast.IfExp, ast.Tuple)):
            children = [
                c for c in ast.iter_child_nodes(node)
                if isinstance(c, ast.expr)
            ]
            classes = [
                self._index_class(ctx, params, local, c) for c in children
            ]
            strongest = _strongest(classes)
            if strongest == "pid" and isinstance(node, ast.BinOp):
                return "pid-arith"  # arithmetic on the grid coordinate
            return strongest
        return "const"


_INDEX_ORDER = {"const": 0, "pid": 1, "pid-arith": 2, "ref": 3}


def _strongest(classes: Iterable[str]) -> str:
    best = "const"
    for c in classes:
        if _INDEX_ORDER[c] > _INDEX_ORDER[best]:
            best = c
    return best


# -- inventory + lock ------------------------------------------------------------

def analyze_program(program: Program) -> ProvenanceAnalysis:
    return ProvenanceAnalysis(program).run()


def build_retrace_inventory(
    program: Program, analysis: Optional[ProvenanceAnalysis] = None
) -> dict:
    """The ``nimble.retrace/v1`` site inventory — the work-list the
    zero-retrace PR consumes."""
    analysis = analysis or analyze_program(program)
    sites = analysis.trace_sites()
    counts = {TOPOLOGY_STABLE: 0, WINDOW_DEPENDENT: 0, PLAN_DEPENDENT: 0}
    for s in sites:
        counts[s.provenance] += 1
    return tag(RETRACE_KIND, {
        "files": len(program.contexts),
        "sites": [s.to_json_obj() for s in sites],
        "counts": counts,
        "rounds": analysis.rounds,
    })


def default_retrace_lock_path() -> str:
    return os.path.join(os.path.dirname(__file__), "retrace.lock.json")


def generate_retrace_lock_obj(
    program: Program, analysis: Optional[ProvenanceAnalysis] = None
) -> dict:
    analysis = analysis or analyze_program(program)
    entries = {
        s.lock_key(): s.provenance for s in analysis.trace_sites()
    }
    return tag(RETRACE_LOCK_KIND, {
        "entries": {k: entries[k] for k in sorted(entries)},
    })


def write_retrace_lock(
    program: Program, path: str,
    analysis: Optional[ProvenanceAnalysis] = None,
) -> dict:
    obj = generate_retrace_lock_obj(program, analysis)
    write_json_file(path, obj)
    return obj


def retrace_lock_is_fresh(
    path: str, program: Program,
    analysis: Optional[ProvenanceAnalysis] = None,
) -> bool:
    if not os.path.exists(path):
        return False
    committed = read_json_file(path)
    return committed == generate_retrace_lock_obj(program, analysis)
