"""Units lattice: bytes | bytes_per_s | fraction | price | windows.

The cost/fabric stack moves five physically different quantities through
plain floats: raw/effective **bytes** (demands, committed loads),
**bytes_per_s** capacities (link caps, ``relay_cap``/``inject_cap``),
dimensionless **fractions** (``hysteresis``, ``rail_relay_eff``, EMA
weights), congestion **prices** (the fabric arbiter's export), and
**windows** (telemetry window counters, ``half_life`` recency).  Nothing
in the type system separates them, and the ledger contract is strict:
``FabricState.commit`` takes *effective bytes per resource* — committing
a fraction or a price there corrupts every other tenant's costs
silently.

This analysis seeds units from the explicitly annotated signatures below
(``core/cost.py``, ``core/mcf.py``, ``fabric/state.py``) plus identifier
conventions (``*_bytes``, ``*_cap``, ``*_eff``, ``price``, ``window``,
``half_life``), derives function return units through a short
interprocedural fixpoint over the :class:`~repro.analysis.callgraph.Program`,
and flags **unit mixing**:

  * ``+`` / ``-`` / comparison between two different known units;
  * a call-site argument whose unit contradicts the callee's param unit.

``*`` and ``/`` legitimately *change* units, so they never flag; instead
the algebra is modeled where it is unambiguous — a fraction scales
without changing the other operand's unit, ``bytes / bytes`` is a
fraction, a bare numeric literal is unitless.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

from ..jsonio import tag
from .callgraph import Program, module_name_of
from .context import FileContext

UNITS_KIND = "units"

BYTES = "bytes"
BYTES_PER_S = "bytes_per_s"
FRACTION = "fraction"
PRICE = "price"
WINDOWS = "windows"

UNITS = (BYTES, BYTES_PER_S, FRACTION, PRICE, WINDOWS)

#: explicit signature seeds: qualname -> {param: unit} (+ "return")
UNIT_SIGNATURES: Dict[str, Dict[str, str]] = {
    # core/cost.py
    "repro.core.cost.ResourceModel.charges": {"f": BYTES},
    "repro.core.cost.ResourceModel.resource_cost": {"load": BYTES},
    "repro.core.cost.ResourceModel.path_cost": {"msg_bytes": BYTES},
    "repro.core.cost.ResourceModel.smooth_loads": {
        "prev": BYTES, "now": BYTES, "return": BYTES,
    },
    "repro.core.cost.capacity_normalized": {
        "loads": BYTES, "return": FRACTION,
    },
    # core/mcf.py
    "repro.core.mcf.solve_mwu": {
        "lam": FRACTION, "eps": BYTES,
        "prev_loads": BYTES, "ext_loads": BYTES,
    },
    "repro.core.mcf._quantized_fraction": {"lam": FRACTION, "eps": BYTES},
    "repro.core.mcf.solve_degraded": {"prev_loads": BYTES,
                                      "ext_loads": BYTES},
    # fabric/state.py — the ledger contract the module docstring names
    "repro.fabric.state.FabricState.commit": {
        "resource_bytes": BYTES, "window": WINDOWS,
    },
    # fabric/arbiter.py: the exported "prices" are *denominated in
    # weighted effective bytes* ("external load over tenant weight" —
    # prices_for docstring), which is why solve_mwu prices ext_loads
    # as-is.  The PRICE unit is reserved for genuinely per-unit prices.
    "repro.fabric.arbiter.FabricArbiter.prices_for": {"return": BYTES},
    "repro.fabric.state.FabricState.decay_factor": {
        "half_life": WINDOWS, "return": FRACTION,
    },
    "repro.fabric.state.FabricState.drain_time_s": {"loads": BYTES},
}

#: attribute-name units (CostModel fields and friends)
ATTR_UNITS: Dict[str, str] = {
    "split_threshold": BYTES,
    "hop_setup_bytes": BYTES,
    "hysteresis": FRACTION,
    "relay_cap": BYTES_PER_S,
    "inject_cap": BYTES_PER_S,
    "rail_relay_eff": FRACTION,
    "capacity": BYTES_PER_S,
    "half_life": WINDOWS,
    "price_decay": WINDOWS,
}

#: metadata attrs carry no unit and block suffix matching
_NO_UNIT_ATTRS = {"shape", "dtype", "ndim", "size"}

#: unit-preserving casts/selections (same quantity, new container)
_CAST_CALLS = {
    "int", "float", "abs", "round",
    "asarray", "array", "copy", "minimum", "maximum", "min", "max",
    "where", "clip", "floor", "ceil", "sum",
}


def unit_of_name(name: str) -> Optional[str]:
    """Identifier-convention unit (params, locals, attrs)."""
    if name in ATTR_UNITS:
        return ATTR_UNITS[name]
    low = name.lower()
    tokens = low.split("_")
    if low.endswith("_bytes") or low == "bytes":
        return BYTES
    if low.endswith("_cap"):
        return BYTES_PER_S
    if low.endswith("_frac") or low.endswith("_eff") or low == "fraction":
        return FRACTION
    if "price" in tokens or "prices" in tokens:
        return PRICE
    if low in ("window", "windows") or low.endswith("_window"):
        return WINDOWS
    return None


@dataclasses.dataclass(frozen=True)
class UnitMix:
    path: str
    line: int
    col: int
    function: str
    message: str


class UnitsAnalysis:
    """Seed -> propagate return units -> flag mixing at use sites."""

    MAX_ROUNDS = 4

    def __init__(self, program: Program,
                 signatures: Optional[Dict[str, Dict[str, str]]] = None):
        self.program = program
        self.signatures = dict(
            UNIT_SIGNATURES if signatures is None else signatures
        )
        #: qualname -> derived return unit
        self.ret_unit: Dict[str, Optional[str]] = {}
        self.mixes: List[UnitMix] = []

    # -- seeds ------------------------------------------------------------------
    def param_unit(self, qual: str, param: str) -> Optional[str]:
        sig = self.signatures.get(qual)
        if sig and param in sig:
            return sig[param]
        return unit_of_name(param)

    def _seeded_return(self, qual: str) -> Optional[str]:
        sig = self.signatures.get(qual)
        if sig and "return" in sig:
            return sig["return"]
        return unit_of_name(qual.rsplit(".", 1)[1])

    # -- expression units -------------------------------------------------------
    def _expr(self, ctx: FileContext, env: Dict[str, Optional[str]],
              node: ast.AST, sink: Optional[List[UnitMix]] = None,
              function: str = "") -> Optional[str]:
        if isinstance(node, ast.Constant):
            return None  # bare literals are unitless scalars
        if isinstance(node, ast.Name):
            if node.id in env:
                return env[node.id]
            return unit_of_name(node.id)
        if isinstance(node, ast.Attribute):
            if node.attr in _NO_UNIT_ATTRS:
                return None
            return unit_of_name(node.attr)
        if isinstance(node, ast.BinOp):
            return self._binop(ctx, env, node, sink, function)
        if isinstance(node, ast.UnaryOp):
            return self._expr(ctx, env, node.operand, sink, function)
        if isinstance(node, ast.Call):
            return self._call_unit(ctx, env, node, sink, function)
        if isinstance(node, ast.IfExp):
            # `x if cond else None` keeps x's unit — None is absence,
            # not a differently-dimensioned value
            units = {
                self._expr(ctx, env, branch, sink, function)
                for branch in (node.body, node.orelse)
                if not (
                    isinstance(branch, ast.Constant)
                    and branch.value is None
                )
            }
            return units.pop() if len(units) == 1 else None
        if isinstance(node, ast.Subscript):
            return self._expr(ctx, env, node.value, sink, function)
        return None

    def _binop(self, ctx, env, node: ast.BinOp, sink, function):
        left = self._expr(ctx, env, node.left, sink, function)
        right = self._expr(ctx, env, node.right, sink, function)
        if isinstance(node.op, (ast.Add, ast.Sub)):
            if left and right and left != right:
                self._mix(ctx, node, function, sink,
                          f"{left} {_op_str(node.op)} {right}",
                          node.left, node.right)
                return None
            return left or right
        if isinstance(node.op, ast.Mult):
            # a fraction (or unitless scalar) scales without changing units
            if left == FRACTION or left is None:
                return right
            if right == FRACTION or right is None:
                return left
            return None
        if isinstance(node.op, (ast.Div, ast.FloorDiv)):
            if left == BYTES and right == BYTES:
                return FRACTION
            if right == FRACTION or right is None:
                return left
            return None
        return None

    def _call_unit(self, ctx, env, node: ast.Call, sink, function):
        target = ctx.resolve(node.func)
        base = target.rsplit(".", 1)[-1] if target else ""
        resolved = (
            self.program.resolve_target(target, module_name_of(ctx.path))
            if target else None
        )
        # check args against the callee's seeded/derived param units
        if resolved is not None and sink is not None:
            self._check_call_args(ctx, env, node, resolved, sink, function)
        if resolved is not None:
            derived = self.ret_unit.get(resolved)
            if derived is not None:
                return derived
        if base in _CAST_CALLS:
            units = {
                u for u in (
                    self._expr(ctx, env, a, sink, function)
                    for a in node.args
                ) if u is not None
            }
            return units.pop() if len(units) == 1 else None
        return None

    def _check_call_args(self, ctx, env, call: ast.Call, callee_qual: str,
                         sink: List[UnitMix], function: str) -> None:
        callee = self.program.summaries.get(callee_qual)
        if callee is None:
            return
        params = list(callee.params)
        offset = 0
        if params and params[0] in ("self", "cls") and isinstance(
            call.func, ast.Attribute
        ):
            offset = 1
        pairs: List[Tuple[str, ast.AST]] = []
        for i, arg in enumerate(call.args):
            if isinstance(arg, ast.Starred):
                continue
            idx = i + offset
            if idx < len(params):
                pairs.append((params[idx], arg))
        for kw in call.keywords:
            if kw.arg is not None and kw.arg in params:
                pairs.append((kw.arg, kw.value))
        for param, arg in pairs:
            expected = self.param_unit(callee_qual, param)
            if expected is None:
                continue
            got = self._expr(ctx, env, arg, None, function)
            if got is not None and got != expected:
                sink.append(UnitMix(
                    ctx.path, arg.lineno, arg.col_offset, function,
                    f"passes {got} where `{callee_qual}` expects "
                    f"{expected} for param `{param}`",
                ))

    def _mix(self, ctx, node, function, sink, desc, left, right):
        if sink is None:
            return
        sink.append(UnitMix(
            ctx.path, node.lineno, node.col_offset, function,
            f"mixes units: {desc} "
            f"(`{_short(left)}` vs `{_short(right)}`)",
        ))

    # -- per-function env + checks ----------------------------------------------
    def _env_for(self, qual: str) -> Tuple[FileContext, ast.AST,
                                           Dict[str, Optional[str]]]:
        ctx, node = self.program.nodes[qual]
        summary = self.program.summaries[qual]
        env: Dict[str, Optional[str]] = {
            p: self.param_unit(qual, p) for p in summary.params
        }
        stmts = sorted(
            (
                n for n in ast.walk(node)
                if isinstance(n, (ast.Assign, ast.AnnAssign, ast.AugAssign))
            ),
            key=lambda n: (n.lineno, n.col_offset),
        )
        for stmt in stmts:
            if stmt.value is None:
                continue
            unit = self._expr(ctx, env, stmt.value)
            targets = (
                stmt.targets if isinstance(stmt, ast.Assign)
                else [stmt.target]
            )
            for t in targets:
                if isinstance(t, ast.Name):
                    named = unit_of_name(t.id)
                    env[t.id] = unit if unit is not None else named
        return ctx, node, env

    # -- fixpoint + sweep -------------------------------------------------------
    def run(self) -> "UnitsAnalysis":
        for qual in sorted(self.program.summaries):
            self.ret_unit[qual] = self._seeded_return(qual)
        for _ in range(self.MAX_ROUNDS):
            if not self._round():
                break
        self._sweep()
        return self

    def _round(self) -> bool:
        changed = False
        for qual in sorted(self.program.nodes):
            if self.ret_unit.get(qual) is not None:
                continue
            ctx, node, env = self._env_for(qual)
            units = set()
            for sub in ast.walk(node):
                if isinstance(sub, ast.Return) and sub.value is not None:
                    if ctx.enclosing_function(sub) is node:
                        units.add(self._expr(ctx, env, sub.value))
            units.discard(None)
            if len(units) == 1:
                self.ret_unit[qual] = units.pop()
                changed = True
        return changed

    def _sweep(self) -> None:
        """Final pass: flag mixing at every +, -, comparison, call site."""
        sink: List[UnitMix] = []
        for qual in sorted(self.program.nodes):
            ctx, node, env = self._env_for(qual)
            for sub in ast.walk(node):
                if isinstance(sub, ast.BinOp) and isinstance(
                    sub.op, (ast.Add, ast.Sub)
                ):
                    self._binop(ctx, env, sub, sink, qual)
                elif isinstance(sub, ast.Compare):
                    operands = [sub.left, *sub.comparators]
                    if any(
                        isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                        for op in sub.ops
                    ):
                        continue
                    units = [
                        self._expr(ctx, env, o, None, qual)
                        for o in operands
                    ]
                    known = [u for u in units if u is not None]
                    if len(set(known)) > 1:
                        sink.append(UnitMix(
                            ctx.path, sub.lineno, sub.col_offset, qual,
                            f"compares {' vs '.join(sorted(set(known)))} — "
                            "different units never order meaningfully",
                        ))
                elif isinstance(sub, ast.Call):
                    self._call_unit(ctx, env, sub, sink, qual)
        seen = set()
        for m in sorted(sink, key=lambda m: (m.path, m.line, m.message)):
            key = (m.path, m.function, m.message)
            if key not in seen:
                seen.add(key)
                self.mixes.append(m)


def _op_str(op: ast.AST) -> str:
    return "+" if isinstance(op, ast.Add) else "-"


def _short(node: ast.AST, limit: int = 40) -> str:
    try:
        text = ast.unparse(node)
    except Exception:  # pragma: no cover - unparse is py3.9+ and total
        text = type(node).__name__
    return text if len(text) <= limit else text[: limit - 3] + "..."


def analyze_units(program: Program) -> UnitsAnalysis:
    return UnitsAnalysis(program).run()


def build_units_inventory(
    program: Program, analysis: Optional[UnitsAnalysis] = None
) -> dict:
    """The ``nimble.units/v1`` inventory: seeds, derived returns, mixes."""
    analysis = analysis or analyze_units(program)
    derived = {
        q: u for q, u in sorted(analysis.ret_unit.items()) if u is not None
    }
    return tag(UNITS_KIND, {
        "files": len(program.contexts),
        "seeds": len(analysis.signatures),
        "derived_returns": derived,
        "mixes": [
            {
                "path": m.path, "line": m.line, "function": m.function,
                "message": m.message,
            }
            for m in analysis.mixes
        ],
    })
