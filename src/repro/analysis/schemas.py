"""Schema-site collection and the ``schemas.lock.json`` manifest.

The schema-discipline rule and the lock generator share one scanner:
:func:`collect_schema_sites` finds, per file,

  * every ``nimble.<kind>/vN`` string literal (validators, docstrings —
    stale version references in prose are staleness too), strict-parsed
    through :func:`repro.jsonio.parse_schema_id`;
  * every ``repro.jsonio.tag(kind, payload, version=...)`` call, with the
    kind seen through module-level string constants and the payload keys
    statically recovered from dict literals or ``dataclasses.asdict(self)``
    against the enclosing dataclass's fields.

The lock (``schemas.lock.json``, a ``nimble.schemas_lock/v1`` record) is
the committed manifest of every kind emitted under ``src/repro`` with its
version and the union of statically-known emitted keys.  The rule checks
call sites against it (a new key without a version bump + regeneration is
a finding); ``--check-lock`` / the smoke ``static_gate`` check that
regenerating it is a no-op, so key *removals* fail closed too.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, FrozenSet, Iterable, List, Optional

from ..jsonio import parse_schema_id, read_json_file, tag, write_json_file
from .context import FileContext

#: loose detection net for schema-id-shaped strings; strict validation is
#: ``parse_schema_id`` so near-misses surface as findings, not silence
SCHEMA_LITERAL_RE = re.compile(r"nimble\.[A-Za-z0-9_.-]*/v[A-Za-z0-9_.-]*")

LOCK_KIND = "schemas_lock"


@dataclasses.dataclass(frozen=True)
class SchemaSite:
    """One place a schema id is minted or referenced."""

    path: str
    line: int
    col: int
    kind: Optional[str]            # None when not statically resolvable
    version: Optional[int]         # None when not statically resolvable
    keys: Optional[FrozenSet[str]]  # None when payload keys are unknown
    source: str                    # "literal" | "tag"
    raw: str                       # the literal text / call description
    error: Optional[str] = None    # strict-parse failure, if any


def collect_schema_sites(ctx: FileContext) -> List[SchemaSite]:
    sites: List[SchemaSite] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            sites.extend(_literal_sites(ctx, node))
        elif isinstance(node, ast.Call) and _is_tag_call(ctx, node):
            sites.append(_tag_site(ctx, node))
    return sites


def _literal_sites(
    ctx: FileContext, node: ast.Constant
) -> Iterable[SchemaSite]:
    for m in SCHEMA_LITERAL_RE.finditer(node.value):
        raw = m.group(0)
        try:
            kind, version = parse_schema_id(raw)
            err = None
        except ValueError as e:
            kind = version = None
            err = str(e)
        yield SchemaSite(
            ctx.path, node.lineno, node.col_offset, kind, version,
            None, "literal", raw, err,
        )


def _is_tag_call(ctx: FileContext, call: ast.Call) -> bool:
    target = ctx.resolve(call.func)
    return target is not None and (
        target.endswith("jsonio.tag") or target == "jsonio.tag"
    )


def _tag_site(ctx: FileContext, call: ast.Call) -> SchemaSite:
    kind = ctx.string_value(call.args[0]) if call.args else None
    version: Optional[int] = 1
    if len(call.args) >= 3:
        version = _const_int(call.args[2])
    for kw in call.keywords:
        if kw.arg == "version":
            version = _const_int(kw.value)
    error = None
    if kind is None:
        error = "tag() kind is not a static string"
    elif version is None:
        error = f"tag({kind!r}) version is not a static integer"
    keys = _payload_keys(ctx, call) if kind is not None else None
    return SchemaSite(
        ctx.path, call.lineno, call.col_offset, kind, version, keys,
        "tag", f"tag({kind!r})", error,
    )


def _const_int(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) and (
        not isinstance(node.value, bool)
    ):
        return node.value
    return None


def _payload_keys(
    ctx: FileContext, call: ast.Call
) -> Optional[FrozenSet[str]]:
    if len(call.args) < 2:
        return None
    payload = call.args[1]
    if isinstance(payload, ast.Dict):
        keys: List[str] = []
        for k in payload.keys:
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                keys.append(k.value)
            else:
                return None  # **splat or computed key — keys unknown
        return frozenset(keys)
    if isinstance(payload, ast.Call):
        target = ctx.resolve(payload.func)
        if target in ("dataclasses.asdict", "asdict") and payload.args:
            arg = payload.args[0]
            if isinstance(arg, ast.Name) and arg.id == "self":
                cls = ctx.enclosing_class(call)
                if cls is not None and cls.name in ctx.dataclasses:
                    return frozenset(ctx.dataclasses[cls.name].fields)
    return None


# -- lock generation / freshness -------------------------------------------------

def generate_lock_obj(contexts: Iterable[FileContext]) -> dict:
    """Scan ``contexts`` into a ``nimble.schemas_lock/v1`` manifest."""
    kinds: Dict[str, dict] = {}
    for ctx in contexts:
        for site in collect_schema_sites(ctx):
            if site.kind is None or site.version is None:
                continue  # malformed sites are rule findings, not lock input
            entry = kinds.setdefault(
                site.kind,
                {"version": site.version, "keys": None, "sites": 0},
            )
            entry["sites"] += 1
            entry["version"] = max(entry["version"], site.version)
            if site.keys is not None:
                known = set(entry["keys"] or [])
                entry["keys"] = sorted(known | site.keys)
    return tag(LOCK_KIND, {"kinds": {k: kinds[k] for k in sorted(kinds)}})


def write_lock(contexts: Iterable[FileContext], path: str) -> dict:
    obj = generate_lock_obj(contexts)
    write_json_file(path, obj)
    return obj


def load_lock(path: str) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    return read_json_file(path)


def lock_is_fresh(path: str, contexts: Iterable[FileContext]) -> bool:
    """True iff regenerating the lock from ``contexts`` is a no-op."""
    committed = load_lock(path)
    if committed is None:
        return False
    return _normalize(committed) == _normalize(generate_lock_obj(contexts))


def _normalize(obj):
    if isinstance(obj, dict):
        return {k: _normalize(obj[k]) for k in sorted(obj)}
    if isinstance(obj, list):
        return [_normalize(x) for x in obj]
    return obj
