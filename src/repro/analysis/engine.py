"""Lint engine: file discovery, suppressions, baseline, report.

The engine owns everything that is not rule logic:

  * walking roots for ``.py`` files and building one
    :class:`~repro.analysis.context.FileContext` per file;
  * inline suppressions — ``# nimble: ignore[<rule-id>] -- reason`` on
    the flagged line or the comment line directly above it.  The reason is
    mandatory: a suppression without one (or naming an unknown rule, or
    suppressing nothing) is itself a finding (rule id ``suppression``),
    so every grandfathered violation carries a written justification;
  * the committed baseline (``baseline.json``): findings matching a
    baseline entry by ``(rule, path, message)`` — line numbers churn —
    are reported as *baselined*, not failures.  The ``src/`` baseline
    ships empty and should stay that way;
  * the ``nimble.lint/v1`` report through :mod:`repro.jsonio`.
"""

from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Protocol, Sequence, Tuple

from ..jsonio import read_json_file, tag, write_json_file
from .context import FileContext, build_context

#: inline suppression: ``# nimble: ignore[<rule-a>,<rule-b>] -- why``
SUPPRESS_RE = re.compile(
    r"#\s*nimble:\s*ignore\[(?P<rules>[a-z0-9_,\s-]*)\]"
    r"(?:\s*--\s*(?P<reason>.*\S))?\s*$"
)

LINT_KIND = "lint"
BASELINE_KIND = "lint_baseline"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def key(self) -> Tuple[str, str, str]:
        """Baseline identity — line/col churn must not invalidate entries."""
        return (self.rule, self.path, self.message)

    def to_json_obj(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
        }

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


class Rule(Protocol):
    """A lint rule: stateless check over one resolved file context."""

    rule_id: str
    description: str

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        """Yield findings for ``ctx`` (relative paths, 1-based lines)."""
        ...


@dataclasses.dataclass
class Suppression:
    line: int              # line the comment sits on (1-based)
    rules: Tuple[str, ...]
    reason: str
    used: bool = False


def parse_suppressions(source: str) -> List[Suppression]:
    out: List[Suppression] = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = tuple(
            r.strip() for r in m.group("rules").split(",") if r.strip()
        )
        out.append(Suppression(i, rules, (m.group("reason") or "").strip()))
    return out


def _comment_only(line_text: str) -> bool:
    stripped = line_text.strip()
    return stripped.startswith("#")


@dataclasses.dataclass
class AnalysisReport:
    """Aggregate result of one engine run."""

    root: str
    files: int
    findings: List[Finding]              # live (not suppressed/baselined)
    suppressed: List[Finding]
    baselined: List[Finding]
    counts: Dict[str, int]

    @property
    def clean(self) -> bool:
        return not self.findings

    def to_json_obj(self) -> dict:
        return tag(LINT_KIND, {
            "root": self.root,
            "files": self.files,
            "clean": self.clean,
            "counts": {k: self.counts[k] for k in sorted(self.counts)},
            "findings": [f.to_json_obj() for f in self.findings],
            "suppressed": len(self.suppressed),
            "baselined": len(self.baselined),
        })


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(__file__), "baseline.json")


def default_lock_path() -> str:
    return os.path.join(os.path.dirname(__file__), "schemas.lock.json")


def load_baseline(path: Optional[str] = None) -> List[Tuple[str, str, str]]:
    """Baseline entries as ``(rule, path, message)`` keys (missing file =
    empty baseline)."""
    path = path or default_baseline_path()
    if not os.path.exists(path):
        return []
    obj = read_json_file(path)
    entries = obj.get("entries", [])
    return [(e["rule"], e["path"], e["message"]) for e in entries]


def write_baseline(findings: Sequence[Finding], path: str) -> None:
    """Write ``findings`` as a fresh baseline (``--update-baseline``)."""
    write_json_file(path, tag(BASELINE_KIND, {
        "entries": [
            {"rule": f.rule, "path": f.path, "message": f.message}
            for f in sorted(findings, key=lambda f: f.key())
        ],
    }))


class AnalysisEngine:
    """Run a rule set over a file set and classify the findings."""

    def __init__(
        self,
        rules: Sequence[Rule],
        baseline: Optional[Sequence[Tuple[str, str, str]]] = None,
    ):
        self.rules = list(rules)
        self.rule_ids = {r.rule_id for r in self.rules} | {"suppression"}
        self.baseline = set(baseline or [])

    # -- per-file --------------------------------------------------------------
    def check_file(self, ctx: FileContext) -> List[Finding]:
        """All raw findings for one file, suppression hygiene included."""
        findings: List[Finding] = []
        for rule in self.rules:
            findings.extend(rule.check(ctx))
        suppressions = parse_suppressions(ctx.source)
        live = self._apply_suppressions(ctx, findings, suppressions)
        live.extend(self._suppression_hygiene(ctx, suppressions))
        return live

    def _apply_suppressions(
        self,
        ctx: FileContext,
        findings: List[Finding],
        suppressions: List[Suppression],
    ) -> List[Finding]:
        by_line: Dict[int, Suppression] = {s.line: s for s in suppressions}
        live: List[Finding] = []
        for f in findings:
            sup = by_line.get(f.line)
            if sup is None:
                above = by_line.get(f.line - 1)
                if above is not None and _comment_only(
                    ctx.lines[above.line - 1]
                ):
                    sup = above
            if sup is not None and f.rule in sup.rules and sup.reason:
                sup.used = True
                live.append(dataclasses.replace(f, rule=f"~{f.rule}"))
            else:
                live.append(f)
        return live

    def _suppression_hygiene(
        self, ctx: FileContext, suppressions: List[Suppression]
    ) -> List[Finding]:
        out: List[Finding] = []
        for s in suppressions:
            if not s.rules:
                out.append(Finding(
                    "suppression", ctx.path, s.line, 0,
                    "suppression names no rule id — use "
                    "`# nimble: ignore[<rule-id>] -- reason`",
                ))
                continue
            unknown = [r for r in s.rules if r not in self.rule_ids]
            if unknown:
                out.append(Finding(
                    "suppression", ctx.path, s.line, 0,
                    f"suppression names unknown rule(s) {unknown}",
                ))
            if not s.reason:
                out.append(Finding(
                    "suppression", ctx.path, s.line, 0,
                    f"suppression of {list(s.rules)} has no written "
                    "justification (append `-- reason`)",
                ))
            elif not s.used and not unknown:
                out.append(Finding(
                    "suppression", ctx.path, s.line, 0,
                    f"suppression of {list(s.rules)} matches no finding — "
                    "stale, remove it",
                ))
        return out

    # -- aggregate -------------------------------------------------------------
    def run(self, contexts: Iterable[FileContext], root: str = "") -> AnalysisReport:
        live: List[Finding] = []
        suppressed: List[Finding] = []
        baselined: List[Finding] = []
        files = 0
        for ctx in contexts:
            files += 1
            for f in self.check_file(ctx):
                if f.rule.startswith("~"):
                    suppressed.append(
                        dataclasses.replace(f, rule=f.rule[1:])
                    )
                elif f.key() in self.baseline:
                    baselined.append(f)
                else:
                    live.append(f)
        live.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
        counts: Dict[str, int] = {}
        for f in live:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return AnalysisReport(root, files, live, suppressed, baselined, counts)


# -- discovery ------------------------------------------------------------------

def iter_python_files(root: str) -> Iterable[str]:
    if os.path.isfile(root):
        yield root
        return
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(
            d for d in dirnames if d != "__pycache__" and not d.startswith(".")
        )
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def _package_of(path: str) -> str:
    """Dotted package for a file path (``.../src/repro/x/y.py`` ->
    ``repro.x``); empty when no ``repro`` anchor is present."""
    parts = os.path.normpath(os.path.abspath(path)).split(os.sep)
    if "repro" not in parts:
        return ""
    pkg = parts[parts.index("repro"):-1]
    return ".".join(pkg)


def build_contexts(
    paths: Sequence[str], rel_to: Optional[str] = None
) -> List[FileContext]:
    contexts: List[FileContext] = []
    for root in paths:
        for path in iter_python_files(root):
            with open(path, encoding="utf-8") as f:
                source = f.read()
            rel = os.path.relpath(path, rel_to) if rel_to else path
            contexts.append(build_context(rel, source, _package_of(path)))
    return contexts


def analyze_paths(
    paths: Sequence[str],
    rules: Optional[Sequence[Rule]] = None,
    baseline: Optional[Sequence[Tuple[str, str, str]]] = None,
    rel_to: Optional[str] = None,
) -> AnalysisReport:
    """Lint ``paths`` with ``rules`` (default: the full registry)."""
    if rules is None:
        from .rules import RULES

        rules = RULES
    engine = AnalysisEngine(rules, baseline)
    contexts = build_contexts(paths, rel_to=rel_to)
    return engine.run(contexts, root=";".join(paths))


def analyze_source(
    source: str,
    path: str = "<fixture>.py",
    rules: Optional[Sequence[Rule]] = None,
    package: str = "",
) -> AnalysisReport:
    """Lint one in-memory source blob (the test-fixture entry point)."""
    if rules is None:
        from .rules import RULES

        rules = RULES
    engine = AnalysisEngine(rules)
    return engine.run([build_context(path, source, package)], root=path)
