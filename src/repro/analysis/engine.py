"""Lint engine: file discovery, suppressions, baseline, report.

The engine owns everything that is not rule logic:

  * walking roots for ``.py`` files and building one
    :class:`~repro.analysis.context.FileContext` per file;
  * inline suppressions — ``# nimble: ignore[<rule-id>] -- reason`` on
    the flagged line or the comment line directly above it.  The reason is
    mandatory: a suppression without one (or naming an unknown rule, or
    suppressing nothing) is itself a finding (rule id ``suppression``),
    so every grandfathered violation carries a written justification;
  * the committed baseline (``baseline.json``): findings matching a
    baseline entry by ``(rule, path, message)`` — line numbers churn —
    are reported as *baselined*, not failures.  The ``src/`` baseline
    ships empty and should stay that way;
  * the ``nimble.lint/v1`` report through :mod:`repro.jsonio`.
"""

from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Protocol, Sequence, Tuple

from ..jsonio import read_json_file, tag, write_json_file
from .context import FileContext, build_context

#: inline suppression: ``# nimble: ignore[<rule-a>,<rule-b>] -- why``
SUPPRESS_RE = re.compile(
    r"#\s*nimble:\s*ignore\[(?P<rules>[a-z0-9_,\s-]*)\]"
    r"(?:\s*--\s*(?P<reason>.*\S))?\s*$"
)

LINT_KIND = "lint"
BASELINE_KIND = "lint_baseline"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def key(self) -> Tuple[str, str, str]:
        """Baseline identity — line/col churn must not invalidate entries."""
        return (self.rule, self.path, self.message)

    def to_json_obj(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
        }

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


class Rule(Protocol):
    """A lint rule: stateless check over one resolved file context.

    Interprocedural rules additionally implement
    ``prepare(program: repro.analysis.callgraph.Program)`` — the engine
    builds the whole-program view once per run and calls ``prepare`` on
    every rule that has it before any ``check``; such rules compute their
    findings there and replay them per file from ``check(ctx)``, so
    suppressions and the baseline apply uniformly.
    """

    rule_id: str
    description: str

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        """Yield findings for ``ctx`` (relative paths, 1-based lines)."""
        ...


@dataclasses.dataclass
class Suppression:
    line: int              # line the comment sits on (1-based)
    rules: Tuple[str, ...]
    reason: str
    used: bool = False


def parse_suppressions(source: str) -> List[Suppression]:
    out: List[Suppression] = []
    for i, text in enumerate(source.splitlines(), start=1):
        m = SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = tuple(
            r.strip() for r in m.group("rules").split(",") if r.strip()
        )
        out.append(Suppression(i, rules, (m.group("reason") or "").strip()))
    return out


def _comment_only(line_text: str) -> bool:
    stripped = line_text.strip()
    return stripped.startswith("#")


@dataclasses.dataclass
class AnalysisReport:
    """Aggregate result of one engine run."""

    root: str
    files: int
    findings: List[Finding]              # live (not suppressed/baselined)
    suppressed: List[Finding]
    baselined: List[Finding]
    counts: Dict[str, int]

    @property
    def clean(self) -> bool:
        return not self.findings

    def to_json_obj(self) -> dict:
        return tag(LINT_KIND, {
            "root": self.root,
            "files": self.files,
            "clean": self.clean,
            "counts": {k: self.counts[k] for k in sorted(self.counts)},
            "findings": [f.to_json_obj() for f in self.findings],
            "suppressed": len(self.suppressed),
            "baselined": len(self.baselined),
        })


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(__file__), "baseline.json")


def default_lock_path() -> str:
    return os.path.join(os.path.dirname(__file__), "schemas.lock.json")


def load_baseline(path: Optional[str] = None) -> List[dict]:
    """Baseline entries as dicts (``rule``/``path``/``message`` plus the
    optional ``reason``/``since`` debt fields); missing file = empty."""
    path = path or default_baseline_path()
    if not os.path.exists(path):
        return []
    obj = read_json_file(path)
    out = []
    for e in obj.get("entries", []):
        out.append({
            "rule": e["rule"], "path": e["path"], "message": e["message"],
            "reason": e.get("reason", ""), "since": e.get("since", ""),
        })
    return out


def write_baseline(
    findings: Sequence[Finding], path: str, since: str = ""
) -> None:
    """Write ``findings`` as a fresh baseline (``--update-baseline``).

    Reasons survive regeneration: an existing entry's ``reason``/``since``
    carry over by ``(rule, path, message)`` key.  New entries land with an
    empty reason — which the engine reports as a ``baseline`` finding
    until someone writes the justification, so the baseline can only grow
    *loudly*.
    """
    previous = {
        (e["rule"], e["path"], e["message"]): e
        for e in load_baseline(path)
    }
    entries = []
    for f in sorted(findings, key=lambda f: f.key()):
        old = previous.get(f.key(), {})
        entries.append({
            "rule": f.rule, "path": f.path, "message": f.message,
            "reason": old.get("reason", ""),
            "since": old.get("since", "") or since,
        })
    write_json_file(path, tag(BASELINE_KIND, {"entries": entries}))


class AnalysisEngine:
    """Run a rule set over a file set and classify the findings."""

    def __init__(
        self,
        rules: Sequence[Rule],
        baseline: Optional[Sequence] = None,
    ):
        self.rules = list(rules)
        self.rule_ids = {r.rule_id for r in self.rules} | {
            "suppression", "baseline",
        }
        # entries arrive as (rule, path, message) keys or as full dicts
        self.baseline_entries: Dict[Tuple[str, str, str], dict] = {}
        for e in baseline or []:
            if isinstance(e, dict):
                key = (e["rule"], e["path"], e["message"])
                self.baseline_entries[key] = {
                    "reason": e.get("reason", ""),
                    "since": e.get("since", ""),
                }
            else:
                self.baseline_entries[tuple(e)] = {"reason": "", "since": ""}
        self.baseline = set(self.baseline_entries)
        self.program = None  # whole-program view of the last run()

    # -- per-file --------------------------------------------------------------
    def check_file(self, ctx: FileContext) -> List[Finding]:
        """All raw findings for one file, suppression hygiene included."""
        findings: List[Finding] = []
        for rule in self.rules:
            findings.extend(rule.check(ctx))
        suppressions = parse_suppressions(ctx.source)
        live = self._apply_suppressions(ctx, findings, suppressions)
        live.extend(self._suppression_hygiene(ctx, suppressions))
        return live

    def _apply_suppressions(
        self,
        ctx: FileContext,
        findings: List[Finding],
        suppressions: List[Suppression],
    ) -> List[Finding]:
        by_line: Dict[int, Suppression] = {s.line: s for s in suppressions}
        live: List[Finding] = []
        for f in findings:
            sup = by_line.get(f.line)
            if sup is None:
                above = by_line.get(f.line - 1)
                if above is not None and _comment_only(
                    ctx.lines[above.line - 1]
                ):
                    sup = above
            if sup is not None and f.rule in sup.rules and sup.reason:
                sup.used = True
                live.append(dataclasses.replace(f, rule=f"~{f.rule}"))
            else:
                live.append(f)
        return live

    def _suppression_hygiene(
        self, ctx: FileContext, suppressions: List[Suppression]
    ) -> List[Finding]:
        out: List[Finding] = []
        for s in suppressions:
            if not s.rules:
                out.append(Finding(
                    "suppression", ctx.path, s.line, 0,
                    "suppression names no rule id — use "
                    "`# nimble: ignore[<rule-id>] -- reason`",
                ))
                continue
            unknown = [r for r in s.rules if r not in self.rule_ids]
            if unknown:
                out.append(Finding(
                    "suppression", ctx.path, s.line, 0,
                    f"suppression names unknown rule(s) {unknown}",
                ))
            if not s.reason:
                out.append(Finding(
                    "suppression", ctx.path, s.line, 0,
                    f"suppression of {list(s.rules)} has no written "
                    "justification (append `-- reason`)",
                ))
            elif not s.used and not unknown:
                out.append(Finding(
                    "suppression", ctx.path, s.line, 0,
                    f"suppression of {list(s.rules)} matches no finding — "
                    "stale, remove it",
                ))
        return out

    # -- aggregate -------------------------------------------------------------
    def run(
        self,
        contexts: Iterable[FileContext],
        root: str = "",
        cache=None,
    ) -> AnalysisReport:
        contexts = list(contexts)
        self.program = self.prepare_rules(contexts, cache=cache)
        live: List[Finding] = []
        suppressed: List[Finding] = []
        baselined: List[Finding] = []
        files = 0
        for ctx in contexts:
            files += 1
            for f in self.check_file(ctx):
                if f.rule.startswith("~"):
                    suppressed.append(
                        dataclasses.replace(f, rule=f.rule[1:])
                    )
                elif f.key() in self.baseline:
                    baselined.append(f)
                else:
                    live.append(f)
        live.extend(self._police_baseline(contexts, baselined))
        live.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
        counts: Dict[str, int] = {}
        for f in live:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return AnalysisReport(root, files, live, suppressed, baselined, counts)

    def prepare_rules(
        self, contexts: Sequence[FileContext], cache=None
    ):
        """Build the whole-program view once and hand it to every rule
        that wants it.  Returns the Program (None when no rule needs it)."""
        interproc = [r for r in self.rules if hasattr(r, "prepare")]
        if not interproc:
            return None
        from .callgraph import build_program

        program = build_program(contexts, cache=cache)
        for rule in interproc:
            rule.prepare(program)
        return program

    def _police_baseline(
        self,
        contexts: Sequence[FileContext],
        baselined: Sequence[Finding],
    ) -> List[Finding]:
        """The baseline's own teeth: entries matching nothing in a scanned
        file are stale, and entries in active use must carry a written
        reason — either way the committed baseline cannot drift silently."""
        scanned = {ctx.path for ctx in contexts}
        used = {f.key() for f in baselined}
        out: List[Finding] = []
        for key in sorted(self.baseline_entries):
            rule, path, message = key
            entry = self.baseline_entries[key]
            if key in used:
                if not entry.get("reason"):
                    out.append(Finding(
                        "baseline", path, 0, 0,
                        f"baseline entry for [{rule}] {message!r} has no "
                        "written reason — justify it or fix the finding",
                    ))
            elif path in scanned:
                out.append(Finding(
                    "baseline", path, 0, 0,
                    f"baseline entry for [{rule}] {message!r} matches no "
                    "finding — stale, remove it",
                ))
        return out


# -- discovery ------------------------------------------------------------------

def iter_python_files(root: str) -> Iterable[str]:
    if os.path.isfile(root):
        yield root
        return
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(
            d for d in dirnames if d != "__pycache__" and not d.startswith(".")
        )
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def _package_of(path: str) -> str:
    """Dotted package for a file path (``.../src/repro/x/y.py`` ->
    ``repro.x``); empty when no ``repro`` anchor is present."""
    parts = os.path.normpath(os.path.abspath(path)).split(os.sep)
    if "repro" not in parts:
        return ""
    pkg = parts[parts.index("repro"):-1]
    return ".".join(pkg)


def build_contexts(
    paths: Sequence[str], rel_to: Optional[str] = None
) -> List[FileContext]:
    contexts: List[FileContext] = []
    for root in paths:
        for path in iter_python_files(root):
            with open(path, encoding="utf-8") as f:
                source = f.read()
            rel = os.path.relpath(path, rel_to) if rel_to else path
            contexts.append(build_context(rel, source, _package_of(path)))
    return contexts


def analyze_paths(
    paths: Sequence[str],
    rules: Optional[Sequence[Rule]] = None,
    baseline: Optional[Sequence[Tuple[str, str, str]]] = None,
    rel_to: Optional[str] = None,
) -> AnalysisReport:
    """Lint ``paths`` with ``rules`` (default: the full registry)."""
    if rules is None:
        from .rules import RULES

        rules = RULES
    engine = AnalysisEngine(rules, baseline)
    contexts = build_contexts(paths, rel_to=rel_to)
    return engine.run(contexts, root=";".join(paths))


def analyze_source(
    source: str,
    path: str = "<fixture>.py",
    rules: Optional[Sequence[Rule]] = None,
    package: str = "",
) -> AnalysisReport:
    """Lint one in-memory source blob (the test-fixture entry point)."""
    if rules is None:
        from .rules import RULES

        rules = RULES
    engine = AnalysisEngine(rules)
    return engine.run([build_context(path, source, package)], root=path)


def _package_from_rel(path: str) -> str:
    """``repro/core/x.py`` -> ``repro.core`` (virtual fixture paths)."""
    parts = path.replace("\\", "/").split("/")[:-1]
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    return ".".join(parts)


def analyze_sources(
    files: Sequence[Tuple[str, str]],
    rules: Optional[Sequence[Rule]] = None,
    baseline: Optional[Sequence] = None,
) -> AnalysisReport:
    """Lint several in-memory ``(path, source)`` blobs as one program —
    the fixture entry point for the interprocedural rules, where the
    finding lives in a different file than its cause."""
    if rules is None:
        from .rules import RULES

        rules = RULES
    engine = AnalysisEngine(rules, baseline)
    contexts = [
        build_context(path, source, _package_from_rel(path))
        for path, source in files
    ]
    return engine.run(contexts, root=";".join(p for p, _ in files))


# -- suppression/baseline debt ---------------------------------------------------

def collect_debt(
    contexts: Iterable[FileContext],
    baseline_entries: Optional[Sequence[dict]] = None,
) -> dict:
    """Every grandfathered violation in one ledger (``--debt``).

    Inline suppressions are read straight from the scanned sources;
    baseline entries come from the committed file, with their
    ``reason``/``since`` age fields.  The shipped ``src/`` debt should be
    empty — the teeth test pins that it stays that way.
    """
    suppressions = []
    for ctx in sorted(contexts, key=lambda c: c.path):
        for s in parse_suppressions(ctx.source):
            suppressions.append({
                "path": ctx.path,
                "line": s.line,
                "rules": sorted(s.rules),
                "reason": s.reason,
            })
    entries = [dict(e) for e in (baseline_entries or [])]
    entries.sort(key=lambda e: (e["rule"], e["path"], e["message"]))
    return {
        "suppressions": suppressions,
        "baseline": entries,
        "total": len(suppressions) + len(entries),
    }
