"""Public grouped-FFN op: ragged tokens -> sort/pad -> blocked kernel.

``grouped_ffn(x, expert_id, wg, wu, wd)`` accepts tokens in arbitrary order
with ``expert_id[i] in [0, E)`` or ``-1`` for padding rows.  It

  1. sorts tokens by expert (stable),
  2. pads each expert's segment to a multiple of ``block_tokens`` (static
     worst-case buffer of ``N + E*block_tokens`` rows),
  3. runs the Pallas blocked kernel with per-block expert ids,
  4. scatters results back to the original order.

Gradients flow through a jnp-reference VJP (the sort/pad is a permutation;
the FFN backward reuses the same grouping).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .ffn import grouped_ffn_blocked
from .ref import grouped_ffn_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _arrange(expert_id: jnp.ndarray, n_experts: int, block: int):
    """Compute padded positions + per-block experts for ragged grouping."""
    n = expert_id.shape[0]
    m_pad = (-(-n // block) + n_experts) * block  # block-aligned worst case
    key = jnp.where(expert_id < 0, n_experts, expert_id)
    order = jnp.argsort(key, stable=True)                       # sorted rows
    counts = jnp.bincount(jnp.clip(key, 0, n_experts), length=n_experts + 1)
    aligned = (jnp.ceil(counts[:-1] / block) * block).astype(jnp.int32)
    aligned_off = jnp.cumsum(aligned) - aligned                 # [E]
    # rank of each sorted row within its expert
    seg_off = jnp.cumsum(counts[:-1]) - counts[:-1]
    rank = jnp.arange(n) - seg_off[jnp.clip(key[order], 0, n_experts - 1)]
    pos_sorted = aligned_off[jnp.clip(key[order], 0, n_experts - 1)] + rank
    pos_sorted = jnp.where(key[order] >= n_experts, m_pad - 1, pos_sorted)
    # block -> expert (blocks past the last segment clamp to E-1, all-zero)
    blk_start = jnp.arange(m_pad // block) * block
    blk_expert = jnp.sum(
        aligned_off[None, :] <= blk_start[:, None], axis=1
    ) - 1
    blk_expert = jnp.clip(blk_expert, 0, n_experts - 1)
    return order, pos_sorted, blk_expert, m_pad


def grouped_ffn_scan(
    x: jnp.ndarray,
    expert_id: jnp.ndarray,
    wg: jnp.ndarray,
    wu: jnp.ndarray,
    wd: jnp.ndarray,
    *,
    block_tokens: int = 128,
) -> jnp.ndarray:
    """Non-TPU large-shape path: same sort/pad arrangement, but the blocked
    matmuls run as a ``lax.scan`` over token blocks with a dynamic gather of
    the block's expert weights.  FLOPs identical to the Pallas kernel (so
    dry-run rooflines are faithful); native autodiff."""
    E = wg.shape[0]
    n, d = x.shape
    order, pos, blk_expert, m_pad = _arrange(expert_id, E, block_tokens)
    x_pad = jnp.zeros((m_pad, d), x.dtype).at[pos].set(x[order])
    xb = x_pad.reshape(-1, block_tokens, d)

    def step(_, inp):
        xi, e = inp
        g = jax.nn.silu(xi.astype(jnp.float32) @ wg[e].astype(jnp.float32))
        u = xi.astype(jnp.float32) @ wu[e].astype(jnp.float32)
        return None, ((g * u) @ wd[e].astype(jnp.float32)).astype(x.dtype)

    _, yb = jax.lax.scan(step, None, (xb, blk_expert))
    y_pad = yb.reshape(m_pad, d)
    y = jnp.zeros((n, d), x.dtype).at[order].set(y_pad[pos])
    return jnp.where((expert_id >= 0)[:, None], y, 0)


def grouped_ffn_dense(
    x: jnp.ndarray,
    expert_id: jnp.ndarray,
    wg: jnp.ndarray,
    wu: jnp.ndarray,
    wd: jnp.ndarray,
    *,
    cap_factor: float = 2.0,
    block_tokens: int = 64,
) -> jnp.ndarray:
    """Static-capacity segment einsum (§Perf iteration C1).

    The block-scan path reads one expert's weights per 64-token block —
    ~128x more weight traffic than necessary (1024 blocks vs 8 experts on
    the qwen3-moe dry-run, dominating its memory roofline term).  Here
    tokens are packed into a [E, cap, d] buffer and each expert's weights
    are read ONCE by three dense einsums.

    Capacity semantics match the dispatcher's buffers (paper §IV policies):
    rows beyond ``cap = ceil(N * cap_factor / E)`` (block-aligned) are
    dropped (output 0).  With a balanced-enough routing (or cap_factor
    sized like the dispatch capacity) the result equals the reference.
    """
    E = wg.shape[0]
    n, d = x.shape
    cap = max(int(-(-n * cap_factor // (E * block_tokens))), 1) * block_tokens
    key = jnp.where(expert_id < 0, E, expert_id)
    order = jnp.argsort(key, stable=True)
    counts = jnp.bincount(jnp.clip(key, 0, E), length=E + 1)
    seg_off = jnp.cumsum(counts[:-1]) - counts[:-1]
    rank_sorted = jnp.arange(n) - seg_off[jnp.clip(key[order], 0, E - 1)]
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    kept = (rank < cap) & (expert_id >= 0)
    e_c = jnp.clip(expert_id, 0, E - 1)
    r_c = jnp.minimum(rank, cap - 1)
    buf = jnp.zeros((E, cap, d), x.dtype).at[e_c, r_c].add(
        jnp.where(kept[:, None], x, 0)
    )
    bf = buf.astype(jnp.float32)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", bf, wg.astype(jnp.float32)))
    u = jnp.einsum("ecd,edf->ecf", bf, wu.astype(jnp.float32))
    yb = jnp.einsum("ecf,efd->ecd", h * u, wd.astype(jnp.float32))
    y = yb[e_c, r_c].astype(x.dtype)
    return jnp.where(kept[:, None], y, 0)


def grouped_ffn(
    x: jnp.ndarray,
    expert_id: jnp.ndarray,
    wg: jnp.ndarray,
    wu: jnp.ndarray,
    wd: jnp.ndarray,
    *,
    block_tokens: int = 128,
    block_ffn: int = 128,
    cap_factor: float = 2.0,
) -> jnp.ndarray:
    if jax.default_backend() != "tpu" and x.shape[0] > 4 * block_tokens:
        # §Perf C1: dense segment einsum by default; the block-scan baseline
        # stays selectable for before/after measurement.  Dense wins when
        # the saved per-block weight re-reads outweigh capacity padding —
        # i.e. when there are substantially more token blocks than experts;
        # tiny decode batches keep the scan path (fixes the 0.87-0.97x
        # MoE-decode regressions in EXPERIMENTS.md §Perf).
        E = wg.shape[0]
        dense_worthwhile = x.shape[0] >= 2 * E * block_tokens
        if (os.environ.get("NIMBLE_FFN_IMPL", "dense") == "scan"
                or not dense_worthwhile):
            return grouped_ffn_scan(x, expert_id, wg, wu, wd,
                                    block_tokens=block_tokens)
        return grouped_ffn_dense(x, expert_id, wg, wu, wd,
                                 cap_factor=cap_factor,
                                 block_tokens=block_tokens)
    return _grouped_ffn(x, expert_id, wg, wu, wd, block_tokens, block_ffn)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _grouped_ffn(x, expert_id, wg, wu, wd, block_tokens, block_ffn):
    E = wg.shape[0]
    n, d = x.shape
    order, pos, blk_expert, m_pad = _arrange(expert_id, E, block_tokens)
    x_pad = jnp.zeros((m_pad, d), x.dtype).at[pos].set(x[order])
    y_pad = grouped_ffn_blocked(
        x_pad, blk_expert, wg, wu, wd,
        block_tokens=block_tokens, block_ffn=block_ffn,
        interpret=_interpret(),
    )
    y = jnp.zeros((n, d), x.dtype).at[order].set(y_pad[pos])
    return jnp.where((expert_id >= 0)[:, None], y, 0)


def _fwd(x, expert_id, wg, wu, wd, block_tokens, block_ffn):
    y = _grouped_ffn(x, expert_id, wg, wu, wd, block_tokens, block_ffn)
    return y, (x, expert_id, wg, wu, wd)


def _bwd(block_tokens, block_ffn, res, g):
    x, expert_id, wg, wu, wd = res
    # backward via the reference formulation (einsum over expert one-hots);
    # exact for the same f32 accumulation.
    def f(x, wg, wu, wd):
        return grouped_ffn_ref(x, expert_id, wg, wu, wd)

    _, vjp = jax.vjp(f, x, wg, wu, wd)
    gx, gwg, gwu, gwd = vjp(g)
    return gx, None, gwg, gwu, gwd


_grouped_ffn.defvjp(_fwd, _bwd)

__all__ = ["grouped_ffn", "grouped_ffn_dense", "grouped_ffn_ref"]
