"""Pure-jnp oracle for the grouped (per-expert) SwiGLU FFN."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_ffn_ref(
    x: jnp.ndarray,         # [N, D] tokens
    expert_id: jnp.ndarray,  # [N] int32, -1 = invalid
    wg: jnp.ndarray,        # [E, D, F] gate
    wu: jnp.ndarray,        # [E, D, F] up
    wd: jnp.ndarray,        # [E, F, D] down
) -> jnp.ndarray:
    """out[i] = SwiGLU_{expert_id[i]}(x[i]); invalid rows -> 0."""
    E = wg.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for e in range(E):
        h = jax.nn.silu(x.astype(jnp.float32) @ wg[e].astype(jnp.float32))
        u = x.astype(jnp.float32) @ wu[e].astype(jnp.float32)
        y = (h * u) @ wd[e].astype(jnp.float32)
        out = jnp.where((expert_id == e)[:, None], y, out)
    return jnp.where((expert_id >= 0)[:, None], out, 0).astype(x.dtype)
