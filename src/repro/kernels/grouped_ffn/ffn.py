"""Pallas TPU kernel: blocked per-expert SwiGLU FFN (megablox-style).

The MoE compute hot-spot.  Tokens arrive sorted by expert and padded so each
(bm)-row block is expert-homogeneous; the block's expert id is scalar-
prefetched and selects the weight slices directly in the BlockSpec
``index_map`` — no gather of full weight matrices into registers.

Grid = (token_blocks, ffn_blocks); the ffn dimension is the innermost
(sequential) axis so the (bm, D) output block accumulates partial
``(act(x·Wg) * (x·Wu)) · Wd`` contributions across F-slices in f32, keeping
VMEM at ~3·D·bf·2B per step — sized for v5e's 16 MB VMEM with D=4096,
bf=256.  MXU alignment: bm, bf multiples of 128 recommended (asserted soft).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(eid_ref, x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    fb = pl.program_id(1)

    @pl.when(fb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    g = jnp.dot(x, wg_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    h = jax.nn.silu(g) * u
    o_ref[...] += jnp.dot(h, wd_ref[0].astype(jnp.float32),
                          preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("block_tokens", "block_ffn", "interpret")
)
def grouped_ffn_blocked(
    x: jnp.ndarray,           # [M, D] sorted+padded tokens (block-homogeneous)
    block_expert: jnp.ndarray,  # [M // block_tokens] int32
    wg: jnp.ndarray,          # [E, D, F]
    wu: jnp.ndarray,          # [E, D, F]
    wd: jnp.ndarray,          # [E, F, D]
    *,
    block_tokens: int = 128,
    block_ffn: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    m, d = x.shape
    e, _, f = wg.shape
    assert m % block_tokens == 0 and f % block_ffn == 0
    grid = (m // block_tokens, f // block_ffn)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_tokens, d), lambda i, fb, eid: (i, 0)),
            pl.BlockSpec((1, d, block_ffn), lambda i, fb, eid: (eid[i], 0, fb)),
            pl.BlockSpec((1, d, block_ffn), lambda i, fb, eid: (eid[i], 0, fb)),
            pl.BlockSpec((1, block_ffn, d), lambda i, fb, eid: (eid[i], fb, 0)),
        ],
        out_specs=pl.BlockSpec((block_tokens, d), lambda i, fb, eid: (i, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(block_expert.astype(jnp.int32), x, wg, wu, wd)
    return out.astype(x.dtype)
