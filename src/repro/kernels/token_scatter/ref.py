"""Pure-jnp oracle for the token gather/scatter (pack) kernel."""

from __future__ import annotations

import jax.numpy as jnp


def token_gather_ref(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """out[i] = x[idx[i]] for idx[i] >= 0 else 0.   x: [N, D], idx: [M]."""
    safe = jnp.clip(idx, 0, x.shape[0] - 1)
    out = x[safe]
    return jnp.where((idx >= 0)[:, None], out, 0).astype(x.dtype)
