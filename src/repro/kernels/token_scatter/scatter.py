"""Pallas TPU kernel: row gather — the "Kernel Scatter" pack stage (§IV-A).

Packing tokens into per-destination contiguous send buffers is a permutation,
so on TPU we express it as a *gather*: the output buffer is written in order
while the input row index comes from a scalar-prefetched index vector (the
same sorted-by-destination order the dispatcher computes).  Using the index
inside the BlockSpec ``index_map`` means the DMA engine fetches exactly the
needed row per grid step — the Pallas/TPU analogue of NCCL's kernel-driven
scatter thread blocks.

Block layout: one (1, D) row per grid step in VMEM; the per-row validity
mask rides as a (1, 1) block multiplied in-kernel (invalid rows fetch row 0
and are zeroed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, x_ref, mask_ref, o_ref):
    o_ref[...] = x_ref[...] * mask_ref[0, 0].astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def token_gather(x: jnp.ndarray, idx: jnp.ndarray, *, interpret: bool = True):
    """out[i] = x[idx[i]] (idx < 0 -> zeros).  x: [N, D], idx: [M] int32."""
    n, d = x.shape
    m = idx.shape[0]
    safe = jnp.clip(idx, 0, n - 1).astype(jnp.int32)
    mask = (idx >= 0).astype(x.dtype).reshape(m, 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),
            pl.BlockSpec((1, 1), lambda i, idx_ref: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(safe, x, mask)
