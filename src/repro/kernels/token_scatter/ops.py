"""Jit'd public wrapper for the token gather/pack kernel.

On non-TPU backends the Pallas body runs in interpret mode (Python
execution, bit-identical semantics); gradients route through the jnp
reference via ``jax.custom_vjp`` since the gather's VJP is a scatter-add.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import token_gather_ref
from .scatter import token_gather as _token_gather_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.custom_vjp
def token_gather(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    return _token_gather_pallas(x, idx, interpret=_interpret())


def _fwd(x, idx):
    return token_gather(x, idx), (x.shape, idx)


def _bwd(res, g):
    (n, d), idx = res
    safe = jnp.clip(idx, 0, n - 1)
    gx = jnp.zeros((n, d), g.dtype).at[safe].add(
        jnp.where((idx >= 0)[:, None], g, 0)
    )
    return gx.astype(g.dtype), None


token_gather.defvjp(_fwd, _bwd)

__all__ = ["token_gather", "token_gather_ref"]
