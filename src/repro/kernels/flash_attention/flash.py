"""Pallas TPU kernel: flash attention with causal + sliding-window masking.

Online-softmax blocked attention for the dense architectures' prefill and
training paths, and — with ``window`` set — the sub-quadratic variant that
makes ``long_500k`` runnable for full-attention models (DESIGN.md §7).

Grid = (batch, heads, q_blocks, kv_blocks); kv is innermost/sequential so the
running (m, l, acc) statistics live in VMEM scratch across kv steps.  GQA is
expressed in the BlockSpec index_map (query head h reads kv head h // g) —
no repeated KV in HBM.  Block shapes default to (128, 128), MXU-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale, causal, window, q_offset, bq, bk, n_kv):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # [bq, dh]
    k = k_ref[0, 0].astype(jnp.float32)          # [bk, dh]
    v = v_ref[0, 0].astype(jnp.float32)          # [bk, dh]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                  # [bq, bk]

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                        # [bq, 1]
    m_new = jnp.maximum(m_prev[:, 0], s.max(axis=-1))[:, None]
    p = jnp.exp(s - m_new)                     # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)            # [bq, 1]
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)[:, None]
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,   # [B, H, Sq, Dh]
    k: jnp.ndarray,   # [B, Hkv, Sk, Dh]
    v: jnp.ndarray,   # [B, Hkv, Sk, Dh]
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    grid = (b, h, sq // bq, sk // bk)
    scale = 1.0 / (dh ** 0.5)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, n_kv=sk // bk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, dh), lambda bi, hi, qi, ki: (bi, hi, qi, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
