"""Public attention op with three execution paths:

  * **TPU**: the Pallas flash kernel (``flash.py``) — the target artifact;
  * **non-TPU, long sequences**: ``chunked_attention`` — the same online-
    softmax algorithm expressed as a pure-jnp ``lax.scan`` over kv blocks.
    This is what dry-run lowering uses: identical FLOPs and O(S) memory,
    so the roofline derived from the compiled HLO is faithful, while
    compile size stays constant in sequence length;
  * **small shapes**: the quadratic reference (cheapest to compile/run).

Gradients: jnp paths differentiate natively (scan AD = recompute-based,
flash-like memory).  The Pallas path uses a reference VJP (a backward
Pallas kernel is a TPU-only optimization, noted in EXPERIMENTS.md).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash import flash_attention as _flash
from .ref import mha_ref

_CHUNK = 2048


def chunked_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True, window: Optional[int] = None, q_offset: int = 0,
    chunk: int = _CHUNK,
) -> jnp.ndarray:
    """Online-softmax over kv chunks (lax.scan) — flash semantics in jnp."""
    b, h, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    nc = -(-sk // chunk)
    pad = nc * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = k.reshape(b, hkv, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
    qf = q.astype(jnp.float32) / (dh ** 0.5)
    qpos = jnp.arange(sq) + q_offset

    def step(carry, inp):
        m, l, acc, ci = carry
        kb, vb = inp                                  # [b,hkv,chunk,dh]
        kb = jnp.repeat(kb, g, axis=1).astype(jnp.float32)
        vb = jnp.repeat(vb, g, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < sk
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        # NOTE (§Perf C3, refuted): storing probs as bf16 for bf16 inputs
        # (flash-kernel style) MEASURED +2.2% memory on the MoE dry-run —
        # XLA:CPU legalizes bf16 compute to f32, so the cast only inserts
        # converts.  The Pallas TPU kernel does keep bf16 P·V natively.
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l, acc, ci + 1), None

    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)), (kc, vc))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _attention_tpu(q, k, v, causal, window, q_offset):
    return _flash(q, k, v, causal=causal, window=window, q_offset=q_offset,
                  interpret=False)


def _fwd(q, k, v, causal, window, q_offset):
    return _attention_tpu(q, k, v, causal, window, q_offset), (q, k, v)


def _bwd(causal, window, q_offset, res, g):
    q, k, v = res

    def f(q, k, v):
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


_attention_tpu.defvjp(_fwd, _bwd)


def attention(q, k, v, causal=True, window=None, q_offset=0):
    """[B,H,Sq,Dh] x [B,Hkv,Sk,Dh]^2 -> [B,H,Sq,Dh]; GQA via Hkv | H."""
    sk = k.shape[2]
    if jax.default_backend() == "tpu" and q.shape[2] >= 128:
        return _attention_tpu(q, k, v, causal, window, q_offset)
    # NOTE (§Perf B2, refuted): routing medium sequences (256 < Sk <= 2k)
    # through chunked_attention was MEASURED WORSE (+7% memory term on
    # whisper prefill) — the per-chunk accumulator rescale traffic exceeds
    # the saved probs passes at small Sk.  Threshold kept at 2*_CHUNK.
    if sk > 2 * _CHUNK:
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset)
    return mha_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)


__all__ = ["attention", "chunked_attention", "mha_ref"]
