"""Pure-jnp oracle for causal / sliding-window attention (GQA)."""

from __future__ import annotations

import jax.numpy as jnp


def mha_ref(
    q: jnp.ndarray,   # [B, H, Sq, Dh]
    k: jnp.ndarray,   # [B, Hkv, Sk, Dh]
    v: jnp.ndarray,   # [B, Hkv, Sk, Dh]
    *,
    causal: bool = True,
    window: int | None = None,   # attend to [pos-window+1, pos]
    q_offset: int = 0,           # absolute position of q[0] (decode)
) -> jnp.ndarray:
    b, h, sq, dh = q.shape
    hkv = k.shape[1]
    g = h // hkv
    kk = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk)
    s = s / jnp.sqrt(dh).astype(jnp.float32)
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)
