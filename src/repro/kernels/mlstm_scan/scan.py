"""Pallas TPU kernel: chunkwise-parallel mLSTM scan.

The EXPERIMENTS.md §Perf PAIR-A analysis identified the mLSTM matrix-memory
round-trip as the xlstm memory-term floor; the chunked jnp reformulation
(models/xlstm.py) cut it 10.2x, and this kernel is the TPU artifact that
takes the remaining step: the carried (C, n, m) state lives in VMEM scratch
across the sequential chunk dimension, so HBM sees only q/k/v/gate inputs
and the h output — one pass each way.

Grid = (batch, heads, chunks); chunks innermost/sequential.  Per step the
kernel computes the exact stabilized chunk recurrence of
``xlstm._mlstm_chunk_body`` (same math, same carry convention):

    Lf = cumsum(lf),  g = ig - Lf,  u_t = max(m_in, cummax g)
    W[t, j] = e^{g_j - u_t} (j <= t)
    h = (qk^T.W @ v + e^{m_in - u}.C_in^T q) / max(|den|, e^{-(Lf + u)})
    C' = e^{m_in - u_L} C + (w.k)^T v, ...

Cumulatives are computed with an in-register doubling scan (log2 L shifted
maximum/add steps) — no lax.cum* dependency inside the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _doubling_scan(x, op, L):
    """Inclusive prefix scan along axis 0 of [L, ...] via doubling."""
    shift = 1
    while shift < L:
        rolled = jnp.concatenate(
            [jnp.full_like(x[:shift], 0.0 if op is jnp.add else _NEG),
             x[:-shift]], axis=0)
        x = op(x, rolled)
        shift *= 2
    return x


def _kernel(q_ref, k_ref, v_ref, ig_ref, lf_ref, o_ref,
            c_scr, n_scr, m_scr, *, L, dh, n_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, -30.0)

    q = q_ref[0, 0].astype(jnp.float32)            # [L, dh]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    ig = ig_ref[0, 0].astype(jnp.float32)          # [L, 1]
    lf = lf_ref[0, 0].astype(jnp.float32)          # [L, 1]

    m_in = m_scr[0, 0]
    Lf = _doubling_scan(lf, jnp.add, L)            # [L, 1]
    g = ig - Lf
    u = jnp.maximum(m_in, _doubling_scan(g, jnp.maximum, L))  # [L, 1]
    m = Lf + u

    # intra-chunk causal weights W[t, j] = e^{g_j - u_t}
    seg = g[None, :, 0] - u[:, None, 0]            # [Lt, Lj]
    ti = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    seg = jnp.where(ti >= tj, seg, _NEG)
    W = jnp.exp(seg)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * W                                           # [Lt, Lj]
    num = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # [Lt, dh]
    den = scores.sum(axis=1, keepdims=True)         # [Lt, 1]

    # inter-chunk contribution from the carried state
    w_in = jnp.exp(m_in - u)                        # [L, 1]
    C_in = c_scr[...]                               # [dh(d), dh(p)]
    n_in = n_scr[...]                               # [1, dh]
    num += w_in * jax.lax.dot_general(
        q, C_in, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    den += w_in * jax.lax.dot_general(
        q, n_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))
    o_ref[0, 0] = h.astype(o_ref.dtype)

    # carry out, stabilized at m_L = Lf_L + u_L (the cell convention)
    u_L = u[L - 1, 0]
    wj = jnp.exp(g - u_L)                           # [L, 1]
    decay = jnp.exp(m_in - u_L)
    c_scr[...] = decay * C_in + jax.lax.dot_general(
        k * wj, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_scr[...] = decay * n_in + (k * wj).sum(axis=0, keepdims=True)
    m_scr[0, 0] = Lf[L - 1, 0] + u_L


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret"),
)
def mlstm_scan(
    q: jnp.ndarray,    # [B, H, S, dh]  (pre-scaled as in _mlstm_qkvif)
    k: jnp.ndarray,    # [B, H, S, dh]
    v: jnp.ndarray,    # [B, H, S, dh]
    ig: jnp.ndarray,   # [B, H, S]
    lf: jnp.ndarray,   # [B, H, S]  log-sigmoid forget gate
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns h [B, H, S, dh]; state starts at the zero/m=-30 init."""
    b, hh, s, dh = q.shape
    L = min(chunk, s)
    assert s % L == 0, "sequence must divide the chunk size"
    nc = s // L
    grid = (b, hh, nc)
    kernel = functools.partial(_kernel, L=L, dh=dh, n_chunks=nc)
    spec3 = pl.BlockSpec((1, 1, L, dh), lambda bi, hi, ci: (bi, hi, ci, 0))
    spec1 = pl.BlockSpec((1, 1, L, 1), lambda bi, hi, ci: (bi, hi, ci, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec3, spec3, spec3, spec1, spec1],
        out_specs=spec3,
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),   # C
            pltpu.VMEM((1, dh), jnp.float32),    # n
            pltpu.VMEM((1, 1), jnp.float32),     # m
        ],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v, ig[..., None], lf[..., None])
