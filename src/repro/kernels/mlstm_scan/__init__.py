from .ops import mlstm_scan, mlstm_scan_op, mlstm_scan_ref

__all__ = ["mlstm_scan_op", "mlstm_scan", "mlstm_scan_ref"]
