"""Pure-jnp oracle for the chunkwise mLSTM scan kernel: the per-step cell
recurrence (matches models/xlstm._mlstm_cell with zero-init state)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_scan_ref(q, k, v, ig, lf):
    """q,k,v: [B, H, S, dh]; ig, lf: [B, H, S] -> h [B, H, S, dh]."""
    b, hh, s, dh = q.shape

    def step(state, inp):
        C, n, m = state
        qt, kt, vt, it, ft = inp                  # [B,H,dh] x3, [B,H] x2
        m_new = jnp.maximum(ft + m, it)
        a = jnp.exp(ft + m - m_new)
        bw = jnp.exp(it - m_new)
        C = C * a[..., None, None] + bw[..., None, None] * (
            kt[..., :, None] * vt[..., None, :]
        )
        n = n * a[..., None] + bw[..., None] * kt
        num = jnp.einsum("bhdp,bhd->bhp", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)),
                          jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    C0 = jnp.zeros((b, hh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, hh, dh), jnp.float32)
    m0 = jnp.full((b, hh), -30.0, jnp.float32)
    xs = (
        q.transpose(2, 0, 1, 3).astype(jnp.float32),
        k.transpose(2, 0, 1, 3).astype(jnp.float32),
        v.transpose(2, 0, 1, 3).astype(jnp.float32),
        ig.transpose(2, 0, 1).astype(jnp.float32),
        lf.transpose(2, 0, 1).astype(jnp.float32),
    )
    _, hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 2, 0, 3).astype(q.dtype)
