"""Public op: Pallas chunkwise mLSTM scan on TPU, jnp chunked path elsewhere.

The non-TPU path reuses the validated chunkwise reformulation in
models/xlstm.py (identical math), keeping dry-run lowering cheap while the
Pallas kernel is the TPU artifact.
"""

from __future__ import annotations

import jax

from .ref import mlstm_scan_ref
from .scan import mlstm_scan


def mlstm_scan_op(q, k, v, ig, lf, *, chunk: int = 64):
    if jax.default_backend() == "tpu" and q.shape[2] % chunk == 0:
        return mlstm_scan(q, k, v, ig, lf, chunk=chunk, interpret=False)
    if q.shape[2] % chunk == 0:
        return mlstm_scan(q, k, v, ig, lf, chunk=chunk, interpret=True)
    return mlstm_scan_ref(q, k, v, ig, lf)


__all__ = ["mlstm_scan_op", "mlstm_scan", "mlstm_scan_ref"]
