"""Oracle for the staged relay copy: an identity over the chunk pipeline."""

from __future__ import annotations

import jax.numpy as jnp


def relay_copy_ref(x: jnp.ndarray) -> jnp.ndarray:
    return x
