"""Pallas TPU kernel: double-buffered staged chunk relay (§IV-C analogue).

The paper's relay GPUs stream data through small P2P staging buffers,
overlapping receive of chunk j+1 with forward of chunk j (counter-based
flow control).  On TPU the inter-chip movement itself is a ppermute in the
scheduled dataplane; what remains kernel-shaped is the *staging discipline*:
move a large buffer through a small VMEM window, chunk by chunk, with two
slots alternating so the inbound DMA of the next chunk overlaps the
outbound store of the current one.

This kernel implements exactly that: grid over chunks, a (2, bc, D) VMEM
scratch, slot parity = program_id % 2.  Pallas double-buffers the HBM->VMEM
block fetches automatically; the explicit scratch models the relay's
fixed-size P2P buffer pool (10 MB/thread-block in the paper's setup) and is
what a fused relay (recv-compute-send) kernel would build on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, buf):
    slot = pl.program_id(0) % 2
    buf[slot] = x_ref[...]          # "receive" into the staging slot
    o_ref[...] = buf[slot]          # "forward" out of the staging slot


@functools.partial(jax.jit, static_argnames=("block_chunk", "interpret"))
def relay_copy(
    x: jnp.ndarray, *, block_chunk: int = 256, interpret: bool = True
) -> jnp.ndarray:
    """Identity copy of [N, D] through a 2-slot VMEM staging pipeline."""
    n, d = x.shape
    bc = min(block_chunk, n)
    assert n % bc == 0
    return pl.pallas_call(
        _kernel,
        grid=(n // bc,),
        in_specs=[pl.BlockSpec((bc, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bc, d), lambda i: (i, 0)),
        scratch_shapes=[pltpu.VMEM((2, bc, d), x.dtype)],
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
