"""Pallas TPU kernel: double-buffered staged chunk relay (§IV-C analogue).

The paper's relay GPUs stream data through small P2P staging buffers,
overlapping receive of chunk j+1 with forward of chunk j (counter-based
flow control).  On TPU the inter-chip movement itself is a ppermute in the
scheduled dataplane; what remains kernel-shaped is the *staging discipline*:
move a large buffer through a small VMEM window, chunk by chunk, with two
slots alternating so the inbound DMA of the next chunk overlaps the
outbound store of the current one.

The staging-slot schedule is runtime **data**, not a trace-time constant
(ROADMAP item 2, the CUDA-graphs idiom of arxiv 2604.22228): the slot for
each grid step is read out of a scalar-prefetched ``slot_map`` array, so
a swapped plan re-targets relay slots without recompiling the kernel —
``relay_copy`` traces once per geometry and every slot schedule reuses
that executable.  The plan owns slot assignment; baking ``program_id % 2``
into the jaxpr (the previous revision) froze one schedule per trace and
is exactly the PLAN_DEPENDENT hazard ``repro.analysis``'s
``retrace-provenance`` rule now rejects.

Pallas double-buffers the HBM->VMEM block fetches automatically; the
explicit scratch models the relay's fixed-size P2P buffer pool
(10 MB/thread-block in the paper's setup) and is what a fused relay
(recv-compute-send) kernel would build on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

N_SLOTS = 2


def _kernel(slot_ref, x_ref, o_ref, buf):
    i = pl.program_id(0)
    slot = slot_ref[i]              # runtime slot target, not a constant
    buf[slot] = x_ref[...]          # "receive" into the staging slot
    o_ref[...] = buf[slot]          # "forward" out of the staging slot


def parity_slot_map(n_chunks: int) -> jnp.ndarray:
    """The default double-buffer schedule: slot = chunk parity."""
    return jnp.arange(n_chunks, dtype=jnp.int32) % N_SLOTS


@functools.partial(jax.jit, static_argnames=("block_chunk", "interpret"))
def relay_copy(
    x: jnp.ndarray,
    slot_map: jnp.ndarray | None = None,
    *,
    block_chunk: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Identity copy of [N, D] through a 2-slot VMEM staging pipeline.

    ``slot_map`` maps grid step -> staging slot (default: parity).  It is
    scalar-prefetched, so swapping schedules costs a parameter update,
    not a retrace — pinned by ``tests/test_kernels.py`` via
    ``relay_copy._cache_size()``.
    """
    n, d = x.shape
    bc = min(block_chunk, n)
    assert n % bc == 0
    n_chunks = n // bc
    if slot_map is None:
        slot_map = parity_slot_map(n_chunks)
    assert slot_map.shape == (n_chunks,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_chunks,),
        in_specs=[pl.BlockSpec((bc, d), lambda i, s: (i, 0))],
        out_specs=pl.BlockSpec((bc, d), lambda i, s: (i, 0)),
        scratch_shapes=[pltpu.VMEM((N_SLOTS, bc, d), x.dtype)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(slot_map.astype(jnp.int32), x)
