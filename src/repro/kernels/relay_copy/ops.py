"""Jit'd wrapper for the staged relay copy."""

from __future__ import annotations

import jax

from .ref import relay_copy_ref
from .relay import relay_copy as _relay_pallas


def relay_copy(x, *, block_chunk: int = 256):
    return _relay_pallas(
        x, block_chunk=block_chunk, interpret=jax.default_backend() != "tpu"
    )


__all__ = ["relay_copy", "relay_copy_ref"]
