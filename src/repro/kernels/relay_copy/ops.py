"""Jit'd wrapper for the staged relay copy."""

from __future__ import annotations

import jax

from .ref import relay_copy_ref
from .relay import parity_slot_map
from .relay import relay_copy as _relay_pallas


def relay_copy(x, slot_map=None, *, block_chunk: int = 256):
    return _relay_pallas(
        x, slot_map, block_chunk=block_chunk,
        interpret=jax.default_backend() != "tpu",
    )


__all__ = ["relay_copy", "relay_copy_ref", "parity_slot_map"]
