"""MoE transformer (qwen3-moe / granite-moe / paper-moe-8e).

Same GQA+RoPE skeleton as ``dense.py`` with the FFN replaced by a top-k
routed expert layer.  Expert parallelism is where the paper's technique
lives: with ``ctx.ep_size > 1`` the dispatch/combine All-to-Allv runs
through :class:`repro.core.MoEDispatcher` (NIMBLE planner + scheduled
multi-path dataplane) inside ``shard_map`` over the model axis; single
device falls back to local grouped FFN (CPU smoke tests).

Router: softmax top-k with renormalized gates + switch-style load-balance
auxiliary loss.  No capacity cap at the router (DeepSeek-style no-drop,
§V-D); the dispatcher's buffer capacity factor is the physical bound.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.jax_compat import shard_map
from repro.core.moe_comm import MoECommConfig, MoEDispatcher
from repro.kernels.grouped_ffn.ops import grouped_ffn, grouped_ffn_ref
from repro.sharding.context import ParallelContext, SINGLE

from . import layers as L


def init(rng, cfg: ModelConfig, ctx: ParallelContext = SINGLE):
    dt = ctx.param_dtype
    k_embed, k_blocks, k_head = jax.random.split(rng, 3)

    def init_block(r):
        r1, r2, r3 = jax.random.split(r, 3)
        ks = jax.random.split(r2, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "attn": L.init_attention(
                r1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                dt, cfg.qkv_bias,
            ),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "router": L.dense_init(r3, cfg.d_model, cfg.n_experts, dt),
            "wg": jax.vmap(lambda k: L.dense_init(k, cfg.d_model, cfg.d_ff, dt))(
                jax.random.split(ks[0], cfg.n_experts)),
            "wu": jax.vmap(lambda k: L.dense_init(k, cfg.d_model, cfg.d_ff, dt))(
                jax.random.split(ks[1], cfg.n_experts)),
            "wd": jax.vmap(lambda k: L.dense_init(k, cfg.d_ff, cfg.d_model, dt))(
                jax.random.split(ks[2], cfg.n_experts)),
        }

    blocks = jax.vmap(init_block)(jax.random.split(k_blocks, cfg.n_layers))
    return {
        "embed": L.embed_init(k_embed, cfg.vocab, cfg.d_model, dt),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.vocab, dt),
    }


def _router(p, xf: jnp.ndarray, cfg: ModelConfig):
    """xf [N, D] -> (top_idx [N,k], top_w [N,k], aux_loss scalar)."""
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # [N, E]
    top_w, top_idx = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance loss
    frac = jnp.zeros((cfg.n_experts,), jnp.float32).at[top_idx.reshape(-1)].add(
        1.0
    ) / top_idx.size
    imp = probs.mean(0)
    aux = cfg.n_experts * jnp.sum(frac * imp)
    return top_idx.astype(jnp.int32), top_w, aux


def _moe_local(p, xf, top_idx, top_w, cfg: ModelConfig):
    """Single-device expert compute via the grouped FFN kernel."""
    n, d = xf.shape
    k = cfg.top_k
    x_rep = jnp.repeat(xf, k, axis=0)
    eid = top_idx.reshape(-1)
    y = grouped_ffn(x_rep, eid, p["wg"], p["wu"], p["wd"],
                    block_tokens=64, block_ffn=min(128, cfg.d_ff))
    y = (y.reshape(n, k, d) * top_w[..., None].astype(y.dtype)).sum(1)
    return y


def _moe_ep(p, xf, top_idx, top_w, cfg: ModelConfig, ctx: ParallelContext,
            dispatcher: MoEDispatcher):
    """Expert-parallel path (inside shard_map): NIMBLE dispatch/combine."""
    epd = cfg.n_experts // ctx.ep_size
    recv, e_local, state = dispatcher.dispatch(xf, top_idx)
    n, C, ct, d = recv.shape
    flat = recv.reshape(n * C * ct, d)
    eids = e_local.reshape(n * C * ct)
    y = grouped_ffn(flat, eids, p["wg"], p["wu"], p["wd"],
                    block_tokens=64, block_ffn=min(128, cfg.d_ff))
    out = dispatcher.combine(y.reshape(n, C, ct, d), state, top_w)
    return out


def make_moe_ffn(cfg: ModelConfig, ctx: ParallelContext):
    """Build the (possibly shard_mapped) MoE FFN apply function."""
    if ctx.ep_size <= 1:
        def apply(p, x):
            b, s, d = x.shape
            xf = x.reshape(-1, d)
            ti, tw, aux = _router(p, xf, cfg)
            y = _moe_local(p, xf, ti, tw, cfg)
            return y.reshape(b, s, d).astype(x.dtype), aux
        return apply

    comm_cfg = MoECommConfig(
        n_devices=ctx.ep_size,
        n_experts=cfg.n_experts,
        d_model=cfg.d_model,
        chunk_tokens=ctx.moe_chunk_tokens,
        capacity_factor=cfg.moe_capacity_factor,
        group_size=ctx.group_size,
        alt_frac=ctx.moe_alt_frac,
        mode=ctx.moe_mode,
        payload_dtype=ctx.compute_dtype,
    )
    if ctx.session is not None:
        # endpoint API: the session supplies cost model, planner config,
        # and (when adaptive) runtime telemetry wiring — see DESIGN.md §5
        dispatcher = ctx.session.moe_dispatcher(ctx.model_axis, comm_cfg)
    else:
        dispatcher = MoEDispatcher(ctx.model_axis, comm_cfg)
    from jax.sharding import PartitionSpec as P

    expert_spec = P(ctx.model_axis, None, None)
    mesh_sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    data_prod = 1
    for a in ctx.data_axes:
        data_prod *= mesh_sizes.get(a, 1)
    full_prod = data_prod * mesh_sizes.get(ctx.model_axis, 1)

    def _inner_full(wg, wu, wd, xf, ti, tw):
        pp = {"wg": wg, "wu": wu, "wd": wd}
        return _moe_ep(pp, xf, ti, tw, cfg, ctx, dispatcher)

    def _inner_masked(wg, wu, wd, xf, ti, tw):
        """Tokens replicated over the model axis (small decode batches):
        each model device owns a disjoint round-robin slice, routes only
        owned tokens, and the owned outputs are merged with a psum
        (DESIGN.md §8)."""
        pp = {"wg": wg, "wu": wu, "wd": wd}
        me = jax.lax.axis_index(ctx.model_axis)
        T = xf.shape[0]
        owned = (jnp.arange(T) % ctx.ep_size) == me
        recv, e_local, state = dispatcher.dispatch(xf, ti, token_valid=owned)
        n, C, ct, d = recv.shape
        y = grouped_ffn(
            recv.reshape(n * C * ct, d), e_local.reshape(n * C * ct),
            pp["wg"], pp["wu"], pp["wd"],
            block_tokens=64, block_ffn=min(128, cfg.d_ff),
        )
        out = dispatcher.combine(y.reshape(n, C, ct, d), state, tw)
        return jax.lax.psum(out, ctx.model_axis)

    def apply(p, x):
        b, s, d = x.shape
        xf = x.reshape(-1, d)
        n_tok = b * s
        ti, tw, aux = _router(p, xf, cfg)
        if n_tok % full_prod == 0:
            tok_spec = P(ctx.token_axes, None)
            inner = _inner_full
        elif n_tok % data_prod == 0:
            tok_spec = P(tuple(ctx.data_axes), None)
            inner = _inner_masked
        else:
            tok_spec = P(None, None)     # tiny batches: fully replicated
            inner = _inner_masked
        y = shard_map(
            inner,
            mesh=ctx.mesh,
            in_specs=(expert_spec, expert_spec, expert_spec,
                      tok_spec, tok_spec, tok_spec),
            out_specs=tok_spec,
            check_vma=False,
        )(p["wg"], p["wu"], p["wd"], xf, ti, tw)
        return y.reshape(b, s, d).astype(x.dtype), aux

    return apply


def _block_fwd(p, x, cfg: ModelConfig, moe_apply, window, pos_offset=0):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + L.attention_forward(
        p["attn"], h,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, causal=True, window=window,
        pos_offset=pos_offset,
    )
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    y, aux = moe_apply(p, h)
    return x + y, aux


def forward(
    params, tokens: jnp.ndarray, cfg: ModelConfig,
    ctx: ParallelContext = SINGLE, *, window=None, last_only: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B, S] -> (logits [B, S, V], aux_loss scalar)."""
    x = params["embed"][tokens].astype(ctx.compute_dtype)
    moe_apply = make_moe_ffn(cfg, ctx)
    # NOTE (§Perf D, refuted for MoE): pinning batch to the data axes here
    # (as dense.forward does) MEASURED worse on qwen3-moe (+9.5% memory,
    # +80% collective) — it fights the EP shard_map's token layout (tokens
    # sharded over data x model), inserting a reshard every layer.

    def body(x, p):
        fn = _block_fwd
        if ctx.remat:
            fn = jax.checkpoint(fn, static_argnums=(2, 3, 4))
        x, aux = fn(p, x, cfg, moe_apply, window)
        return x, aux

    x, auxs = jax.lax.scan(body, x, params["blocks"])
    if last_only:
        x = x[:, -1:]                    # §Perf B1: slice before lm_head
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"], auxs.mean()


# -- serving ---------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               ctx: ParallelContext = SINGLE):
    def one(_):
        return L.init_kv_cache(
            batch, cfg.n_kv_heads, cache_len, cfg.head_dim, ctx.compute_dtype
        )
    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def decode_step(params, cache, token, pos, cfg: ModelConfig,
                ctx: ParallelContext = SINGLE):
    x = params["embed"][token][:, None, :].astype(ctx.compute_dtype)
    moe_apply = make_moe_ffn(cfg, ctx)

    def body(x, pc):
        p, c = pc
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        a, c = L.attention_decode(
            p["attn"], h, c, pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
        )
        x = x + a
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        y, _ = moe_apply(p, h)
        return x + y, c

    x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x @ params["lm_head"])[:, 0], cache
