"""Mamba2-style selective SSM blocks (zamba2 backbone; standalone SSM).

Implements the SSD (state-space duality) recurrence with per-head scalar
decay, chunked for training:

    h_t = exp(A * dt_t) * h_{t-1} + dt_t * B_t ⊗ x_t         (state [P, N])
    y_t = C_t · h_t + D * x_t

Training uses a chunk-parallel scan (intra-chunk cumulative decay + carried
chunk states via ``lax.scan``) — O(S·N·P) instead of quadratic attention,
which is what qualifies the hybrid/SSM archs for ``long_500k``.  Decode is
the O(1) recurrent update on a carried state.

NIMBLE applicability: none — the recurrence is sequence-local and the only
collectives are balanced TP/DP (DESIGN.md §7).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.context import ParallelContext, SINGLE

from . import layers as L


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or max(1, d_inner // 64)
    P = d_inner // H          # head channel dim
    N = cfg.ssm_state         # state dim
    return d_inner, H, P, N


def init_mamba_block(rng, cfg: ModelConfig, dtype):
    d_inner, H, P, N = _dims(cfg)
    ks = jax.random.split(rng, 5)
    # in_proj emits [z (gate), x, B, C, dt] fused as in Mamba2
    d_in_proj = 2 * d_inner + 2 * N * H + H
    return {
        "norm": jnp.ones((cfg.d_model,), dtype),
        "in_proj": L.dense_init(ks[0], cfg.d_model, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_inner)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_proj": L.dense_init(ks[2], d_inner, cfg.d_model, dtype),
        "gate_norm": jnp.ones((d_inner,), dtype),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    d_inner, H, P, N = _dims(cfg)
    z, x, B, C, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + N * H, 2 * d_inner + 2 * N * H],
        axis=-1,
    )
    return z, x, B, C, dt


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv1d.  x: [B, S, C]; w: [K, C].  state: [B, K-1, C]."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return jax.nn.silu(out), new_state


def _ssd_chunked(x, dt, B, C, A, D, chunk: int = 128):
    """Chunk-parallel SSD scan.

    x: [Bt, S, H, P]; dt: [Bt, S, H]; B, C: [Bt, S, H, N]; A: [H] (negative).
    Returns y: [Bt, S, H, P].
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = nc * chunk
    xc = x.reshape(Bt, nc, chunk, H, P)
    dtc = dt.reshape(Bt, nc, chunk, H)
    Bc = B.reshape(Bt, nc, chunk, H, N)
    Cc = C.reshape(Bt, nc, chunk, H, N)

    dA = dtc * A[None, None, None, :]                  # [Bt,nc,L,H] (<=0)
    cum = jnp.cumsum(dA, axis=2)                       # within-chunk log decay
    total = cum[:, :, -1]                              # [Bt,nc,H]

    # intra-chunk (quadratic within chunk, causal)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [Bt,nc,Li,Lj,H]
    li = jnp.arange(chunk)
    causal = li[:, None] >= li[None, :]
    # mask BEFORE exp: non-causal entries have seg >= 0 (cum is decreasing),
    # exp would overflow and where()'s grad turns inf*0 into NaN.
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)
    G = jnp.einsum("bclhn,bcmhn->bclmh", Cc, Bc)          # [Bt,nc,Li,Lj,H]
    M = G * decay
    y_intra = jnp.einsum("bclmh,bcmh,bcmhp->bclhp", M, dtc, xc)

    # chunk states: S_c = sum_j exp(total - cum_j) * dt_j * B_j x_j^T
    w = jnp.exp(total[:, :, None, :] - cum) * dtc          # [Bt,nc,L,H]
    states = jnp.einsum("bclh,bclhn,bclhp->bchnp", w, Bc, xc)

    # inter-chunk recurrence over carried state
    def scan_fn(h, inp):
        st, tot = inp                                       # [Bt,H,N,P],[Bt,H]
        h_out = h
        h = h * jnp.exp(tot)[:, :, None, None] + st
        return h, h_out

    h0 = jnp.zeros((Bt, H, N, P), x.dtype)
    _, h_prev = jax.lax.scan(
        scan_fn,
        h0,
        (states.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)               # [Bt,nc,H,N,P]
    y_inter = jnp.einsum(
        "bclhn,bclh,bchnp->bclhp", Cc, jnp.exp(cum), h_prev
    )
    y = (y_intra + y_inter).reshape(Bt, Sp, H, P)[:, :S]
    return y + x.reshape(Bt, Sp, H, P)[:, :S] * D[None, None, :, None]


def mamba_forward(p, x, cfg: ModelConfig):
    """x: [B, S, D] -> [B, S, D] (residual applied by caller)."""
    d_inner, H, P, N = _dims(cfg)
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z, xi, Bv, Cv, dt = _split_proj(zxbcdt, cfg)
    xi, _ = _causal_conv(xi, p["conv_w"][:, :d_inner], p["conv_b"], None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    Bt, S = x.shape[:2]
    y = _ssd_chunked(
        xi.reshape(Bt, S, H, P).astype(jnp.float32),
        dt,
        Bv.reshape(Bt, S, H, N).astype(jnp.float32),
        Cv.reshape(Bt, S, H, N).astype(jnp.float32),
        A,
        p["D"],
    ).reshape(Bt, S, d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"]


# -- decode ------------------------------------------------------------------


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    d_inner, H, P, N = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def mamba_decode(p, x, cache, cfg: ModelConfig):
    """x: [B, 1, D]; O(1) recurrent update."""
    d_inner, H, P, N = _dims(cfg)
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z, xi, Bv, Cv, dt = _split_proj(zxbcdt, cfg)
    xi, conv_state = _causal_conv(
        xi, p["conv_w"][:, :d_inner], p["conv_b"], cache["conv"]
    )
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # [B,H]
    A = -jnp.exp(p["A_log"])
    xh = xi[:, 0].reshape(-1, H, P).astype(jnp.float32)
    Bh = Bv[:, 0].reshape(-1, H, N).astype(jnp.float32)
    Ch = Cv[:, 0].reshape(-1, H, N).astype(jnp.float32)
    decay = jnp.exp(dt * A[None, :])                                   # [B,H]
    hs = cache["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, Bh, xh
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, hs) + xh * p["D"][None, :, None]
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"conv": conv_state, "ssm": hs}
