"""xLSTM blocks [arXiv:2405.04517]: alternating sLSTM and mLSTM layers.

* **mLSTM** — per-head matrix memory C ∈ R^{dh×dh} with stabilized
  exponential input/forget gating:

      m_t = max(logsig(f_t) + m_{t-1}, i_t)
      C_t = e^{logsig(f)+m_{t-1}-m_t} C_{t-1} + e^{i_t-m_t} k_t v_tᵀ
      n_t = e^{logsig(f)+m_{t-1}-m_t} n_{t-1} + e^{i_t-m_t} k_t
      h_t = (C_tᵀ q_t) / max(|n_tᵀ q_t|, e^{-m_t})

* **sLSTM** — scalar memory per channel with exponential gating and the
  same max-stabilizer.

Both train via ``lax.scan`` over time (the recurrent cell IS the layer, so
decode parity is exact by construction); the recurrence is O(S·dh²) —
sub-quadratic in sequence length, which is what runs ``long_500k``.  The
chunkwise-parallel mLSTM (TFLA-style) is a §Perf candidate, not required
for correctness.

Attention-free: NIMBLE inapplicable (DESIGN.md §7); built without.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.context import ParallelContext, SINGLE

from . import layers as L


def _dims(cfg: ModelConfig):
    H = cfg.n_heads
    dh = cfg.d_model // H
    return H, dh


# --------------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------------- #


def init_mlstm(rng, cfg: ModelConfig, dtype):
    H, dh = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(rng, 7)
    return {
        "norm": jnp.ones((d,), dtype),
        "wq": L.dense_init(ks[0], d, d, dtype),
        "wk": L.dense_init(ks[1], d, d, dtype),
        "wv": L.dense_init(ks[2], d, d, dtype),
        "wi": L.dense_init(ks[3], d, H, dtype, scale=0.02),
        "wf": L.dense_init(ks[4], d, H, dtype, scale=0.02),
        "bi": jnp.zeros((H,), jnp.float32),
        "bf": jnp.full((H,), 3.0, jnp.float32),
        "wg": L.dense_init(ks[5], d, d, dtype),
        "gate_norm": jnp.ones((d,), dtype),
        "wo": L.dense_init(ks[6], d, d, dtype),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int):
    H, dh = _dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -30.0, jnp.float32),
    }


def _mlstm_cell(state, qkvif):
    q, k, v, ig, fg = qkvif       # q,k,v: [B,H,dh]; ig,fg: [B,H]
    C, n, m = state["C"], state["n"], state["m"]
    lf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(lf + m, ig)
    a = jnp.exp(lf + m - m_new)                    # [B,H]
    b = jnp.exp(ig - m_new)
    C = C * a[..., None, None] + b[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = n * a[..., None] + b[..., None] * k
    num = jnp.einsum("bhdp,bhd->bhp", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return {"C": C, "n": n, "m": m_new}, h


def _mlstm_qkvif(p, h, cfg: ModelConfig):
    H, dh = _dims(cfg)
    B_, S, D = h.shape
    q = (h @ p["wq"]).reshape(B_, S, H, dh).astype(jnp.float32) / (dh ** 0.5)
    k = (h @ p["wk"]).reshape(B_, S, H, dh).astype(jnp.float32) / (dh ** 0.25)
    v = (h @ p["wv"]).reshape(B_, S, H, dh).astype(jnp.float32)
    ig = (h @ p["wi"]).astype(jnp.float32) + p["bi"]
    fg = (h @ p["wf"]).astype(jnp.float32) + p["bf"]
    return q, k, v, ig, fg


def mlstm_forward(p, x, cfg: ModelConfig, state=None):
    B_, S, D = x.shape
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v, ig, fg = _mlstm_qkvif(p, h, cfg)
    st = state or init_mlstm_state(cfg, B_)

    def step(st, inp):
        return _mlstm_cell(st, inp)

    st, ys = jax.lax.scan(
        step, st,
        (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
         v.transpose(1, 0, 2, 3), ig.transpose(1, 0, 2), fg.transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2, 3).reshape(B_, S, D).astype(x.dtype)
    og = jax.nn.sigmoid(h @ p["wg"])
    y = L.rms_norm(y * og, p["gate_norm"], cfg.norm_eps)
    return y @ p["wo"], st


def _mlstm_chunk_body(carry, inp, L: int):
    """One chunk of the chunkwise-parallel mLSTM (TFLA-style).

    Exact (not approximate) reformulation of the per-step cell: the carried
    (C, n, m) state uses the SAME stabilized convention as ``_mlstm_cell``,
    so chunked-vs-scan equality is bitwise up to float associativity
    (asserted in tests).  Per chunk the matrix memory is read/written once
    instead of L times — the §Perf memory-term optimization.

    Derivation: with Lf_t = Σ_{r<=t} logsig(f_r) (within-chunk) and
    g_j = i_j - Lf_j, the running stabilizer is m_t = Lf_t + u_t where
    u_t = max(m_in, cummax_{j<=t} g_j), and

        C_t = e^{m_in - u_t} C_in + Σ_{j<=t} e^{g_j - u_t} k_j v_j^T
        h_t = (C_t^T q_t) / max(|n_t . q_t|, e^{-m_t})
    """
    q, k, v, ig, lf = inp          # q,k,v: [B,L,H,dh]; ig,lf: [B,L,H]
    C_in, n_in, m_in = carry["C"], carry["n"], carry["m"]
    Lf = jnp.cumsum(lf, axis=1)                        # [B,L,H]
    g = ig - Lf
    u = jnp.maximum(m_in[:, None], jax.lax.cummax(g, axis=1))
    m = Lf + u                                          # global m_t
    # intra-chunk causal weights  W[t, j] = e^{g_j - u_t}  (j <= t)
    seg = g[:, None, :] - u[:, :, None]                # [B,Lt,Lj,H]
    li = jnp.arange(L)
    causal = li[:, None] >= li[None, :]
    seg = jnp.where(causal[None, :, :, None], seg, -jnp.inf)  # mask pre-exp
    W = jnp.exp(seg)
    scores = jnp.einsum("bthd,bjhd->btjh", q, k) * W   # [B,Lt,Lj,H]
    num = jnp.einsum("btjh,bjhd->bthd", scores, v)
    den = scores.sum(axis=2)                           # [B,Lt,H]
    # inter-chunk contribution from the carried state
    w_in = jnp.exp(m_in[:, None] - u)                  # [B,L,H]
    num = num + w_in[..., None] * jnp.einsum("bhdp,bthd->bthp", C_in, q)
    den = den + w_in * jnp.einsum("bhd,bthd->bth", n_in, q)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
    # carry out (stabilized at m_L = Lf_L + u_L, the cell's convention)
    u_L = u[:, -1]                                     # [B,H]
    wj = jnp.exp(g - u_L[:, None])                     # [B,L,H]
    C_out = (jnp.exp(m_in - u_L)[..., None, None] * C_in
             + jnp.einsum("bjh,bjhd,bjhp->bhdp", wj, k, v))
    n_out = jnp.exp(m_in - u_L)[..., None] * n_in \
        + jnp.einsum("bjh,bjhd->bhd", wj, k)
    m_out = Lf[:, -1] + u_L
    return {"C": C_out, "n": n_out, "m": m_out}, h


def mlstm_forward_chunked(p, x, cfg: ModelConfig, state=None, chunk: int = 64):
    """Chunkwise-parallel mLSTM forward — same result as ``mlstm_forward``."""
    B_, S, D = x.shape
    H, dh = _dims(cfg)
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v, ig, fg = _mlstm_qkvif(p, h, cfg)
    lf = jax.nn.log_sigmoid(fg)
    Lc = min(chunk, S)
    nc = -(-S // Lc)
    pad = nc * Lc - S
    if pad:
        # pad with f = -inf-ish decays? simpler: pad with neutral inputs
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
    rc = lambda a: a.reshape((B_, nc, Lc) + a.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, a.ndim + 1)))
    st = state or init_mlstm_state(cfg, B_)
    st, ys = jax.lax.scan(
        functools.partial(_mlstm_chunk_body, L=Lc), st,
        (rc(q), rc(k), rc(v), rc(ig), rc(lf)),
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, nc * Lc, H * dh)[:, :S]
    y = y.astype(x.dtype)
    og = jax.nn.sigmoid(h @ p["wg"])
    y = L.rms_norm(y * og, p["gate_norm"], cfg.norm_eps)
    return y @ p["wo"], st


# --------------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------------- #


def init_slstm(rng, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(rng, 6)
    return {
        "norm": jnp.ones((d,), dtype),
        "wz": L.dense_init(ks[0], d, d, dtype),
        "wi": L.dense_init(ks[1], d, d, dtype, scale=0.02),
        "wf": L.dense_init(ks[2], d, d, dtype, scale=0.02),
        "wo_gate": L.dense_init(ks[3], d, d, dtype, scale=0.02),
        "bf": jnp.full((d,), 3.0, jnp.float32),
        "up": L.dense_init(ks[4], d, 2 * d, dtype),
        "down": L.dense_init(ks[5], d, d, dtype),
    }


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": z - 30.0, "h": z}


def _slstm_cell(state, zifo):
    z, ig, fg, og = zifo          # all [B, D]
    c, n, m, _ = state["c"], state["n"], state["m"], state["h"]
    lf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(lf + m, ig)
    a = jnp.exp(lf + m - m_new)
    b = jnp.exp(ig - m_new)
    c = c * a + b * jnp.tanh(z)
    n = n * a + b
    h = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h}, h


def _lin_scan_raw(a, u):
    """Prefix of y_t = a_t * y_{t-1} + u_t along axis=1 (no custom grad)."""

    def comb(e1, e2):
        a1, u1 = e1
        a2, u2 = e2
        return a1 * a2, u1 * a2 + u2

    _, y = jax.lax.associative_scan(comb, (a, u), axis=1)
    return y


@jax.custom_vjp
def linear_prefix(a, u):
    """First-order linear recurrence with a hand-written adjoint.

    Differentiating *through* ``associative_scan`` emits per-level pad/slice
    traffic (~35% of the memory term in the dry-run profile).  The adjoint
    of y_t = a_t y_{t-1} + u_t is itself a REVERSE linear recurrence
        c̄_t = ȳ_t + a_{t+1} c̄_{t+1},   ā_t = c̄_t y_{t-1},   ū_t = c̄_t,
    so backward is one more associative_scan instead of an unrolled
    differentiated tree (§Perf iteration A3).
    """
    return _lin_scan_raw(a, u)


def _linear_prefix_fwd(a, u):
    y = _lin_scan_raw(a, u)
    return y, (a, y)


def _linear_prefix_bwd(res, g):
    a, y = res
    a_next = jnp.concatenate([a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)
    cbar = jnp.flip(
        _lin_scan_raw(jnp.flip(a_next, axis=1), jnp.flip(g, axis=1)), axis=1
    )
    y_prev = jnp.concatenate([jnp.zeros_like(y[:, :1]), y[:, :-1]], axis=1)
    return cbar * y_prev, cbar


linear_prefix.defvjp(_linear_prefix_fwd, _linear_prefix_bwd)


def _maxplus_scan_raw(s, v):
    """Prefix of m_t = max(m_{t-1} + s_t, v_t) along axis=1."""

    def comb(e1, e2):
        s1, v1 = e1
        s2, v2 = e2
        return s1 + s2, jnp.maximum(v1 + s2, v2)

    _, m = jax.lax.associative_scan(comb, (s, v), axis=1)
    return m


@jax.custom_vjp
def maxplus_prefix(s, v):
    """Max-plus recurrence with a hand-written adjoint (§Perf iteration A4).

    Forward picks carry (m_{t-1}+s_t) or fresh (v_t) per step; the adjoint
    routes m̄ backward along the carry-selection chain:
        c̄_t = m̄_t + sel_{t+1} c̄_{t+1}
    (a reverse linear recurrence with binary coefficients), then
    s̄_t = sel_t c̄_t and v̄_t = (1 - sel_t) c̄_t.
    """
    return _maxplus_scan_raw(s, v)


def _maxplus_fwd(s, v):
    m = _maxplus_scan_raw(s, v)
    return m, (s, v, m)


def _maxplus_bwd(res, g):
    s, v, m = res
    m_prev = jnp.concatenate(
        [jnp.full_like(m[:, :1], -jnp.inf), m[:, :-1]], axis=1
    )
    sel = (m_prev + s >= v).astype(g.dtype)      # 1 = carry selected
    sel_next = jnp.concatenate([sel[:, 1:], jnp.zeros_like(sel[:, :1])],
                               axis=1)
    cbar = jnp.flip(
        _lin_scan_raw(jnp.flip(sel_next, axis=1), jnp.flip(g, axis=1)), axis=1
    )
    return sel * cbar, (1.0 - sel) * cbar


maxplus_prefix.defvjp(_maxplus_fwd, _maxplus_bwd)


def slstm_forward_assoc(p, x, cfg: ModelConfig, state=None):
    """sLSTM via two ``associative_scan``s (§Perf memory-term optimization).

    This implementation's sLSTM gates depend only on the layer input (no
    h-feedback), so the recurrence factors into
      1. a max-plus prefix  m_t = max(m_{t-1} + lf_t, ig_t)
         (elements (s, v) combine as (s1+s2, max(v1+s2, v2))), and
      2. two linear prefixes c_t = a_t c_{t-1} + u_t, n_t likewise
         (elements (a, u) combine as (a1*a2, u1*a2 + u2)),
    both log-depth — no 4096-trip while loop, ~two full-array passes of HBM
    traffic instead of thousands of per-step round-trips.  Exact up to float
    associativity (tests assert allclose vs the cell scan).
    """
    B_, S, D = x.shape
    hpre = L.rms_norm(x, p["norm"], cfg.norm_eps)
    z = (hpre @ p["wz"]).astype(jnp.float32)
    ig = (hpre @ p["wi"]).astype(jnp.float32)
    fg = (hpre @ p["wf"]).astype(jnp.float32) + p["bf"]
    og = (hpre @ p["wo_gate"]).astype(jnp.float32)
    st = state or init_slstm_state(cfg, B_)
    lf = jax.nn.log_sigmoid(fg)                       # [B,S,D]

    # 1. stabilizer prefix (seed the carried m as a virtual step 0)
    s_el = jnp.concatenate([jnp.zeros((B_, 1, D)), lf], axis=1)
    v_el = jnp.concatenate([st["m"][:, None], ig], axis=1)
    m_all = maxplus_prefix(s_el, v_el)
    m_prev, m = m_all[:, :-1], m_all[:, 1:]
    a = jnp.exp(lf + m_prev - m)                      # decay  (<= 1)
    b = jnp.exp(ig - m)                               # input weight

    # 2. linear prefixes for c and n (seed carried state as step 0: a=1)
    ones = jnp.ones((B_, 1, D))
    a_el = jnp.concatenate([ones, a], axis=1)
    c_el = jnp.concatenate([st["c"][:, None], b * jnp.tanh(z)], axis=1)
    n_el = jnp.concatenate([st["n"][:, None], b], axis=1)
    c = linear_prefix(a_el, c_el)[:, 1:]
    n = linear_prefix(a_el, n_el)[:, 1:]

    h = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1e-6)
    new_state = {"c": c[:, -1], "n": n[:, -1], "m": m[:, -1], "h": h[:, -1]}
    y = h.astype(x.dtype)
    y = jax.nn.gelu(y @ p["up"][:, :D]) * (y @ p["up"][:, D:])
    return y @ p["down"], new_state


def slstm_forward(p, x, cfg: ModelConfig, state=None):
    B_, S, D = x.shape
    hpre = L.rms_norm(x, p["norm"], cfg.norm_eps)
    z = (hpre @ p["wz"]).astype(jnp.float32)
    ig = (hpre @ p["wi"]).astype(jnp.float32)
    fg = (hpre @ p["wf"]).astype(jnp.float32) + p["bf"]
    og = (hpre @ p["wo_gate"]).astype(jnp.float32)
    st = state or init_slstm_state(cfg, B_)
    st, ys = jax.lax.scan(
        _slstm_cell, st,
        (z.transpose(1, 0, 2), ig.transpose(1, 0, 2),
         fg.transpose(1, 0, 2), og.transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    # post-projection (GEGLU-ish up/down as in the xLSTM block)
    y = jax.nn.gelu(y @ p["up"][:, :D]) * (y @ p["up"][:, D:])
    return y @ p["down"], st


# --------------------------------------------------------------------------- #
# full model
# --------------------------------------------------------------------------- #


def is_slstm_layer(cfg: ModelConfig, i: int) -> bool:
    per = max(cfg.slstm_every, 1)
    return (i % per) == (per - 1)


def init(rng, cfg: ModelConfig, ctx: ParallelContext = SINGLE):
    dt = ctx.param_dtype
    k_e, k_b, k_h = jax.random.split(rng, 3)
    ks = jax.random.split(k_b, cfg.n_layers)
    blocks = []
    for i in range(cfg.n_layers):
        if is_slstm_layer(cfg, i):
            blocks.append(init_slstm(ks[i], cfg, dt))
        else:
            blocks.append(init_mlstm(ks[i], cfg, dt))
    return {
        "embed": L.embed_init(k_e, cfg.vocab, cfg.d_model, dt),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": L.dense_init(k_h, cfg.d_model, cfg.vocab, dt),
    }


def forward(params, tokens, cfg: ModelConfig, ctx: ParallelContext = SINGLE,
            *, last_only: bool = False, **_):
    x = params["embed"][tokens].astype(ctx.compute_dtype)
    for i, p in enumerate(params["blocks"]):
        if is_slstm_layer(cfg, i):
            fwd = slstm_forward_assoc if cfg.slstm_assoc else slstm_forward
            y, _ = fwd(p, x, cfg)
        elif cfg.mlstm_chunk > 0:
            y, _ = mlstm_forward_chunked(p, x, cfg, chunk=cfg.mlstm_chunk)
        else:
            y, _ = mlstm_forward(p, x, cfg)
        x = x + y
    if last_only:
        x = x[:, -1:]                    # §Perf B1: slice before lm_head
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps) @ params["lm_head"]


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               ctx: ParallelContext = SINGLE):
    caches = []
    for i in range(cfg.n_layers):
        if is_slstm_layer(cfg, i):
            caches.append(init_slstm_state(cfg, batch))
        else:
            caches.append(init_mlstm_state(cfg, batch))
    return caches


def decode_step(params, cache, token, pos, cfg: ModelConfig,
                ctx: ParallelContext = SINGLE):
    x = params["embed"][token][:, None, :].astype(ctx.compute_dtype)
    new_cache = []
    for i, (p, st) in enumerate(zip(params["blocks"], cache)):
        if is_slstm_layer(cfg, i):
            y, st = slstm_forward(p, x, cfg, state=st)
        else:
            y, st = mlstm_forward(p, x, cfg, state=st)
        x = x + y
        new_cache.append(st)
    lg = L.rms_norm(x, params["final_norm"], cfg.norm_eps) @ params["lm_head"]
    return lg[:, 0], new_cache
