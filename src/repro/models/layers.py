"""Shared model layers: norms, RoPE, GQA attention (+caches), SwiGLU.

Functional style: ``init_*(rng, ...) -> params`` (nested dicts of arrays)
and pure apply functions.  Layer stacks are scanned (stacked params with a
leading layer axis) so 94-layer configs lower to a single compiled block.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import attention as flash_attention

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #


def dense_init(rng, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out)) * scale).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype):
    return (jax.random.normal(rng, (vocab, d)) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layer_norm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w + b


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #


def rope_freqs(dh: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, dh]; pos: [S] (or [..., S]) absolute positions."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [dh/2]
    ang = pos[..., :, None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# GQA attention
# --------------------------------------------------------------------------- #


def init_attention(rng, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   dtype, qkv_bias: bool = False) -> Params:
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _project_qkv(p: Params, x, n_heads, n_kv, head_dim):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, n_kv, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, n_kv, head_dim).transpose(0, 2, 1, 3)
    return q, k, v


def attention_forward(
    p: Params,
    x: jnp.ndarray,              # [B, S, D]
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float | None,
    causal: bool = True,
    window: Optional[int] = None,
    pos_offset: int = 0,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill path, flash kernel)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim)
    if rope_theta is not None:
        pos = jnp.arange(s) + pos_offset
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    o = flash_attention(q, k, v, causal, window, pos_offset)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
    return o @ p["wo"]


# -- KV caches ------------------------------------------------------------------


def init_kv_cache(batch: int, n_kv: int, cache_len: int, head_dim: int,
                  dtype) -> Params:
    """Ring-buffer KV cache.  ``cache_len`` = window for SWA, seq for full."""
    return {
        "k": jnp.zeros((batch, n_kv, cache_len, head_dim), dtype),
        "v": jnp.zeros((batch, n_kv, cache_len, head_dim), dtype),
        "slot_pos": jnp.full((cache_len,), -1, jnp.int32),  # absolute pos
    }


def attention_decode(
    p: Params,
    x: jnp.ndarray,              # [B, 1, D] current token
    cache: Params,
    pos: jnp.ndarray,            # scalar int32 absolute position
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta: float | None,
) -> Tuple[jnp.ndarray, Params]:
    """One decode step against a ring-buffer cache (RoPE at write time)."""
    b = x.shape[0]
    W = cache["k"].shape[2]
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim)   # [B,H,1,dh]
    if rope_theta is not None:
        ppos = pos[None] if pos.ndim == 0 else pos
        q = apply_rope(q, ppos, rope_theta)
        k = apply_rope(k, ppos, rope_theta)
    slot = jnp.mod(pos, W)                                   # ring write
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))
    spos = cache["slot_pos"].at[slot].set(pos.astype(jnp.int32))

    g = n_heads // n_kv
    kk = jnp.repeat(ck, g, axis=1).astype(jnp.float32)       # [B,H,W,dh]
    vv = jnp.repeat(cv, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk)
    s = s / math.sqrt(head_dim)
    valid = (spos >= 0) & (spos <= pos)                      # [W]
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", w, vv).astype(x.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, n_heads * head_dim)
    return o @ p["wo"], {"k": ck, "v": cv, "slot_pos": spos}


# --------------------------------------------------------------------------- #
# SwiGLU MLP
# --------------------------------------------------------------------------- #


def init_swiglu(rng, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    return {
        "wg": dense_init(ks[0], d_model, d_ff, dtype),
        "wu": dense_init(ks[1], d_model, d_ff, dtype),
        "wd": dense_init(ks[2], d_ff, d_model, dtype),
    }


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


# --------------------------------------------------------------------------- #
# GELU MLP (whisper-style)
# --------------------------------------------------------------------------- #


def init_mlp(rng, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(rng, 2)
    return {
        "w1": dense_init(ks[0], d_model, d_ff, dtype),
        "b1": jnp.zeros((d_ff,), dtype),
        "w2": dense_init(ks[1], d_ff, d_model, dtype),
        "b2": jnp.zeros((d_model,), dtype),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
