"""Whisper-style encoder-decoder [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: the encoder consumes precomputed frame embeddings
``frames [B, n_audio_frames, d]`` (what the conv stack would emit), adds
sinusoidal positions, and runs bidirectional pre-LN attention blocks.  The
decoder is causal self-attention + cross-attention to the encoder output.

Serving: cross-attention K/V are computed once from the encoder output and
held in the cache alongside the self-attention ring cache.  ``long_500k``
is skipped for this arch (30 s context enc-dec; DESIGN.md §7).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.context import ParallelContext, SINGLE

from . import layers as L


def _attn_out(p, q, k, v, n_heads, head_dim, causal, pos_offset=0):
    from repro.kernels.flash_attention.ops import attention
    b, s, _ = q.shape
    sk = k.shape[1]
    qh = q.reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)
    kh = k.reshape(b, sk, n_heads, head_dim).transpose(0, 2, 1, 3)
    vh = v.reshape(b, sk, n_heads, head_dim).transpose(0, 2, 1, 3)
    o = attention(qh, kh, vh, causal, None, pos_offset)
    return o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)


def _init_xattn(rng, d, dtype):
    ks = jax.random.split(rng, 4)
    return {
        "wq": L.dense_init(ks[0], d, d, dtype),
        "wk": L.dense_init(ks[1], d, d, dtype),
        "wv": L.dense_init(ks[2], d, d, dtype),
        "wo": L.dense_init(ks[3], d, d, dtype),
    }


def init(rng, cfg: ModelConfig, ctx: ParallelContext = SINGLE):
    dt = ctx.param_dtype
    d = cfg.d_model
    k_e, k_enc, k_dec, k_h = jax.random.split(rng, 4)

    def enc_block(r):
        r1, r2 = jax.random.split(r)
        return {
            "ln1": jnp.ones((d,), dt), "b_ln1": jnp.zeros((d,), dt),
            "attn": _init_xattn(r1, d, dt),
            "ln2": jnp.ones((d,), dt), "b_ln2": jnp.zeros((d,), dt),
            "mlp": L.init_mlp(r2, d, cfg.d_ff, dt),
        }

    def dec_block(r):
        r1, r2, r3 = jax.random.split(r, 3)
        return {
            "ln1": jnp.ones((d,), dt), "b_ln1": jnp.zeros((d,), dt),
            "self_attn": _init_xattn(r1, d, dt),
            "ln_x": jnp.ones((d,), dt), "b_ln_x": jnp.zeros((d,), dt),
            "cross_attn": _init_xattn(r2, d, dt),
            "ln2": jnp.ones((d,), dt), "b_ln2": jnp.zeros((d,), dt),
            "mlp": L.init_mlp(r3, d, cfg.d_ff, dt),
        }

    return {
        "embed": L.embed_init(k_e, cfg.vocab, d, dt),
        "dec_pos": (jax.random.normal(k_h, (4096, d)) * 0.01).astype(dt),
        "enc": jax.vmap(enc_block)(jax.random.split(k_enc, cfg.n_enc_layers)),
        "dec": jax.vmap(dec_block)(jax.random.split(k_dec, cfg.n_layers)),
        "enc_norm": jnp.ones((d,), dt), "b_enc_norm": jnp.zeros((d,), dt),
        "dec_norm": jnp.ones((d,), dt), "b_dec_norm": jnp.zeros((d,), dt),
    }


def encode(params, frames: jnp.ndarray, cfg: ModelConfig,
           ctx: ParallelContext = SINGLE) -> jnp.ndarray:
    """frames [B, F, d] (stub conv output) -> encoder states [B, F, d]."""
    b, f, d = frames.shape
    x = frames.astype(ctx.compute_dtype) + L.sinusoidal_positions(f, d).astype(
        ctx.compute_dtype
    )

    def body(x, p):
        h = L.layer_norm(x, p["ln1"], p["b_ln1"], cfg.norm_eps)
        q, k, v = h @ p["attn"]["wq"], h @ p["attn"]["wk"], h @ p["attn"]["wv"]
        x = x + _attn_out_proj(p["attn"], q, k, v, cfg)
        h = L.layer_norm(x, p["ln2"], p["b_ln2"], cfg.norm_eps)
        return x + L.mlp(p["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return L.layer_norm(x, params["enc_norm"], params["b_enc_norm"],
                        cfg.norm_eps)


def _attn_out_proj(p, q, k, v, cfg, causal=False, pos_offset=0):
    o = _attn_out(p, q, k, v, cfg.n_heads, cfg.head_dim, causal, pos_offset)
    return o @ p["wo"]


def decode(params, tokens: jnp.ndarray, enc_out: jnp.ndarray,
           cfg: ModelConfig, ctx: ParallelContext = SINGLE,
           last_only: bool = False) -> jnp.ndarray:
    """tokens [B, S], enc_out [B, F, d] -> logits [B, S, V]."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(ctx.compute_dtype)
    x = x + params["dec_pos"][:s].astype(ctx.compute_dtype)
    # §Perf PAIR D follow-up: pin batch to the data axes (propagation was
    # replicating the full global batch through the decoder stack).
    from repro.sharding.context import constrain_tokens
    x = constrain_tokens(x, ctx)

    def body(x, p):
        h = L.layer_norm(x, p["ln1"], p["b_ln1"], cfg.norm_eps)
        q = h @ p["self_attn"]["wq"]
        k = h @ p["self_attn"]["wk"]
        v = h @ p["self_attn"]["wv"]
        x = x + _attn_out_proj(p["self_attn"], q, k, v, cfg, causal=True)
        h = L.layer_norm(x, p["ln_x"], p["b_ln_x"], cfg.norm_eps)
        q = h @ p["cross_attn"]["wq"]
        k = enc_out @ p["cross_attn"]["wk"]
        v = enc_out @ p["cross_attn"]["wv"]
        x = x + _attn_out_proj(p["cross_attn"], q, k, v, cfg, causal=False)
        h = L.layer_norm(x, p["ln2"], p["b_ln2"], cfg.norm_eps)
        return x + L.mlp(p["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["dec"])
    if last_only:
        x = x[:, -1:]                    # §Perf B1: slice before lm_head
    x = L.layer_norm(x, params["dec_norm"], params["b_dec_norm"], cfg.norm_eps)
    return x @ params["embed"].T     # whisper ties output to embedding


def forward(params, tokens, cfg: ModelConfig, ctx: ParallelContext = SINGLE,
            *, frames=None, last_only: bool = False, **_):
    assert frames is not None, "audio arch requires stub frame embeddings"
    enc_out = encode(params, frames, cfg, ctx)
    return decode(params, tokens, enc_out, cfg, ctx, last_only=last_only)


# -- serving ---------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               ctx: ParallelContext = SINGLE, enc_out=None):
    """Self-attn ring caches + precomputed cross K/V (needs enc_out)."""
    self_c = jax.vmap(
        lambda _: L.init_kv_cache(batch, cfg.n_heads, cache_len,
                                  cfg.head_dim, ctx.compute_dtype)
    )(jnp.arange(cfg.n_layers))
    if enc_out is None:
        f = cfg.n_audio_frames
        enc_out = jnp.zeros((batch, f, cfg.d_model), ctx.compute_dtype)
    return {"self": self_c, "enc_out": enc_out}


def decode_step(params, cache, token, pos, cfg: ModelConfig,
                ctx: ParallelContext = SINGLE):
    b = token.shape[0]
    x = params["embed"][token][:, None, :].astype(ctx.compute_dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], pos, 1, 0
    ).astype(ctx.compute_dtype)
    enc_out = cache["enc_out"]

    def body(x, pc):
        p, c = pc
        h = L.layer_norm(x, p["ln1"], p["b_ln1"], cfg.norm_eps)
        a, c = L.attention_decode(
            p["self_attn"], h, c, pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_heads, head_dim=cfg.head_dim,
            rope_theta=None,
        )
        x = x + a
        h = L.layer_norm(x, p["ln_x"], p["b_ln_x"], cfg.norm_eps)
        q = h @ p["cross_attn"]["wq"]
        k = enc_out @ p["cross_attn"]["wk"]
        v = enc_out @ p["cross_attn"]["wv"]
        x = x + _attn_out_proj(p["cross_attn"], q, k, v, cfg, causal=False)
        h = L.layer_norm(x, p["ln2"], p["b_ln2"], cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h)
        return x, c

    x, self_c = jax.lax.scan(body, x, (params["dec"], cache["self"]))
    x = L.layer_norm(x, params["dec_norm"], params["b_dec_norm"], cfg.norm_eps)
    lg = (x @ params["embed"].T)[:, 0]
    return lg, {"self": self_c, "enc_out": enc_out}
