"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block.

Zamba2 [arXiv:2411.15242] interleaves a single *shared* full-attention
(+MLP) block into a Mamba2 tower — the same attention parameters are reused
at every invocation point (every ``cfg.attn_every`` layers).  We implement
exactly that sharing; the per-invocation LoRA deltas of the released model
are omitted (noted simplification, parameter-count-neutral at our scale).

Layer schedule for n_layers=38, attn_every=6:
  mamba x5, [shared attn], mamba x5, [shared attn], ... (6 invocations),
  trailing mamba layers.  Mamba segments are scanned (stacked params);
  attention invocations are unrolled (they share one param set).
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.context import ParallelContext, SINGLE

from . import layers as L
from . import ssm


def layer_schedule(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """[('mamba', count), ('attn', 1), ...] — segments in order."""
    per = max(cfg.attn_every, 1)
    segs: List[Tuple[str, int]] = []
    remaining = cfg.n_layers
    while remaining > 0:
        m = min(per - 1, remaining)
        if m:
            segs.append(("mamba", m))
            remaining -= m
        if remaining > 0:
            segs.append(("attn", 1))
            remaining -= 1
    return segs


def n_mamba_layers(cfg: ModelConfig) -> int:
    return sum(c for kind, c in layer_schedule(cfg) if kind == "mamba")


def init(rng, cfg: ModelConfig, ctx: ParallelContext = SINGLE):
    dt = ctx.param_dtype
    k_embed, k_m, k_a, k_h = jax.random.split(rng, 4)
    n_m = n_mamba_layers(cfg)
    mamba = jax.vmap(lambda k: ssm.init_mamba_block(k, cfg, dt))(
        jax.random.split(k_m, n_m)
    )
    ka1, ka2 = jax.random.split(k_a)
    shared_attn = {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "attn": L.init_attention(
            ka1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt
        ),
        "ln2": jnp.ones((cfg.d_model,), dt),
        "mlp": L.init_swiglu(ka2, cfg.d_model, cfg.d_ff, dt),
    }
    return {
        "embed": L.embed_init(k_embed, cfg.vocab, cfg.d_model, dt),
        "mamba": mamba,
        "shared_attn": shared_attn,
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": L.dense_init(k_h, cfg.d_model, cfg.vocab, dt),
    }


def _attn_block(p, x, cfg: ModelConfig, window=None, pos_offset=0):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + L.attention_forward(
        p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        causal=True, window=window, pos_offset=pos_offset,
    )
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + L.swiglu(p["mlp"], h)


def _take(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def forward(params, tokens, cfg: ModelConfig, ctx: ParallelContext = SINGLE,
            *, window=None, last_only: bool = False):
    x = params["embed"][tokens].astype(ctx.compute_dtype)
    off = 0
    for kind, count in layer_schedule(cfg):
        if kind == "mamba":
            seg = _take(params["mamba"], off, off + count)
            off += count

            def body(x, p):
                fn = ssm.mamba_forward
                if ctx.remat:
                    fn = jax.checkpoint(fn, static_argnums=(2,))
                return x + fn(p, x, cfg), None

            x, _ = jax.lax.scan(body, x, seg)
        else:
            # the SHARED attention block — same params each invocation.
            # Zamba2 uses full (not windowed) attention here; window only
            # kicks in for the long_500k sub-quadratic mode.
            # §Perf PAIR D: pin batch to the data axes around the block —
            # propagation otherwise replicates the global batch per device.
            from repro.sharding.context import constrain_tokens
            x = constrain_tokens(x, ctx)
            x = _attn_block(params["shared_attn"], x, cfg, window)
            x = constrain_tokens(x, ctx)
    if last_only:
        x = x[:, -1:]                    # §Perf B1: slice before lm_head
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps) @ params["lm_head"]


# -- serving ---------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               ctx: ParallelContext = SINGLE):
    n_attn = sum(1 for k, _ in layer_schedule(cfg) if k == "attn")
    n_m = n_mamba_layers(cfg)
    mamba = jax.vmap(lambda _: ssm.init_mamba_cache(cfg, batch, ctx.compute_dtype))(
        jnp.arange(n_m)
    )
    attn = jax.vmap(
        lambda _: L.init_kv_cache(batch, cfg.n_kv_heads, cache_len,
                                  cfg.head_dim, ctx.compute_dtype)
    )(jnp.arange(n_attn))
    return {"mamba": mamba, "attn": attn}


def decode_step(params, cache, token, pos, cfg: ModelConfig,
                ctx: ParallelContext = SINGLE):
    x = params["embed"][token][:, None, :].astype(ctx.compute_dtype)
    m_off = 0
    a_off = 0
    new_m, new_a = [], []
    for kind, count in layer_schedule(cfg):
        if kind == "mamba":
            seg = _take(params["mamba"], m_off, m_off + count)
            cseg = _take(cache["mamba"], m_off, m_off + count)
            m_off += count

            def body(x, pc):
                p, c = pc
                y, c = ssm.mamba_decode(p, x, c, cfg)
                return x + y, c

            x, cs = jax.lax.scan(body, x, (seg, cseg))
            new_m.append(cs)
        else:
            p = params["shared_attn"]
            c = _take(cache["attn"], a_off, a_off + 1)
            c1 = jax.tree.map(lambda a: a[0], c)
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            a, c1 = L.attention_decode(
                p["attn"], h, c1, pos,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            )
            x = x + a
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            x = x + L.swiglu(p["mlp"], h)
            new_a.append(jax.tree.map(lambda a: a[None], c1))
            a_off += 1
    cache = {
        "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_m),
        "attn": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_a),
    }
    lg = L.rms_norm(x, params["final_norm"], cfg.norm_eps) @ params["lm_head"]
    return lg[:, 0], cache
