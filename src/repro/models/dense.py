"""Dense llama-family transformer (tinyllama / smollm / qwen2.5 / llama3).

Pre-norm GQA + SwiGLU blocks, RoPE, optional QKV bias (qwen), optional
sliding-window attention (the sub-quadratic variant that makes ``long_500k``
runnable for dense archs — DESIGN.md §7).  Layers are stacked and scanned.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.context import ParallelContext, SINGLE

from . import layers as L


def init(rng, cfg: ModelConfig, ctx: ParallelContext = SINGLE):
    dt = ctx.param_dtype
    k_embed, k_blocks, k_head = jax.random.split(rng, 3)

    def init_block(r):
        r1, r2 = jax.random.split(r)
        return {
            "ln1": jnp.ones((cfg.d_model,), dt),
            "attn": L.init_attention(
                r1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                dt, cfg.qkv_bias,
            ),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "mlp": L.init_swiglu(r2, cfg.d_model, cfg.d_ff, dt),
        }

    blocks = jax.vmap(init_block)(jax.random.split(k_blocks, cfg.n_layers))
    params = {
        "embed": L.embed_init(k_embed, cfg.vocab, cfg.d_model, dt),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab, dt)
    return params


def _block_fwd(p, x, cfg: ModelConfig, window: Optional[int], pos_offset=0):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + L.attention_forward(
        p["attn"], h,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, causal=True, window=window,
        pos_offset=pos_offset,
    )
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + L.swiglu(p["mlp"], h)


def _logits(params, x, cfg: ModelConfig):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return x @ head


def forward(
    params, tokens: jnp.ndarray, cfg: ModelConfig,
    ctx: ParallelContext = SINGLE, *, window: Optional[int] = None,
    inputs_embeds: Optional[jnp.ndarray] = None, last_only: bool = False,
) -> jnp.ndarray:
    """tokens [B, S] -> logits [B, S, V].  Full attention unless window.

    ``last_only`` slices the hidden state to the final position BEFORE the
    lm_head projection — prefill only needs the last logits, and projecting
    the full sequence would all-reduce a [B, S, V] tensor across TP
    (§Perf iteration B1: 448x smaller logits collective).
    """
    x = params["embed"][tokens] if inputs_embeds is None else inputs_embeds
    x = x.astype(ctx.compute_dtype)
    # §Perf PAIR D follow-up: pin batch to the data axes each layer —
    # heads that don't divide the model axis (e.g. smollm's 9) otherwise
    # make propagation replicate the full global batch per device.
    from repro.sharding.context import constrain_tokens

    def body(x, p):
        x = constrain_tokens(x, ctx)
        fn = _block_fwd
        if ctx.remat:
            fn = jax.checkpoint(fn, static_argnums=(2, 3))
        return fn(p, x, cfg, window), None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    if last_only:
        x = x[:, -1:]
    return _logits(params, x, cfg)


# -- serving ---------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               ctx: ParallelContext = SINGLE):
    def one(_):
        return L.init_kv_cache(
            batch, cfg.n_kv_heads, cache_len, cfg.head_dim, ctx.compute_dtype
        )
    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def decode_step(
    params, cache, token: jnp.ndarray, pos: jnp.ndarray, cfg: ModelConfig,
    ctx: ParallelContext = SINGLE,
) -> Tuple[jnp.ndarray, dict]:
    """token [B] int32, pos scalar -> (logits [B, V], cache')."""
    x = params["embed"][token][:, None, :].astype(ctx.compute_dtype)

    def body(x, pc):
        p, c = pc
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        a, c = L.attention_decode(
            p["attn"], h, c, pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta,
        )
        x = x + a
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.swiglu(p["mlp"], h)
        return x, c

    x, cache = jax.lax.scan(body, x, (params["blocks"], cache))
    return _logits(params, x, cfg)[:, 0], cache
