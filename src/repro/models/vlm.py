"""InternVL2-style VLM [arXiv:2404.16821]: vision stub + InternLM2 backbone.

The InternViT encoder + MLP projector is a STUB per the assignment
carve-out: ``patches [B, n_patches, d]`` arrive as precomputed projected
patch embeddings.  The language model is the dense llama-family backbone
(GQA kv=8); image tokens are prepended to the text sequence (the standard
``<img>...</img>`` interleave collapsed to a prefix, uniform across the
batch so shapes stay static).

Decode: the patch prefix is prefilled into the KV cache; token positions
are offset by ``n_patches``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.context import ParallelContext, SINGLE

from . import dense
from . import layers as L


def init(rng, cfg: ModelConfig, ctx: ParallelContext = SINGLE):
    return dense.init(rng, cfg, ctx)


def forward(params, tokens, cfg: ModelConfig, ctx: ParallelContext = SINGLE,
            *, patches=None, window=None, last_only: bool = False, **_):
    """tokens [B, S_text], patches [B, P, d] -> logits [B, P+S_text, V]."""
    assert patches is not None, "vlm arch requires stub patch embeddings"
    tok_emb = params["embed"][tokens]
    x = jnp.concatenate(
        [patches.astype(tok_emb.dtype), tok_emb], axis=1
    )
    return dense.forward(params, tokens, cfg, ctx, window=window,
                         inputs_embeds=x, last_only=last_only)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               ctx: ParallelContext = SINGLE):
    return dense.init_cache(cfg, batch, cache_len, ctx)


def decode_step(params, cache, token, pos, cfg: ModelConfig,
                ctx: ParallelContext = SINGLE):
    """pos is the absolute position INCLUDING the patch prefix."""
    return dense.decode_step(params, cache, token, pos, cfg, ctx)
