"""Model registry: uniform Model facade over the arch families.

``build_model(cfg, ctx)`` returns a :class:`Model` exposing
``init / forward / loss / init_cache / decode_step / input_specs`` with the
same signatures across all 10 assigned architectures, so the launcher,
dry-run, and benchmarks are arch-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.sharding.context import ParallelContext, SINGLE

from . import dense, encdec, hybrid, moe, vlm, xlstm

_FAMILIES = {
    "dense": dense,
    "moe": moe,
    "hybrid": hybrid,
    "ssm": xlstm,
    "audio": encdec,
    "vlm": vlm,
}

# decode cache length policy: sub-quadratic archs keep O(1)/windowed state
_LONG = "long_500k"


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    ctx: ParallelContext
    mod: Any

    # -- parameters -------------------------------------------------------------
    def init(self, rng):
        return self.mod.init(rng, self.cfg, self.ctx)

    # -- forward / loss ----------------------------------------------------------
    def forward(self, params, batch: Dict[str, jnp.ndarray], *, window=None,
                last_only: bool = False):
        kwargs = {}
        if self.cfg.arch_type == "audio":
            kwargs["frames"] = batch["frames"]
        if self.cfg.arch_type == "vlm":
            kwargs["patches"] = batch["patches"]
        out = self.mod.forward(params, batch["tokens"], self.cfg, self.ctx,
                               window=window, last_only=last_only, **kwargs)
        if isinstance(out, tuple):
            return out              # (logits, aux)
        return out, jnp.float32(0.0)

    def loss(self, params, batch, *, window=None, aux_weight: float = 0.01):
        logits, aux = self.forward(params, batch, window=window)
        labels = batch["labels"]
        # vlm: logits cover patch prefix too; score text positions only
        if logits.shape[1] != labels.shape[1]:
            logits = logits[:, -labels.shape[1]:]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        return nll.mean() + aux_weight * aux

    # -- serving ----------------------------------------------------------------
    def cache_len(self, shape: InputShape) -> int:
        if self.cfg.arch_type in ("ssm",):
            return 0                               # O(1) recurrent state
        if shape.name == _LONG:
            # dense/hybrid/moe/vlm run long context via sliding window
            return self.cfg.window or 4096
        if self.cfg.arch_type == "audio":
            return min(shape.seq_len, 448)         # whisper max target len
        return shape.seq_len

    def init_cache(self, batch: int, shape: InputShape):
        return self.mod.init_cache(
            self.cfg, batch, max(self.cache_len(shape), 1), self.ctx
        )

    def decode_step(self, params, cache, token, pos):
        return self.mod.decode_step(params, cache, token, pos, self.cfg,
                                    self.ctx)

    # -- dry-run input specs ------------------------------------------------------
    def supports(self, shape: InputShape) -> bool:
        return shape.name not in self.cfg.skip_shapes

    def input_specs(self, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
            if cfg.arch_type == "audio":
                # decoder scores text; encoder consumes stub frames
                specs["tokens"] = jax.ShapeDtypeStruct((B, min(S, 448)), i32)
                specs["labels"] = jax.ShapeDtypeStruct((B, min(S, 448)), i32)
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_audio_frames, cfg.d_model), self.ctx.compute_dtype
                )
            if cfg.arch_type == "vlm":
                specs["patches"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_patches, cfg.d_model), self.ctx.compute_dtype
                )
            return specs
        # decode: one token against a seq_len-deep cache
        return {
            "token": jax.ShapeDtypeStruct((B,), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }


def build_model(cfg: ModelConfig, ctx: ParallelContext = SINGLE) -> Model:
    if cfg.arch_type not in _FAMILIES:
        raise KeyError(f"unknown arch_type {cfg.arch_type!r}")
    return Model(cfg, ctx, _FAMILIES[cfg.arch_type])
