"""Pallas chunkwise mLSTM scan kernel vs the per-step cell oracle
(interpret=True on CPU), swept over shapes/chunks/dtypes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mlstm_scan import mlstm_scan, mlstm_scan_ref


def _inputs(B, H, S, dh, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = (rng.normal(size=(B, H, S, dh)) * 0.3).astype(dtype)
    k = (rng.normal(size=(B, H, S, dh)) * 0.3).astype(dtype)
    v = (rng.normal(size=(B, H, S, dh)) * 0.3).astype(dtype)
    ig = (rng.normal(size=(B, H, S)) * 0.5).astype(np.float32)
    fg = (rng.normal(size=(B, H, S)) + 2.0).astype(np.float32)
    lf = np.log(1.0 / (1.0 + np.exp(-fg))).astype(np.float32)  # log-sigmoid
    return map(jnp.asarray, (q, k, v, ig, lf))


@pytest.mark.parametrize("B,H,S,dh,chunk", [
    (2, 2, 64, 16, 16),
    (1, 3, 128, 32, 32),
    (2, 1, 96, 8, 32),     # chunk doesn't divide evenly into powers
    (1, 1, 256, 64, 64),
])
def test_kernel_matches_cell_oracle(B, H, S, dh, chunk):
    q, k, v, ig, lf = _inputs(B, H, S, dh)
    got = mlstm_scan(q, k, v, ig, lf, chunk=chunk, interpret=True)
    ref = mlstm_scan_ref(q, k, v, ig, lf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_kernel_bf16_io():
    q, k, v, ig, lf = _inputs(1, 2, 64, 16, seed=1)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    got = mlstm_scan(qb, kb, vb, ig, lf, chunk=32, interpret=True)
    ref = mlstm_scan_ref(q, k, v, ig, lf)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref), rtol=5e-2, atol=5e-2
    )


def test_matches_model_chunk_body():
    """The kernel's chunk recurrence equals models/xlstm's jnp version."""
    import functools

    import jax

    from repro.models import xlstm

    B, H, S, dh, L = 2, 2, 64, 16, 16
    q, k, v, ig, lf = _inputs(B, H, S, dh, seed=2)
    got = mlstm_scan(q, k, v, ig, lf, chunk=L, interpret=True)

    # drive _mlstm_chunk_body directly ([B, L, H, dh] layout)
    rc = lambda a: a.transpose(0, 2, 1, 3).reshape(
        (B, S // L, L) + a.shape[3:][-1:]).transpose(1, 0, 2, 3) \
        if a.ndim == 4 else \
        a.transpose(0, 2, 1).reshape(B, S // L, L).transpose(1, 0, 2)
    qs = q.transpose(0, 2, 1, 3)  # [B, S, H, dh]
    ks = k.transpose(0, 2, 1, 3)
    vs = v.transpose(0, 2, 1, 3)
    igs = ig.transpose(0, 2, 1)   # [B, S, H]
    lfs = lf.transpose(0, 2, 1)
    chunked = lambda a: a.reshape((B, S // L, L) + a.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, a.ndim + 1)))
    st = {"C": jnp.zeros((B, H, dh, dh), jnp.float32),
          "n": jnp.zeros((B, H, dh), jnp.float32),
          "m": jnp.full((B, H), -30.0, jnp.float32)}
    _, ys = jax.lax.scan(
        functools.partial(xlstm._mlstm_chunk_body, L=L), st,
        (chunked(qs), chunked(ks), chunked(vs), chunked(igs), chunked(lfs)),
    )
    ref = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh).transpose(
        0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
