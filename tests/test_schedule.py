"""Offset-parameterized schedule: structural invariants."""

import numpy as np
import pytest

from repro.core.dataplane import build_rel_of_pair, rel_id_of
from repro.core.schedule import (
    build_planner_tables,
    build_schedule,
    enumerate_relations,
    n_candidates,
    path_hops,
    path_nodes,
)
from repro.core.topology import Topology


@pytest.mark.parametrize("n,G", [(8, 4), (16, 4), (4, 4), (12, 4), (8, 2)])
def test_relations_cover_all_pairs(n, G):
    t = Topology(n, group_size=G)
    rel = build_rel_of_pair(n, G)
    rels = enumerate_relations(n // G, G)
    assert len(rels) == n - 1 or len(rels) == (n // G) * G - 1
    # every ordered pair maps to exactly one relation; diagonal none
    for s in range(n):
        seen = set()
        for d in range(n):
            if s == d:
                assert rel[s, d] == -1
            else:
                assert rel[s, d] >= 0
                seen.add(rel[s, d])
        assert len(seen) == n - 1


@pytest.mark.parametrize("n,G", [(8, 4), (16, 4)])
def test_paths_reach_destination(n, G):
    """Composing each candidate's hops lands on the relation's dest."""
    NG = n // G
    for rel in enumerate_relations(NG, G):
        for k in range(n_candidates(rel, G)):
            for s in range(n):
                nodes = path_nodes(rel, k, s, G, NG)
                g, p = divmod(s, G)
                want = ((g + rel.m) % NG) * G + (p + rel.dq) % G
                assert nodes[-1] == want
                assert len(nodes) <= 4  # <=3 hops (paper cap)


def test_candidate_uniqueness():
    """Different k => different relay/rail — no duplicate routes."""
    G, NG = 4, 2
    for rel in enumerate_relations(NG, G):
        seen = set()
        for k in range(n_candidates(rel, G)):
            nodes = tuple(path_nodes(rel, k, 0, G, NG))
            assert nodes not in seen
            seen.add(nodes)


def test_schedule_slots_and_rounds():
    t = Topology(8, group_size=4)
    sched = build_schedule(t, C=16, alt_frac=0.5)
    # slot bookkeeping covers every (rel, k) exactly S[rel, k] times
    for rel in sched.rels:
        for k in range(sched.K):
            count = int(
                ((sched.slot_rel == rel.rel_id) & (sched.slot_k == k)).sum()
            )
            assert count == int(sched.S[rel.rel_id, k])
    # each slot appears in exactly the rounds its path has hops for
    for sid in range(sched.n_slots):
        rel = sched.rels[sched.slot_rel[sid]]
        hops = path_hops(rel, int(sched.slot_k[sid]), t.group_size)
        for tstep in range(3):
            in_round = any(
                sid in ids for _, ids in sched.rounds[tstep]
            )
            assert in_round == (hops[tstep] is not None)


def test_perm_pairs_are_permutations():
    t = Topology(16, group_size=4)
    sched = build_schedule(t, C=4)
    for rnd in sched.rounds:
        for hop, _ in rnd:
            pairs = sched.perm_pairs(hop)
            srcs = [a for a, _ in pairs]
            dsts = [b for _, b in pairs]
            assert sorted(srcs) == list(range(16))
            assert sorted(dsts) == list(range(16))


def test_planner_tables_shapes():
    t = Topology(8, group_size=4)
    tb = build_planner_tables(t)
    assert tb.pair_path_ids.shape == (64, tb.K)
    # diagonal pairs have no paths
    for s in range(8):
        assert (tb.pair_path_ids[s * 8 + s] == -1).all()
    # every non-diagonal pair has at least one candidate
    for s in range(8):
        for d in range(8):
            if s != d:
                assert (tb.pair_path_ids[s * 8 + d] >= 0).any()
