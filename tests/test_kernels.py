"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash import flash_attention
from repro.kernels.flash_attention.ops import chunked_attention
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.grouped_ffn.ffn import grouped_ffn_blocked
from repro.kernels.grouped_ffn.ops import grouped_ffn, grouped_ffn_scan
from repro.kernels.grouped_ffn.ref import grouped_ffn_ref
from repro.kernels.relay_copy.relay import relay_copy
from repro.kernels.token_scatter.ops import token_gather
from repro.kernels.token_scatter.ref import token_gather_ref

RNG = np.random.default_rng(0)


# --------------------------------------------------------------------------- #
# token gather (kernel scatter)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("n,m,d", [(64, 100, 32), (16, 16, 128), (128, 7, 8)])
def test_token_gather(n, m, d, dtype):
    x = RNG.normal(size=(n, d)).astype(dtype)
    idx = RNG.integers(-1, n, size=(m,)).astype(np.int32)
    out = token_gather(jnp.asarray(x), jnp.asarray(idx))
    ref = token_gather_ref(jnp.asarray(x), jnp.asarray(idx))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


def test_token_gather_grad_is_scatter_add():
    x = RNG.normal(size=(32, 8)).astype(np.float32)
    idx = np.array([0, 0, 1, 5, 31, -1], np.int32)
    g = jax.grad(lambda x: token_gather(x, jnp.asarray(idx)).sum())(
        jnp.asarray(x)
    )
    expect = np.zeros_like(x)
    for i in idx:
        if i >= 0:
            expect[i] += 1
    np.testing.assert_allclose(np.asarray(g), expect)


# --------------------------------------------------------------------------- #
# grouped FFN
# --------------------------------------------------------------------------- #


def _ffn_inputs(N, D, F, E, dtype=np.float32):
    x = (RNG.normal(size=(N, D)) * 0.1).astype(dtype)
    eid = RNG.integers(-1, E, size=(N,)).astype(np.int32)
    wg = (RNG.normal(size=(E, D, F)) * 0.05).astype(dtype)
    wu = (RNG.normal(size=(E, D, F)) * 0.05).astype(dtype)
    wd = (RNG.normal(size=(E, F, D)) * 0.05).astype(dtype)
    return map(jnp.asarray, (x, eid, wg, wu, wd))


@pytest.mark.parametrize("N,D,F,E,bt,bf", [
    (128, 32, 64, 2, 32, 32),
    (200, 64, 128, 4, 32, 64),
    (64, 16, 32, 8, 16, 16),
])
def test_grouped_ffn_pallas(N, D, F, E, bt, bf):
    x, eid, wg, wu, wd = _ffn_inputs(N, D, F, E)
    y = grouped_ffn(x, eid, wg, wu, wd, block_tokens=bt, block_ffn=bf)
    ref = grouped_ffn_ref(x, eid, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_grouped_ffn_scan_matches_ref():
    x, eid, wg, wu, wd = _ffn_inputs(700, 32, 64, 4)
    y = grouped_ffn_scan(x, eid, wg, wu, wd, block_tokens=64)
    ref = grouped_ffn_ref(x, eid, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_grouped_ffn_bf16():
    x, eid, wg, wu, wd = _ffn_inputs(96, 32, 64, 2, np.float32)
    x = x.astype(jnp.bfloat16)
    y = grouped_ffn(x, eid, wg, wu, wd, block_tokens=32, block_ffn=32)
    ref = grouped_ffn_ref(x, eid, wg, wu, wd)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-3,
    )


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("window", [None, 96, 256])
@pytest.mark.parametrize("B,H,Hkv,S,Dh", [
    (2, 4, 2, 256, 64), (1, 2, 1, 128, 32), (1, 8, 8, 256, 16),
])
def test_flash_vs_ref(B, H, Hkv, S, Dh, window):
    q = (RNG.normal(size=(B, H, S, Dh)) * 0.3).astype(np.float32)
    k = (RNG.normal(size=(B, Hkv, S, Dh)) * 0.3).astype(np.float32)
    v = (RNG.normal(size=(B, Hkv, S, Dh)) * 0.3).astype(np.float32)
    o = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=True, window=window, bq=128, bk=128,
                        interpret=True)
    r = mha_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("window", [None, 100])
def test_chunked_attention_vs_ref(window):
    B, H, Hkv, Sq, Sk, Dh = 1, 4, 2, 64, 384, 32
    q = (RNG.normal(size=(B, H, Sq, Dh)) * 0.3).astype(np.float32)
    k = (RNG.normal(size=(B, Hkv, Sk, Dh)) * 0.3).astype(np.float32)
    v = (RNG.normal(size=(B, Hkv, Sk, Dh)) * 0.3).astype(np.float32)
    o = chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=False, window=window, chunk=100)
    r = mha_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                causal=False, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=2e-5, atol=2e-6)


def test_flash_decode_offset():
    """q_offset (decode position) shifts causal masking correctly."""
    B, H, S, Dh = 1, 2, 128, 32
    q = (RNG.normal(size=(B, H, 8, Dh)) * 0.3).astype(np.float32)
    k = (RNG.normal(size=(B, H, S, Dh)) * 0.3).astype(np.float32)
    v = (RNG.normal(size=(B, H, S, Dh)) * 0.3).astype(np.float32)
    o = chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=True, q_offset=64, chunk=64)
    r = mha_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                causal=True, q_offset=64)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                               rtol=2e-5, atol=2e-6)


# --------------------------------------------------------------------------- #
# relay copy
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("n,d,bc", [(1024, 64, 256), (512, 128, 64),
                                    (256, 32, 256)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_relay_copy(n, d, bc, dtype):
    if dtype == np.int32:
        x = RNG.integers(-100, 100, size=(n, d)).astype(dtype)
    else:
        x = RNG.normal(size=(n, d)).astype(dtype)
    out = relay_copy(jnp.asarray(x), block_chunk=bc, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), x)


def test_relay_copy_slot_map_bit_exact():
    # ISSUE 10 satellite: the slot schedule is runtime data.  Any valid
    # schedule — parity, reversed parity, constant-slot — must produce a
    # bit-identical copy, because the slot only selects *which* staging
    # buffer the chunk passes through, never the data path.
    from repro.kernels.relay_copy.relay import parity_slot_map

    x = jnp.asarray(RNG.normal(size=(1024, 64)).astype(np.float32))
    n_chunks = 1024 // 256
    default = relay_copy(x, block_chunk=256, interpret=True)
    for slot_map in (
        parity_slot_map(n_chunks),
        1 - parity_slot_map(n_chunks),          # swapped slot assignment
        jnp.zeros((n_chunks,), dtype=jnp.int32),  # degenerate single slot
    ):
        out = relay_copy(x, slot_map, block_chunk=256, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(default))


def test_relay_copy_slot_swap_does_not_retrace():
    # the point of the scalar-prefetched slot map: re-targeting staging
    # slots is a parameter update, not a recompile — one jit cache entry
    # serves every schedule of the same geometry (ROADMAP item 2)
    from repro.kernels.relay_copy.relay import (
        parity_slot_map,
        relay_copy as relay_jit,
    )

    relay_jit._clear_cache()
    x = jnp.asarray(RNG.normal(size=(512, 32)).astype(np.float32))
    n_chunks = 512 // 256
    relay_jit(x, parity_slot_map(n_chunks), block_chunk=256, interpret=True)
    relay_jit(x, 1 - parity_slot_map(n_chunks), block_chunk=256,
              interpret=True)
    relay_jit(x, jnp.ones((n_chunks,), dtype=jnp.int32), block_chunk=256,
              interpret=True)
    assert relay_jit._cache_size() == 1
