"""Price recency (ISSUE 5): decayed ledger prices, swap-boundary
re-pricing, fingerprint-checked commits, and the unregister hint audit.

The contracts under test:

  * window-stamped commits drive a fabric clock; a stamped peer's exported
    price fades with a configurable half-life (monotone non-increasing in
    staleness — property-tested), unstamped host commits never fade, and
    ``price_decay=None`` is byte-identical to the raw pre-recency ledger
    (the skew-vs-elephant acceptance scenario is pinned bit-exact);
  * a pending plan whose prices moved past ``price_hint_rel`` between
    issue and swap boundary still swaps, but is immediately re-solved
    against live prices (swap-and-refine, one round per replan chain);
  * the mutual-drift scenario that regressed to ~0.92x combined drain
    under raw prices holds >= 1.0x vs the unpriced baseline under the
    calibrated ``SessionSpec`` defaults;
  * ``FabricState.commit`` names both fingerprints when a tenant exports
    telemetry solved against a different fabric geometry, and accepts
    transient per-link-scale divergence;
  * ``FabricArbiter.unregister`` removes the departing tenant's bus
    subscription *before* the withdrawal hint and publishes nothing (and
    counts nothing) when no subscriber remains.
"""

import os
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from hypothesis_compat import given, settings, st

from repro.core.mcf import solve_direct, solve_mwu
from repro.core.topology import Topology
from repro.fabric import (
    ArbiterConfig,
    FabricArbiter,
    FabricState,
    RepriceDecision,
)
from repro.runtime import (
    OrchestrationRuntime,
    PolicyConfig,
    PricesMovedHint,
    ReplanPolicy,
    balanced_trace,
)

ROOT = os.path.join(os.path.dirname(__file__), "..")

MB = float(1 << 20)
N = 8
G = 4


@pytest.fixture(scope="module")
def topo():
    return Topology(N, group_size=G)


def skew_demand(bytes_per_src=64 * MB, hot=0, hot_frac=0.7):
    return {
        (s, d): bytes_per_src * (
            hot_frac if d == hot else (1.0 - hot_frac) / (N - 2)
        )
        for s in range(N)
        for d in range(N)
        if s != d
    }


def elephant(topo, mb=128.0, rails=(0, 1)):
    D = {}
    for r in rails:
        D[(r, r + G)] = mb * MB
        D[(r + G, r)] = mb * MB
    return solve_direct(topo, D)


# -- ledger recency ---------------------------------------------------------------

def test_commit_stamps_and_clock(topo):
    state = FabricState(topo)
    loads = np.ones(state.n_resources)
    state.commit("host", loads)                 # unstamped
    state.commit("rt", loads, window=3)         # stamped
    assert state.clock == 3
    assert state.staleness("host") is None
    assert state.staleness("rt") == 0.0
    state.commit("rt2", loads, window=7)
    assert state.clock == 7
    assert state.staleness("rt") == 4.0
    # a commit stamped behind the clock never rewinds it
    state.commit("rt", loads, window=5)
    assert state.clock == 7 and state.staleness("rt") == 2.0
    # withdrawal forgets the stamp
    state.withdraw("rt")
    assert state.staleness("rt") is None


def test_decay_factor_semantics(topo):
    state = FabricState(topo)
    loads = np.ones(state.n_resources)
    state.commit("host", loads)
    state.commit("stale", loads, window=0)
    state.commit("fresh", loads, window=4)
    # half-life semantics: exactly halved per half_life windows of staleness
    assert state.decay_factor("stale", 4.0) == pytest.approx(0.5)
    assert state.decay_factor("stale", 2.0) == pytest.approx(0.25)
    # fresh, unstamped, unknown, and disabled half-lives are all exactly 1
    assert state.decay_factor("fresh", 2.0) == 1.0
    assert state.decay_factor("host", 2.0) == 1.0
    assert state.decay_factor("missing", 2.0) == 1.0
    assert state.decay_factor("stale", None) == 1.0
    assert state.decay_factor("stale", 0.0) == 1.0


def test_external_load_decay_none_bit_identical(topo):
    """half_life=None takes the exact raw-ledger path (total minus own)."""
    rng = np.random.default_rng(0)
    state = FabricState(topo)
    for i, t in enumerate(("a", "b", "c")):
        state.commit(t, rng.uniform(0.0, 1e9, state.n_resources), window=i)
    raw = state.external_load("a")
    expect = state.total_load() - state.committed_load("a")
    assert np.array_equal(raw, np.maximum(expect, 0.0))
    # with every entry unstamped the decayed path multiplies by exactly
    # 1.0 per peer — same value up to summation order (it sums peers
    # directly instead of total-minus-own)
    state2 = FabricState(topo)
    for t in ("a", "b", "c"):
        state2.commit(t, state.committed_load(t))  # unstamped
    decayed = state2.external_load("a", half_life=2.0)
    assert np.allclose(decayed, state2.external_load("a"), rtol=1e-15)
    assert np.array_equal(
        decayed,
        state2.committed_load("b") + state2.committed_load("c"),
    )


@settings(max_examples=20, deadline=None)
@given(st.floats(0.5, 16.0), st.integers(1, 6))
def test_decayed_prices_monotone_in_staleness(half_life, steps):
    """Property: a peer's decayed price is monotone non-increasing as the
    fabric clock runs past its last stamp."""
    topo = Topology(N, group_size=G)
    state = FabricState(topo)
    rng = np.random.default_rng(42)
    peer_load = rng.uniform(0.0, 1e9, state.n_resources)
    state.commit("peer", peer_load, window=0)
    state.commit("me", np.zeros(state.n_resources), window=0)
    prev = state.external_load("me", half_life=half_life)
    assert np.array_equal(prev, peer_load)  # staleness 0: exact
    for k in range(1, steps + 1):
        state.commit("me", np.zeros(state.n_resources), window=k)
        cur = state.external_load("me", half_life=half_life)
        assert (cur <= prev + 1e-9).all(), (
            f"decayed price increased with staleness at clock {k}"
        )
        assert (cur[peer_load > 0] < prev[peer_load > 0]).all()
        prev = cur


def test_prices_for_applies_decay(topo):
    bg = elephant(topo).resource_bytes
    arb = FabricArbiter(topo, cfg=ArbiterConfig(price_decay=2.0))
    raw = FabricArbiter(topo)
    for a in (arb, raw):
        a.register("me")
        a.register("peer")
        a.commit("peer", bg, window=0)
        a.commit("me", np.zeros(a.state.n_resources), window=4)
    assert np.allclose(arb.prices_for("me"), 0.25 * bg)
    assert np.array_equal(raw.prices_for("me"), bg)  # price_decay=None raw


# -- regression: skew-vs-elephant pinned bit-identical under decay=None ----------

def test_skew_vs_elephant_bit_identical_decay_none(topo):
    """The PR-3 acceptance scenario byte-for-byte under price_decay=None —
    via the raw hand-wired arbiter and via the opt-out Session."""
    from repro.api import Session, SessionSpec

    D = skew_demand()
    bg = elephant(topo)

    # hand-wired raw-ledger reference (exactly the PR-3 code path)
    ref_arb = FabricArbiter(topo)
    ref_arb.register("skew")
    ref_arb.register("bg")
    ref_arb.commit("bg", bg.resource_bytes)
    ref = solve_mwu(topo, D, ext_loads=ref_arb.prices_for("skew"))
    ref_arb.commit("skew", ref.resource_bytes)

    spec = SessionSpec(topology=topo, adaptivity="arbitrated", tenant="skew",
                       price_decay=None, fabric_staleness=None)
    with Session(spec) as sess:
        sess.join_static_tenant("bg", bg)
        got = sess.plan(D)
        got_combined = sess.fabric.combined_drain_s()
    assert np.array_equal(got.resource_bytes, ref.resource_bytes)
    assert np.array_equal(got.link_bytes, ref.link_bytes)
    assert got.per_pair_bytes() == ref.per_pair_bytes()
    assert got_combined == ref_arb.combined_drain_s()
    # and the calibrated-default Session is *also* identical here: the
    # background commit is unstamped (timeless), so decay never touches it
    with Session(SessionSpec(topology=topo, adaptivity="arbitrated",
                             tenant="skew")) as sess:
        sess.join_static_tenant("bg", bg)
        assert np.array_equal(sess.plan(D).resource_bytes, ref.resource_bytes)


# -- swap-boundary re-pricing -----------------------------------------------------

def test_reprice_decision_semantics(topo):
    bg = elephant(topo).resource_bytes
    arb = FabricArbiter(topo)
    arb.register("me")
    arb.register("peer")
    # idle fabric, solved unpriced: nothing moved
    d = arb.reprice("me", None)
    assert isinstance(d, RepriceDecision)
    assert not d.moved and d.rel_change == 0.0 and d.prices is None
    # peer appears after the solve: full move
    arb.commit("peer", bg)
    d = arb.reprice("me", None)
    assert d.moved and d.rel_change == 1.0
    assert np.array_equal(d.prices, bg)
    # solved under the same prices: no move
    d = arb.reprice("me", bg.copy())
    assert not d.moved and d.rel_change == 0.0
    # sub-threshold wiggle: no move
    arb.commit("peer", bg * 1.05)
    assert not arb.reprice("me", bg.copy()).moved
    # peer withdrew after the solve: full move back to unpriced
    arb.state.withdraw("peer")
    d = arb.reprice("me", bg.copy())
    assert d.moved and d.prices is None
    assert arb.stats.reprices == 2  # only the moved verdicts count


def test_reprice_disabled_by_hint_rel_zero(topo):
    arb = FabricArbiter(topo, cfg=ArbiterConfig(price_hint_rel=0.0))
    arb.register("me")
    arb.register("peer")
    arb.commit("peer", elephant(topo).resource_bytes)
    d = arb.reprice("me", None)
    assert not d.moved and d.rel_change == 1.0  # measured, never acted on
    assert arb.stats.reprices == 0


def test_swap_boundary_reprices_stale_pending(topo):
    """A pending plan whose prices moved between issue and swap boundary
    swaps in AND spawns one re-priced refinement (swap-and-refine)."""
    trace = balanced_trace(N, 10)
    arb = FabricArbiter(topo)
    rt = OrchestrationRuntime(
        topo,
        policy=ReplanPolicy(PolicyConfig(max_staleness=3,
                                         cooldown_windows=0)),
    )
    arb.register_runtime("t", rt)
    arb.register("peer")

    reports = [rt.step(trace[0]), rt.step(trace[1]), rt.step(trace[2])]
    # w3 hits max_staleness: replan issued, solved under prices=None
    reports.append(rt.step(trace[3]))
    assert reports[-1].replan_issued and reports[-1].replan_reason == "staleness"
    # the fabric shifts while the plan is in flight
    arb.commit("peer", elephant(topo, mb=512.0).resource_bytes)
    # swap boundary: the admitted plan swaps, a refine is parked pending
    reports.append(rt.step(trace[4]))
    assert reports[-1].swapped
    assert rt.stats.reprices == 1 and arb.stats.reprices == 1
    # the refined (live-priced) plan lands at the next boundary
    reports.append(rt.step(trace[5]))
    assert reports[-1].swapped and reports[-1].plan_source == "reprice"
    # one refine round per chain: even with prices still moving, the
    # refined plan swapped without spawning another
    assert rt.stats.reprices == 1
    # refines complete an admitted replan — they are not new replans
    assert rt.stats.replans == 1


def test_reprice_skipped_when_prices_stable(topo):
    """Stable prices across the issue->swap window: swap exactly as the
    pre-recency runtime did, no refine, no extra solves."""
    trace = balanced_trace(N, 8)
    bg = elephant(topo)

    plain = OrchestrationRuntime(
        topo,
        policy=ReplanPolicy(PolicyConfig(max_staleness=3,
                                         cooldown_windows=0)),
    )
    arb = FabricArbiter(topo)
    rt = OrchestrationRuntime(
        topo,
        policy=ReplanPolicy(PolicyConfig(max_staleness=3,
                                         cooldown_windows=0)),
    )
    arb.register_runtime("t", rt)
    arb.register("peer")
    arb.commit("peer", bg.resource_bytes)   # committed BEFORE any solve
    res = rt.run_trace(trace)
    assert rt.stats.reprices == 0 and arb.stats.reprices == 0
    # same trigger cadence as an unpriced runtime (prices never moved)
    ref = plain.run_trace(trace)
    assert [r.replan_issued for r in res.reports] == [
        r.replan_issued for r in ref.reports
    ]
    assert [r.swapped for r in res.reports] == [
        r.swapped for r in ref.reports
    ]


# -- mutual drift: the headline acceptance ---------------------------------------

@pytest.mark.timeout(600)
def test_mutual_drift_calibrated_beats_unpriced():
    """ISSUE 5 acceptance: two mutually drifting arbitrated tenants under
    the calibrated recency defaults drain >= 1.0x vs the unpriced
    baseline (the raw-ledger arbiter regressed to ~0.92x), on the exact
    scenario the --smoke gate pins."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.bench_fairness import (
            mutual_drift,
            validate_mutual_drift,
        )
    finally:
        sys.path.remove(ROOT)
    section = mutual_drift(windows=32)
    validate_mutual_drift(section)      # schema + win >= 1.0
    assert section["win"] >= 1.0, section["win"]
    assert section["win_legacy"] < 1.0, (
        "the raw-ledger regression disappeared — recalibrate the scenario"
    )
    assert section["arms"]["calibrated"]["reprices"] >= 1


# -- fingerprint-checked commits (satellite) --------------------------------------

def test_commit_rejects_foreign_geometry_fingerprint(topo):
    state = FabricState(topo)
    other = Topology(N, group_size=2)       # different geometry
    with pytest.raises(ValueError) as ei:
        state.commit(
            "t", np.ones(state.n_resources), fingerprint=other.fingerprint
        )
    msg = str(ei.value)
    assert str(other.fingerprint) in msg and str(state.fingerprint) in msg
    assert "t" in msg
    # the bare shape error still fires without a fingerprint, and points
    # at the fingerprint-naming path
    with pytest.raises(ValueError, match="shape"):
        state.commit("t", np.ones(3))


def test_commit_accepts_scale_only_divergence(topo):
    """A runtime mid-way through applying a broadcast link event commits
    with a scale-divergent fingerprint — expected, not an error."""
    state = FabricState(topo)
    state.apply_link_overrides({(0, G): 0.5})
    assert state.fingerprint != topo.fingerprint
    state.commit("t", np.ones(state.n_resources),
                 window=1, fingerprint=topo.fingerprint)
    assert state.tenants() == ["t"]


def test_arbiter_commit_passes_fingerprint_through(topo):
    arb = FabricArbiter(topo)
    arb.register("t")
    other = Topology(N, group_size=2)
    with pytest.raises(ValueError, match="fingerprint"):
        arb.commit("t", np.ones(arb.state.n_resources),
                   fingerprint=other.fingerprint)
    assert arb.stats.commits == 0   # rejected commits are not counted


def test_late_joiner_not_priced_stale(topo):
    """A tenant joining a fabric that already ran N windows starts its
    local window counter at 0; its commits must stamp in *fabric* windows
    (bind-time clock offset), or decay prices it to near-nothing and the
    incumbent plans as if it did not exist."""
    from repro.api import Session, SessionSpec

    trace = balanced_trace(N, 60)
    spec_a = SessionSpec(topology=topo, adaptivity="arbitrated", tenant="a")
    with Session(spec_a) as sa:
        for w in range(50):
            sa.step(trace[w])
        assert sa.fabric.state.clock == 49
        spec_b = SessionSpec(topology=topo, adaptivity="arbitrated",
                             tenant="b", fabric=sa.fabric)
        with Session(spec_b) as sb:
            sb.step(trace[50])
            # b's first commit is stamped at the fabric clock, not at 0
            assert sa.fabric.state.staleness("b") == 0.0
            decay = sa.fabric.cfg.price_decay
            assert sa.fabric.state.decay_factor("b", decay) == 1.0
            # a's prices therefore carry b's full committed load
            committed = sa.fabric.state.committed_load("b")
            assert np.array_equal(
                sa.fabric.prices_for("a"), committed
            )


def test_runtime_export_carries_window_and_fingerprint(topo):
    trace = balanced_trace(N, 3)
    arb = FabricArbiter(topo)
    rt = OrchestrationRuntime(topo)
    arb.register_runtime("t", rt)
    for w in range(3):
        rt.step(trace[w])
        assert arb.state.staleness("t") == 0.0
        assert arb.state.clock == w
    assert arb.stats.commits == 3


# -- unregister hint audit (satellite) --------------------------------------------

def test_unregister_no_hint_without_subscribers(topo):
    """The last runtime's own departure must not hint into the void: the
    bus is empty once it unsubscribes, so nothing is published and
    ``price_hints`` stays put."""
    arb = FabricArbiter(topo)
    rt = OrchestrationRuntime(topo)
    arb.register_runtime("solo", rt)
    arb.commit("solo", np.ones(arb.state.n_resources))
    before = arb.stats.price_hints
    arb.unregister("solo")
    assert arb.stats.price_hints == before
    assert len(arb.bus) == 0


def test_unregister_departing_tenant_never_sees_own_hint(topo):
    """Unsubscribe happens before the withdrawal hint: the survivor gets
    exactly one hint, the departing runtime's pressure clock stays off."""
    arb = FabricArbiter(topo)
    rt_leaving = OrchestrationRuntime(
        topo, policy=ReplanPolicy(PolicyConfig(fabric_staleness=1))
    )
    rt_staying = OrchestrationRuntime(
        topo, policy=ReplanPolicy(PolicyConfig(fabric_staleness=1))
    )
    arb.register_runtime("leaving", rt_leaving)
    arb.register_runtime("staying", rt_staying)
    loads = np.ones(arb.state.n_resources)
    arb.commit("leaving", loads)
    arb.commit("staying", loads)
    # isolate the withdrawal hint: clear the clocks the commit-path hints
    # legitimately started above
    rt_leaving.policy._pressure_window = None
    rt_staying.policy._pressure_window = None
    before = arb.stats.price_hints
    arb.unregister("leaving")
    assert arb.stats.price_hints == before + 1
    # the survivor's soft-staleness clock started; the departed runtime
    # was unsubscribed before the hint and never saw its own withdrawal
    assert rt_staying.policy._pressure_window is not None
    assert rt_leaving.policy._pressure_window is None


def test_unregister_hint_watermark_left_for_future_subscribers(topo):
    """A hint skipped for lack of subscribers must not consume the move:
    the next subscribed observer still sees the accumulated shift."""
    arb = FabricArbiter(topo)
    arb.register("a")
    arb.register("b")
    loads = np.ones(arb.state.n_resources)
    arb.commit("a", loads)      # no subscribers: skipped, watermark at 0
    arb.commit("b", loads)
    seen = []
    arb.bus.subscribe(lambda evs: seen.extend(evs))
    arb.commit("b", 1.05 * loads)  # tiny wiggle vs ledger, huge vs watermark
    hints = [e for e in seen if isinstance(e, PricesMovedHint)]
    assert len(hints) == 1 and hints[0].rel_change > 0.5
